"""Encrypted two-table joins: batched nested-loop and sort-merge.

The engine's first multi-table operator.  A `plan.Join` names a join-key
column pair plus optional per-side filter sub-plans; `execute_join`
resolves the sides through the ordinary single-table machinery (fused
scans / index probes), then matches key pairs with one of two
strategies — both built from the same raw-eval + host-side-threshold
design as the filter stage, so ε-band (CKKS float) joins ride the exact
launches the integer path uses:

  * NESTED-LOOP (`strategy="nested"`).  All N_l × N_r key comparisons
    run as tiled batched raw Evals over the padded row-pair grid: a tile
    is the familiar `[A, N]` fused-scan layout with A = a block of left
    rows standing in for "atoms" and N = the right column (ONE XLA
    program per tile, shapes padded to powers of two so the jit cache
    stays warm across queries).  The join's decode threshold (profile τ
    or ε-derived) applies host-side on the raw grid.  Exact, index-free,
    O(n_l·n_r) compare lanes.

  * SORT-MERGE (`strategy="sort_merge"`).  Reuses two `SortedIndex`es
    (building them on the fly when absent, cost attributed): the two
    ascending ciphertext runs merge through the log-depth half-cleaner +
    bitonic merge network (`shard/merge.merge_sorted_runs` — every stage
    ONE batched Eval), then a single adjacency Eval over consecutive
    merged rows splits the run into equal-key classes; cross-side pairs
    within a class are the join candidates.  O((n_l+n_r)·log(n_l+n_r))
    compares instead of the full product.  For ε-band / CKKS joins the
    candidate classes are verified with one batched per-pair Eval
    (ε-equality is not transitive, so adjacency chaining may overclaim;
    the verification pass restores exact |l − r| <= ε semantics).

`strategy="auto"` picks sort-merge when both sides carry an index on
their join-key column, else nested-loop.  Handed a `ShardedTable` on
either side, `execute_join` dispatches to the cross-shard executor
(`db.shard.join`), which runs the same two strategies on the
[S_l, S_r] shard-pair grid / the S_l + S_r shard-run merge.

Output contract: `JoinResult.pairs` is the [P, 2] array of
(left_row_id, right_row_id) matches in canonical lexicographic order —
deliberately placement- and strategy-independent, which is what the
shard-invariance and nested-vs-merge equivalence tests assert
byte-for-byte.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compare as C
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db import executor as X
from repro.db import plan as P
from repro.db.index import SortedIndex
from repro.db.table import Table, rows_to_mask

# Upper bound on row pairs per nested-loop Eval tile: keeps the
# [T·N_r, K, n] eval intermediates in tens of MB on the test profiles
# while leaving every tile ONE fused launch.  Tiles are power-of-two
# sized so repeated queries against the same table pair reuse the jit
# cache entry.  Pair-grid entry points take `block_pairs=None` and
# resolve through the shared lane-budget policy
# (`kernels.ops.resolve_lane_budget` with THIS default), so a
# process-wide `set_lane_budget` / `REPRO_LANE_BUDGET` override governs
# join grids and fused scans with one knob.
DEFAULT_BLOCK_PAIRS = 1 << 14


def _resolve_block_pairs(block_pairs: Optional[int]) -> int:
    """The effective pair budget for a grid launch: explicit argument >
    shared lane-budget overrides > `DEFAULT_BLOCK_PAIRS`."""
    from repro.kernels import ops as KO
    return KO.resolve_lane_budget(block_pairs, default=DEFAULT_BLOCK_PAIRS)


@dataclasses.dataclass
class JoinStats:
    """What the join actually did — benchmarks and tests assert on this.

    Compare counts split by phase so nested-loop and sort-merge are
    directly comparable: `join_compares` is the strategy's own work,
    `left`/`right` hold the per-side filter stats (same launches a
    single-table plan would make).
    """
    strategy: str = ""
    eval_calls: int = 0            # batched Eval launches (grid tiles etc.)
    pair_compares: int = 0         # nested-loop grid lanes (padded N_l·N_r)
    build_compares: int = 0        # on-the-fly sort-merge index builds
    merge_compares: int = 0        # sorted-run merge network stages
    adjacency_compares: int = 0    # equal-class detection lanes
    verify_compares: int = 0       # ε-band candidate verification lanes
    shards: Tuple[int, int] = (1, 1)
    left: X.ExecStats = dataclasses.field(default_factory=X.ExecStats)
    right: X.ExecStats = dataclasses.field(default_factory=X.ExecStats)

    @property
    def join_compares(self) -> int:
        """All compare lanes the matching phase itself spent (excludes
        side filters and index builds — the amortized/one-time parts)."""
        return (self.pair_compares + self.merge_compares
                + self.adjacency_compares + self.verify_compares)


@dataclasses.dataclass
class JoinResult:
    """Matched row-id pairs + projected ciphertexts.

    `pairs` is [P, 2] (left_row_id, right_row_id), lexicographically
    sorted — canonical across strategies and shard counts.  `columns`
    carries the sides' `select` projections gathered at the pair rows,
    keyed "left.<col>" / "right.<col>" (still encrypted).
    """
    pairs: np.ndarray
    left_mask: np.ndarray                    # [n_l] post-filter row mask
    right_mask: np.ndarray                   # [n_r] post-filter row mask
    columns: Dict[str, Ciphertext]
    stats: JoinStats

    def __len__(self) -> int:
        return int(self.pairs.shape[0])

    @property
    def left_row_ids(self) -> np.ndarray:
        """Left-side row id of each matched pair (with repetition)."""
        return self.pairs[:, 0]

    @property
    def right_row_ids(self) -> np.ndarray:
        """Right-side row id of each matched pair (with repetition)."""
        return self.pairs[:, 1]


def join_tau(ks: KeySet, join: P.Join) -> int:
    """The decode threshold the join's equality resolves to (profile τ
    or ε-derived via `ckks.eps_to_tau`) — same contract as the filter
    stage's per-atom thresholds."""
    return C.resolve_tau(ks, join.eps)


def needs_verify(ks: KeySet, join: P.Join) -> bool:
    """Sort-merge candidate classes need a per-pair verification Eval
    whenever equality is a band (explicit ε, or CKKS native tolerance):
    band equality is not transitive, so adjacency chaining can overclaim.
    Exact BFV equality IS transitive — classes are exact, skip the pass."""
    return join.eps is not None or ks.params.profile.scheme == "ckks"


# ---------------------------------------------------------------------------
# nested-loop: tiled batched pair-grid Eval
# ---------------------------------------------------------------------------

def _grid_tile(block_pairs: int, n_left: int, n_right: int) -> int:
    """Left-rows-per-tile: the largest power of two with T·N_r within the
    pair budget (clamped to [1, N_l]; N_l is a power of two, so T always
    divides it — every tile launch has the same static shape)."""
    t = max(1, block_pairs // max(1, n_right))
    t = 1 << (t.bit_length() - 1)
    return min(t, n_left)


def pair_eval_values(ks: KeySet, left_ct: Ciphertext, right_ct: Ciphertext,
                     *, engine: str = "jnp",
                     block_pairs: Optional[int] = None,
                     stats: Optional[JoinStats] = None) -> np.ndarray:
    """RAW eval values for every (left row, right row) pair: [L, R] int64.

    Tiled: left rows chunk into power-of-two blocks of T rows, each tile
    ONE batched Eval over the [T, R] broadcast grid (the fused-scan
    `[A, N]` layout with left rows as the atom dim).  `block_pairs=None`
    resolves through the shared lane-budget policy (see
    `DEFAULT_BLOCK_PAIRS`).  Thresholds are deliberately NOT applied —
    callers decode with the join's own τ host-side, so ε-band joins
    share these launches (the `fused_eval` contract, extended to row
    pairs).
    """
    block_pairs = _resolve_block_pairs(block_pairs)
    L = int(left_ct.c0.shape[0])
    R = int(right_ct.c0.shape[0])
    T = _grid_tile(block_pairs, L, R)
    use_kernel = X._use_kernel(engine)
    out = np.empty((L, R), dtype=np.int64)
    b = Ciphertext(right_ct.c0[None, :], right_ct.c1[None, :])   # [1, R, ...]
    with obs.span("join.pair_grid", left=L, right=R, tile=T) as sp:
        for lo in range(0, L, T):
            a = Ciphertext(left_ct.c0[lo:lo + T, None],
                           left_ct.c1[lo:lo + T, None])          # [T, 1, ...]
            obs.jit_launch("join.pair_grid", a.c0, b.c0)
            obs.count("eval.launches")
            obs.count("eval.tiles")
            obs.count("eval.lanes", min(T, L - lo) * R)
            if use_kernel:
                from repro.kernels import ops as KO
                vals = sp.sync(KO.broadcast_eval_values(ks, a, b))
            else:
                vals = sp.sync(X.jitted_eval(ks)(a, b))          # [T, R]
            out[lo:lo + T] = np.asarray(vals)
            if stats is not None:
                stats.eval_calls += 1
    if stats is not None:
        stats.pair_compares += L * R
    return out


def pairs_from_grid(vals: np.ndarray, tau: int, left_mask: np.ndarray,
                    right_mask: np.ndarray) -> np.ndarray:
    """Raw pair grid -> [P, 2] matched (left, right) row ids.

    |value| < τ is the equality decode; the per-side masks (validity ∧
    filters) gate pad rows and filtered-out rows host-side — pad rows
    are real encryptions of 0, so they MUST be masked, never trusted to
    mismatch."""
    grid = np.abs(vals) < tau
    grid &= left_mask[:, None] & right_mask[None, :]
    return np.argwhere(grid)          # argwhere is already lexsorted


# ---------------------------------------------------------------------------
# sort-merge: run merge + adjacency classes (+ ε verification)
# ---------------------------------------------------------------------------

def merge_runs_to_pairs(ks: KeySet, runs: List[Tuple[Ciphertext, np.ndarray]],
                        n_left: int, tau: int, *, verify: bool,
                        gather_left: Callable[[np.ndarray], Ciphertext],
                        gather_right: Callable[[np.ndarray], Ciphertext],
                        left_mask: np.ndarray, right_mask: np.ndarray,
                        stats: JoinStats) -> np.ndarray:
    """Sorted runs -> matched pairs (the shared sort-merge back half).

    `runs` are ascending (Ciphertext, id-array) runs whose ids encode
    the side: left row l is id l, right row r is id n_left + r (the
    sharded executor passes one run per shard per side).  The runs pad
    to one power-of-two block and merge through
    `merge.merge_sorted_runs` — log₂(#runs) rounds, every stage one
    batched Eval — then ONE adjacency Eval splits the merged run into
    equal-key classes under the join's τ.  Cross-side pairs inside a
    class are candidates; masks filter them, and `verify` re-checks each
    survivor with a batched per-pair Eval (required for band equality,
    where chaining may connect keys farther than ε apart).
    """
    from repro.db.shard import merge as M
    cmp = X.jitted_comparator(ks)
    block = C.next_pow2(max(int(ids.shape[0]) for _, ids in runs))
    num_blocks = C.next_pow2(len(runs))
    ct, ids = M.pad_shard_blocks(ks, runs, block=block,
                                 pad_value=ks.params.max_operand // 2,
                                 num_blocks=num_blocks)
    c0, c1, gid = ct.c0, ct.c1, jnp.asarray(ids)
    if num_blocks > 1:
        c0, c1, gid, n_merge = M.merge_sorted_runs(ks, cmp, c0, c1, gid,
                                                   run=block)
        stats.merge_compares += n_merge
    gid = np.asarray(gid)
    keep = np.nonzero(gid >= 0)[0]            # strip sentinels BY ID
    mids = gid[keep]
    m = int(mids.shape[0])
    if m < 2:
        return np.zeros((0, 2), dtype=np.int64)
    mc0, mc1 = c0[keep], c1[keep]
    # ONE batched adjacency Eval: consecutive merged rows equal under τ?
    with obs.span("join.adjacency", lanes=m - 1) as sp:
        obs.jit_launch("join.adjacency", mc0[:-1])
        obs.count("eval.launches")
        obs.count("eval.lanes", m - 1)
        v = np.asarray(sp.sync(X.jitted_eval(ks)(
            Ciphertext(mc0[:-1], mc1[:-1]), Ciphertext(mc0[1:], mc1[1:]))))
    stats.adjacency_compares += m - 1
    stats.eval_calls += 1
    eq_adj = np.abs(v) < tau
    # equal-key classes: split where adjacency breaks
    breaks = np.nonzero(~eq_adj)[0] + 1
    cand: List[np.ndarray] = []
    for members in np.split(mids, breaks):
        l = members[members < n_left]
        r = members[members >= n_left] - n_left
        l = l[left_mask[l]]
        r = r[right_mask[r]]
        if l.size and r.size:
            li, ri = np.meshgrid(l, r, indexing="ij")
            cand.append(np.stack([li.ravel(), ri.ravel()], axis=1))
    if not cand:
        return np.zeros((0, 2), dtype=np.int64)
    pairs = np.concatenate(cand)
    if verify and len(pairs):
        # band equality: one batched Eval over the candidate pairs, padded
        # to a power of two so repeated joins reuse the compiled shape
        n_cand = len(pairs)
        n_pad = C.next_pow2(n_cand)
        sel = np.concatenate([np.arange(n_cand),
                              np.zeros(n_pad - n_cand, np.int64)])
        with obs.span("join.verify", candidates=n_cand, lanes=n_pad) as sp:
            lct = gather_left(pairs[sel, 0])
            rct = gather_right(pairs[sel, 1])
            obs.jit_launch("join.verify", lct.c0)
            obs.count("eval.launches")
            obs.count("eval.lanes", n_pad)
            vv = np.asarray(sp.sync(X.jitted_eval(ks)(lct, rct)))[:n_cand]
        stats.verify_compares += n_pad
        stats.eval_calls += 1
        pairs = pairs[np.abs(vv) < tau]
    order = np.lexsort((pairs[:, 1], pairs[:, 0]))
    return pairs[order]


def _side_mask(ks: KeySet, table: Table, plan: Optional[P.CompiledPlan], *,
               indexes: Optional[Dict[str, SortedIndex]], engine: str,
               stats: X.ExecStats,
               leaf_masks: Optional[List[np.ndarray]] = None) -> np.ndarray:
    """Resolve one join side to its [n_padded] row mask (filters + any
    order/top-k/limit stage, through the single-table executor helpers).

    `leaf_masks` short-circuits leaf resolution — the batched
    QueryServer passes masks whose leaves already rode its shared
    launches, so a join side never pays a second scan.

    A side with a PENDING DELTA RUN is refused: the pair grids and
    sort-merge runs below address rows by base slot, so compact first
    (`repro.db.delta.compact` — joins resume once the delta folds).
    Tombstoned rows need no such step; they just drop out of the side
    mask here (`alive`)."""
    if table.has_delta:
        raise ValueError(
            f"table {table.name!r} has {table.n_delta} uncompacted delta "
            "rows — joins address base slots; run repro.db.delta.compact "
            "first")
    if plan is None:
        mask = table.valid.copy()
        mask[:table.n_rows] &= table.alive
        return mask
    if leaf_masks is None:
        leaf_masks = X.filter_masks(ks, table, plan, indexes=indexes,
                                    engine=engine, stats=stats)
    mask = X.combine_tree(plan.tree, leaf_masks, table.n_padded)
    mask &= table.valid
    mask[:table.n_rows] &= table.alive
    q = plan.query
    if q.top_k is not None or q.order_by is not None or q.limit is not None:
        row_ids = X.order_rows(ks, table, q, np.nonzero(mask)[0], stats)
        mask = rows_to_mask(row_ids, table.n_padded)
    return mask


def _sorted_run(ks: KeySet, table: Table, column: str,
                index: Optional[SortedIndex],
                stats: JoinStats) -> Tuple[Ciphertext, np.ndarray]:
    """The side's ascending (ciphertext run, row-id array) — reused from
    its SortedIndex when available, built once (cost attributed) when not."""
    if index is None:
        index = SortedIndex.build(ks, table, column)
        stats.build_compares += index.build_compares
    return index.sorted_run()


def resolve_strategy(strategy: str, has_left_idx: bool,
                     has_right_idx: bool) -> str:
    """"auto" -> sort-merge iff both join keys are indexed (their sorted
    runs come for free), else nested-loop."""
    if strategy == "auto":
        return "sort_merge" if (has_left_idx and has_right_idx) else "nested"
    if strategy in ("nested", "sort_merge"):
        return strategy
    raise ValueError(
        f"unknown join strategy {strategy!r} (auto|nested|sort_merge)")


def _project(join: P.CompiledJoin, gather_left, gather_right,
             pairs: np.ndarray) -> Dict[str, Ciphertext]:
    """Gather each side's `select` columns at the matched pair rows."""
    columns: Dict[str, Ciphertext] = {}
    for plan, gather, side, col_ids in (
            (join.left_plan, gather_left, "left", pairs[:, 0]),
            (join.right_plan, gather_right, "right", pairs[:, 1])):
        if plan is None:
            continue
        for c in plan.query.select:
            columns[f"{side}.{c}"] = gather(c, col_ids)
    return columns


def execute_join(ks: KeySet, left, right, join: P.Join, *,
                 strategy: str = "auto",
                 left_indexes: Optional[Dict[str, SortedIndex]] = None,
                 right_indexes: Optional[Dict[str, SortedIndex]] = None,
                 engine: str = "jnp",
                 block_pairs: Optional[int] = None) -> JoinResult:
    """Run a `Join` between two encrypted tables.

    Accepts `Table`s or `ShardedTable`s — any sharded side dispatches to
    the cross-shard executor (`db.shard.join.execute_join_sharded`, a
    plain-`Table` other side is wrapped as a 1-shard table reusing its
    ciphertext rows), so call sites stay placement-agnostic.  `indexes`
    per side serve double duty: filter leaves resolve through them
    (binary search instead of scans) and sort-merge reuses the join-key
    index's sorted run outright.
    """
    import sys
    shard_mod = sys.modules.get("repro.db.shard.table")
    if shard_mod is not None and (isinstance(left, shard_mod.ShardedTable)
                                  or isinstance(right, shard_mod.ShardedTable)):
        from repro.db.shard.join import execute_join_sharded
        return execute_join_sharded(ks, left, right, join,
                                    strategy=strategy,
                                    left_indexes=left_indexes,
                                    right_indexes=right_indexes,
                                    engine=engine, block_pairs=block_pairs)
    cj = P.compile_join(join)
    lcol, rcol = cj.on_columns
    left_indexes = left_indexes or {}
    right_indexes = right_indexes or {}
    stats = JoinStats()
    stats.strategy = resolve_strategy(strategy, lcol in left_indexes,
                                      rcol in right_indexes)
    lmask = _side_mask(ks, left, cj.left_plan, indexes=left_indexes,
                       engine=engine, stats=stats.left)
    rmask = _side_mask(ks, right, cj.right_plan, indexes=right_indexes,
                       engine=engine, stats=stats.right)
    tau = join_tau(ks, join)
    if stats.strategy == "nested":
        vals = pair_eval_values(ks, left.column(lcol), right.column(rcol),
                                engine=engine, block_pairs=block_pairs,
                                stats=stats)
        pairs = pairs_from_grid(vals, tau, lmask, rmask)
    else:
        lrun_ct, lrun_ids = _sorted_run(ks, left, lcol,
                                        left_indexes.get(lcol), stats)
        rrun_ct, rrun_ids = _sorted_run(ks, right, rcol,
                                        right_indexes.get(rcol), stats)
        pairs = merge_runs_to_pairs(
            ks, [(lrun_ct, lrun_ids), (rrun_ct, rrun_ids + left.n_padded)],
            left.n_padded, tau, verify=needs_verify(ks, join),
            gather_left=lambda rows: left.gather(lcol, rows),
            gather_right=lambda rows: right.gather(rcol, rows),
            left_mask=lmask, right_mask=rmask, stats=stats)
    columns = _project(cj, left.gather, right.gather, pairs)
    return JoinResult(pairs=pairs, left_mask=lmask[:left.n_rows],
                      right_mask=rmask[:right.n_rows],
                      columns=columns, stats=stats)
