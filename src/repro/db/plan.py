"""Logical query plan IR for the `repro.db` encrypted query engine.

A query against an encrypted `Table` is a small tree of predicate nodes
over named columns plus optional ordering / truncation stages:

    predicates : Range(col, ct_lo, ct_hi[, eps]) | Eq(col, ct_value[, eps])
                 And(...) | Or(...) | Not(p)
    stages     : OrderBy(col, descending) | TopK(col, k) | Limit(count)

Float (CKKS) columns carry an optional per-predicate tolerance `eps`
(plaintext units): `Eq(col, v, eps)` is the ε-band |col - v| <= ε rather
than exact match, and `Range` bounds become ε-inclusive.  The ε rides
the IR down to the executor's fused eval launch, where it resolves to a
per-atom decode threshold (`ckks.eps_to_tau`) applied host-side on the
shared raw eval values — so mixed-ε plans still fuse into ONE launch.
`eps=None` keeps the profile's native semantics (exact on BFV,
`ckks.equality_tolerance` precision on CKKS).

Predicate *constants* are client-encrypted `Ciphertext` trapdoors — the
server combines HADES comparison outcomes but never sees a plaintext
bound.  `compile_plan` lowers a `Query` to a `CompiledPlan`: the deduped
list of comparison leaves plus a boolean combination tree over leaf
indices.  The executor then resolves every leaf either through a
`SortedIndex` (O(log n) compares) or through one fused linear scan — all
scan comparisons of a plan stage ride in a single batched `eval_value`
call (one XLA program per stage, Mazzone et al.'s batched-comparison
lesson applied to query plans).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.encrypt import Ciphertext


class Predicate:
    """Base class for filter-tree nodes."""

    def __and__(self, other: "Predicate") -> "And":
        return And(self, other)

    def __or__(self, other: "Predicate") -> "Or":
        return Or(self, other)

    def __invert__(self) -> "Not":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class Range(Predicate):
    """lo <= column <= hi (both bounds encrypted, inclusive).  `eps`
    makes the bounds ε-inclusive on float columns (rows within ε of a
    bound count as inside)."""
    column: str
    lo: Ciphertext
    hi: Ciphertext
    eps: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Eq(Predicate):
    """column == value (encrypted; requires EncBasic operands — FAE
    deliberately obfuscates equality, Alg. 3).  `eps` turns exact match
    into the ε-band |column - value| <= ε (the equality semantics float
    CKKS columns need; `eps=None` uses the profile's native τ)."""
    column: str
    value: Ciphertext
    eps: Optional[float] = None


class And(Predicate):
    def __init__(self, *children: Predicate):
        self.children: Tuple[Predicate, ...] = tuple(children)

    def __repr__(self) -> str:
        return f"And{self.children!r}"


class Or(Predicate):
    def __init__(self, *children: Predicate):
        self.children: Tuple[Predicate, ...] = tuple(children)

    def __repr__(self) -> str:
        return f"Or{self.children!r}"


@dataclasses.dataclass(frozen=True)
class Not(Predicate):
    child: Predicate


@dataclasses.dataclass(frozen=True)
class OrderBy:
    column: str
    descending: bool = False


@dataclasses.dataclass(frozen=True)
class TopK:
    column: str
    k: int


@dataclasses.dataclass(frozen=True)
class Limit:
    count: int


@dataclasses.dataclass(frozen=True)
class Query:
    """A complete logical plan: filter -> order/top-k -> limit -> project.

    `select` names the columns whose ciphertexts the result should carry
    (row ids are always returned; gathering ciphertexts is optional).
    """
    where: Optional[Predicate] = None
    order_by: Optional[OrderBy] = None
    top_k: Optional[TopK] = None
    limit: Optional[Union[Limit, int]] = None
    select: Tuple[str, ...] = ()

    @property
    def limit_count(self) -> Optional[int]:
        if self.limit is None:
            return None
        return self.limit.count if isinstance(self.limit, Limit) else int(self.limit)


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Atom:
    """One scan comparison: satisfied iff compare(column_row, value) op 0.

    `eps` is the predicate's tolerance (plaintext units) — the executor
    resolves it to this atom's decode threshold; None = profile default.
    """
    column: str
    op: str                    # ">=", "<=", "=="
    value: Ciphertext
    eps: Optional[float] = None


@dataclasses.dataclass
class CompiledPlan:
    """Lowered plan: deduped comparison leaves + boolean tree over them.

    tree grammar: ("leaf", i) | ("and", [t..]) | ("or", [t..]) | ("not", t)
    leaves[i] is a Range or Eq node.  `None` tree = select-all.
    """
    query: Query
    leaves: list
    tree: Optional[tuple]

    @property
    def num_leaves(self) -> int:
        return len(self.leaves)

    def scan_atoms(self, leaf_idx: int) -> Tuple[Atom, ...]:
        """The linear-scan comparisons leaf `leaf_idx` lowers to."""
        leaf = self.leaves[leaf_idx]
        if isinstance(leaf, Range):
            return (Atom(leaf.column, ">=", leaf.lo, leaf.eps),
                    Atom(leaf.column, "<=", leaf.hi, leaf.eps))
        return (Atom(leaf.column, "==", leaf.value, leaf.eps),)


def _leaf_key(leaf: Predicate) -> tuple:
    """Structural identity for dedup: same column + same trapdoor arrays
    + same tolerance (different ε = different predicate)."""
    if isinstance(leaf, Range):
        return ("range", leaf.column, id(leaf.lo.c0), id(leaf.hi.c0),
                leaf.eps)
    return ("eq", leaf.column, id(leaf.value.c0), leaf.eps)


def compile_plan(query: Union[Query, Predicate]) -> CompiledPlan:
    """Lower a Query (or bare predicate) to a CompiledPlan.

    Duplicate leaves (same column, same trapdoor ciphertexts) collapse to
    one comparison — e.g. Or(And(Range(a), Eq(b)), And(Range(a), Eq(c)))
    evaluates Range(a) once.
    """
    if isinstance(query, Predicate):
        query = Query(where=query)
    leaves: list = []
    seen: dict = {}

    def walk(p: Predicate) -> tuple:
        if isinstance(p, (Range, Eq)):
            key = _leaf_key(p)
            if key not in seen:
                seen[key] = len(leaves)
                leaves.append(p)
            return ("leaf", seen[key])
        if isinstance(p, And):
            return ("and", [walk(c) for c in p.children])
        if isinstance(p, Or):
            return ("or", [walk(c) for c in p.children])
        if isinstance(p, Not):
            return ("not", walk(p.child))
        raise TypeError(f"unknown predicate node: {p!r}")

    tree = walk(query.where) if query.where is not None else None
    return CompiledPlan(query=query, leaves=leaves, tree=tree)
