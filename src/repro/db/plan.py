"""Logical query plan IR for the `repro.db` encrypted query engine.

A query against an encrypted `Table` is a small tree of predicate nodes
over named columns plus optional ordering / truncation stages:

    predicates : Range(col, ct_lo, ct_hi[, eps]) | Eq(col, ct_value[, eps])
                 And(...) | Or(...) | Not(p)
    stages     : OrderBy(col, descending) | TopK(col, k) | Limit(count)
    two-table  : Join(left, right, on[, kind, eps]) — the engine's only
                 multi-table node; see `Join` and `compile_join`

Float (CKKS) columns carry an optional per-predicate tolerance `eps`
(plaintext units): `Eq(col, v, eps)` is the ε-band |col - v| <= ε rather
than exact match, and `Range` bounds become ε-inclusive.  The ε rides
the IR down to the executor's fused eval launch, where it resolves to a
per-atom decode threshold (`ckks.eps_to_tau`) applied host-side on the
shared raw eval values — so mixed-ε plans still fuse into ONE launch.
`eps=None` keeps the profile's native semantics (exact on BFV,
`ckks.equality_tolerance` precision on CKKS).

Predicate *constants* are client-encrypted `Ciphertext` trapdoors — the
server combines HADES comparison outcomes but never sees a plaintext
bound.  `compile_plan` lowers a `Query` to a `CompiledPlan`: the deduped
list of comparison leaves plus a boolean combination tree over leaf
indices.  The executor then resolves every leaf either through a
`SortedIndex` (O(log n) compares) or through one fused linear scan — all
scan comparisons of a plan stage ride in a single batched `eval_value`
call (one XLA program per stage, Mazzone et al.'s batched-comparison
lesson applied to query plans).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.core.encrypt import Ciphertext


class Predicate:
    """Base class for filter-tree nodes."""

    def __and__(self, other: "Predicate") -> "And":
        return And(self, other)

    def __or__(self, other: "Predicate") -> "Or":
        return Or(self, other)

    def __invert__(self) -> "Not":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class Range(Predicate):
    """lo <= column <= hi (both bounds encrypted, inclusive).  `eps`
    makes the bounds ε-inclusive on float columns (rows within ε of a
    bound count as inside).

    Compare cost: lowers to 2 scan atoms (`>= lo`, `<= hi`), i.e. 2·n
    Eval lanes in the fused linear scan, or ~2·log2 n binary-search
    probes when the column has a `SortedIndex` (2 boundary lanes riding
    one batched search)."""
    column: str
    lo: Ciphertext
    hi: Ciphertext
    eps: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Eq(Predicate):
    """column == value (encrypted; requires EncBasic operands — FAE
    deliberately obfuscates equality, Alg. 3).  `eps` turns exact match
    into the ε-band |column - value| <= ε (the equality semantics float
    CKKS columns need; `eps=None` uses the profile's native τ).

    Compare cost: 1 scan atom — n Eval lanes in the fused linear scan —
    or ~2·log2 n probes through a `SortedIndex` (the band's two
    boundaries resolve as 2 lanes of one batched search, for exact and
    ε-band alike)."""
    column: str
    value: Ciphertext
    eps: Optional[float] = None


class And(Predicate):
    """All children hold.  Free at the compare level: children's leaf
    masks AND host-side on trapdoor outcomes (0 extra Eval lanes)."""

    def __init__(self, *children: Predicate):
        self.children: Tuple[Predicate, ...] = tuple(children)

    def __repr__(self) -> str:
        return f"And{self.children!r}"


class Or(Predicate):
    """Any child holds.  Free at the compare level (host-side mask OR —
    0 extra Eval lanes)."""

    def __init__(self, *children: Predicate):
        self.children: Tuple[Predicate, ...] = tuple(children)

    def __repr__(self) -> str:
        return f"Or{self.children!r}"


@dataclasses.dataclass(frozen=True)
class Not(Predicate):
    """Child does not hold.  Free at the compare level (host-side mask
    complement over the valid rows — 0 extra Eval lanes)."""
    child: Predicate


@dataclasses.dataclass(frozen=True)
class OrderBy:
    """Sort matched rows by `column`.  Cost: one full bitonic network
    over the m matched rows — `bitonic_compare_count(m)` = O(m log² m)
    compare-exchanges, each network stage ONE batched Eval."""
    column: str
    descending: bool = False


@dataclasses.dataclass(frozen=True)
class TopK:
    """Largest k matched rows by `column`, descending.  Cost: partial
    bitonic tournament, O(m log² kp) compares (kp = next_pow2(k)) over
    the m matched rows — every stage one batched Eval."""
    column: str
    k: int


@dataclasses.dataclass(frozen=True)
class Limit:
    """Truncate to the first `count` row ids.  Host-side slice —
    0 Eval lanes."""
    count: int


@dataclasses.dataclass(frozen=True)
class Query:
    """A complete logical plan: filter -> order/top-k -> limit -> project.

    `select` names the columns whose ciphertexts the result should carry
    (row ids are always returned; gathering ciphertexts is optional).
    """
    where: Optional[Predicate] = None
    order_by: Optional[OrderBy] = None
    top_k: Optional[TopK] = None
    limit: Optional[Union[Limit, int]] = None
    select: Tuple[str, ...] = ()

    @property
    def limit_count(self) -> Optional[int]:
        """The row cap as an int (accepts Limit or bare int; None = no cap)."""
        if self.limit is None:
            return None
        return self.limit.count if isinstance(self.limit, Limit) else int(self.limit)


@dataclasses.dataclass(frozen=True)
class Join:
    """Two-table equi-join: rows (l, r) with left_col(l) == right_col(r).

    The engine's first multi-table plan node.  `left` / `right` are
    optional single-table sub-plans (a `Query`, a bare `Predicate`, or
    None = all rows) that filter each side BEFORE the join; their
    `select` columns become the joined result's projected columns
    (prefixed "left." / "right.").  `on` names the join key: one column
    name shared by both tables, or a `(left_column, right_column)` pair.

    `eps` widens equality to the ε-band |left_col - right_col| <= ε
    (plaintext units) — the float-key join semantics CKKS columns need;
    `eps=None` keeps the profile's native τ (exact on BFV).  As with
    filter predicates, ε resolves to a host-side decode threshold on the
    shared raw-eval launches, so mixed-ε joins share compiled programs.

    Compare cost (see `db.join` for the execution strategies):

      * nested-loop: ONE tiled batched Eval over the full padded
        N_l × N_r row-pair grid — exact, index-free, O(n_l·n_r) lanes.
      * sort-merge:  two sorted runs (reused from `SortedIndex`es, or
        built on the fly) merged by the log-depth half-cleaner network
        plus one adjacency Eval — O((n_l+n_r)·log(n_l+n_r)) compares.

    `kind` currently must be "eq" (the HADES comparison plane also
    supports ordering, so band/θ-joins are a natural follow-on).
    """
    left: Optional[Union["Query", Predicate]]
    right: Optional[Union["Query", Predicate]]
    on: Union[str, Tuple[str, str]]
    kind: str = "eq"
    eps: Optional[float] = None

    @property
    def on_columns(self) -> Tuple[str, str]:
        """Normalized (left_column, right_column) join-key pair."""
        if isinstance(self.on, str):
            return (self.on, self.on)
        lcol, rcol = self.on
        return (str(lcol), str(rcol))


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Atom:
    """One scan comparison: satisfied iff compare(column_row, value) op 0.

    `eps` is the predicate's tolerance (plaintext units) — the executor
    resolves it to this atom's decode threshold; None = profile default.
    """
    column: str
    op: str                    # ">=", "<=", "=="
    value: Ciphertext
    eps: Optional[float] = None


@dataclasses.dataclass
class CompiledPlan:
    """Lowered plan: deduped comparison leaves + boolean tree over them.

    tree grammar: ("leaf", i) | ("and", [t..]) | ("or", [t..]) | ("not", t)
    leaves[i] is a Range or Eq node.  `None` tree = select-all.
    """
    query: Query
    leaves: list
    tree: Optional[tuple]

    @property
    def num_leaves(self) -> int:
        """Deduped comparison-leaf count (the filter stage's lane budget:
        each leaf is 1 Eq or 2 Range atoms in the fused scan)."""
        return len(self.leaves)

    def scan_atoms(self, leaf_idx: int) -> Tuple[Atom, ...]:
        """The linear-scan comparisons leaf `leaf_idx` lowers to."""
        leaf = self.leaves[leaf_idx]
        if isinstance(leaf, Range):
            return (Atom(leaf.column, ">=", leaf.lo, leaf.eps),
                    Atom(leaf.column, "<=", leaf.hi, leaf.eps))
        return (Atom(leaf.column, "==", leaf.value, leaf.eps),)


def _leaf_key(leaf: Predicate) -> tuple:
    """Structural identity for dedup: same column + same trapdoor arrays
    + same tolerance (different ε = different predicate)."""
    if isinstance(leaf, Range):
        return ("range", leaf.column, id(leaf.lo.c0), id(leaf.hi.c0),
                leaf.eps)
    return ("eq", leaf.column, id(leaf.value.c0), leaf.eps)


def compile_plan(query: Union[Query, Predicate]) -> CompiledPlan:
    """Lower a Query (or bare predicate) to a CompiledPlan.

    Duplicate leaves (same column, same trapdoor ciphertexts) collapse to
    one comparison — e.g. Or(And(Range(a), Eq(b)), And(Range(a), Eq(c)))
    evaluates Range(a) once.
    """
    if isinstance(query, Predicate):
        query = Query(where=query)
    leaves: list = []
    seen: dict = {}

    def walk(p: Predicate) -> tuple:
        if isinstance(p, (Range, Eq)):
            key = _leaf_key(p)
            if key not in seen:
                seen[key] = len(leaves)
                leaves.append(p)
            return ("leaf", seen[key])
        if isinstance(p, And):
            return ("and", [walk(c) for c in p.children])
        if isinstance(p, Or):
            return ("or", [walk(c) for c in p.children])
        if isinstance(p, Not):
            return ("not", walk(p.child))
        raise TypeError(f"unknown predicate node: {p!r}")

    tree = walk(query.where) if query.where is not None else None
    return CompiledPlan(query=query, leaves=leaves, tree=tree)


@dataclasses.dataclass
class CompiledJoin:
    """Lowered `Join`: per-side compiled filter plans + the key pair.

    `left_plan` / `right_plan` are `CompiledPlan`s (None = select-all
    side); their leaves resolve through the same index-or-fused-scan
    machinery as single-table plans — which is exactly how the batched
    QueryServer folds a join's side filters into its shared launches.
    """
    join: Join
    left_plan: Optional[CompiledPlan]
    right_plan: Optional[CompiledPlan]

    @property
    def on_columns(self) -> Tuple[str, str]:
        """Normalized (left_column, right_column) join-key pair."""
        return self.join.on_columns


def _side_plan(side) -> Optional[CompiledPlan]:
    """Compile one side of a Join (None / Predicate / Query)."""
    if side is None:
        return None
    if isinstance(side, (Query, Predicate)):
        return compile_plan(side)
    raise TypeError(f"join side must be Query/Predicate/None, got {side!r}")


def compile_join(join: Join) -> CompiledJoin:
    """Lower a `Join` to a CompiledJoin (validates `kind`)."""
    if not isinstance(join, Join):
        raise TypeError(f"cannot compile {join!r} as a join")
    if join.kind != "eq":
        raise ValueError(
            f"unsupported join kind {join.kind!r} (only 'eq' for now)")
    lcol, rcol = join.on_columns          # validates the `on` shape
    assert lcol and rcol
    return CompiledJoin(join=join, left_plan=_side_plan(join.left),
                        right_plan=_side_plan(join.right))
