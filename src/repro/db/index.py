"""HADES sorted index: build once, answer lookups in O(log n) compares.

The index is built server-side with `encrypted_sort` — trapdoor (Alg. 4)
comparisons only, the server never decrypts.  It stores the column's
ciphertext rows in sorted order plus the permutation back to original row
ids.  Lookups then run encrypted *binary search*: each probe is one
HADES compare against a sorted row, so a point lookup or range boundary
costs ceil(log2 n) compares instead of the linear scan's n.

All searches are lane-batched: `search` takes B (value, strictness)
lanes and resolves them together — every binary-search step is ONE
batched Eval over B probes (a range query is 2 lanes; the multi-query
server stacks 2K lanes for K clients).  The per-step compare is jitted
once per lane count, so repeated queries pay only dispatch.

Float (CKKS) columns: every lane can carry its own decode threshold
(`taus`) — the probe Eval returns raw values and the ε-aware three-way
decode happens host-side, so an ε-band Eq and an exact Range ride the
same batched probe launch.  An ε-band point lookup resolves the
boundaries of [v-ε, v+ε] directly: lower lane "first row with
col > v - ε", upper lane "first row with col > v + ε", both expressed
through the widened τ_ε on the SAME trapdoor ciphertext — the client
sends one encrypted v, never ε-shifted plaintexts.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compare as C
from repro.core.ckks import eps_to_tau
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db.table import Table, rows_to_mask


def _stack_cts(cts) -> Ciphertext:
    return Ciphertext(jnp.stack([ct.c0 for ct in cts]),
                      jnp.stack([ct.c1 for ct in cts]))


def eps_lane_taus(ks: KeySet, eps: Optional[float]) -> Optional[np.ndarray]:
    """The [lower, upper] boundary-lane decode thresholds an ε-band
    predicate resolves to (None = profile default) — one implementation
    for SortedIndex and the sharded fan-out index."""
    if eps is None:
        return None
    tau = eps_to_tau(ks.params, eps)
    return np.asarray([tau, tau], dtype=np.int64)


class SortedIndex:
    """Sorted ciphertext column + permutation, with encrypted binary search."""

    def __init__(self, column: str, sorted_ct: Ciphertext, perm: np.ndarray,
                 *, build_compares: int = 0):
        self.column = column
        self.sorted_ct = sorted_ct
        self.perm = np.asarray(perm)
        self.n_rows = int(self.perm.shape[0])
        self.build_compares = build_compares
        self.search_compares = 0               # cumulative probe count
        self.last_probe_counts = np.zeros(0, np.int64)  # per-lane, last call
        self._cmp: Optional[Callable] = None   # jitted raw probe Eval, lazy

    # -- construction ------------------------------------------------------

    @classmethod
    def build(cls, ks: KeySet, table: Table, column: str, *,
              comparator: Optional[Callable] = None) -> "SortedIndex":
        """Sort the column's valid rows once (server-side, O(n log^2 n)
        trapdoor compares); amortized over every subsequent lookup."""
        col = table.gather(column, np.arange(table.n_rows))
        if comparator is None:
            # jit once: every network stage reuses the same [pairs] shape
            jitted = jax.jit(lambda a, b: C.compare_fae(ks, a, b))
            comparator = lambda _ks, a, b: jitted(a, b)  # noqa: E731
        sorted_ct, perm = C.encrypted_sort(ks, col, comparator)
        return cls(column, sorted_ct, np.asarray(perm),
                   build_compares=C.bitonic_compare_count(table.n_rows))

    def sorted_run(self) -> tuple:
        """The index as an ascending (ciphertext run, row-id array) pair —
        the sort-merge join consumes this directly, so a join between two
        indexed columns pays ZERO extra sort compares (the build is
        already amortized across lookups)."""
        return self.sorted_ct, self.perm

    # -- search ------------------------------------------------------------

    def _eval(self, ks: KeySet) -> Callable:
        """Jitted raw probe Eval (jit specializes per lane shape).  The
        three-way decode happens host-side so each lane applies its own
        τ (profile default or ε-derived)."""
        if self._cmp is None:
            self._cmp = jax.jit(lambda a, b: C.eval_value(ks, a, b))
        return self._cmp

    def _lane_taus(self, ks: KeySet, n_lanes: int,
                   taus: Optional[np.ndarray]) -> np.ndarray:
        if taus is None:
            return np.full(n_lanes, ks.params.tau, dtype=np.int64)
        taus = np.asarray(taus, dtype=np.int64)
        assert taus.shape == (n_lanes,)
        return taus

    def search(self, ks: KeySet, values: Ciphertext, strict: np.ndarray,
               taus: Optional[np.ndarray] = None) -> np.ndarray:
        """Batched boundary search over B lanes.

        values: ciphertexts with leading batch dim B (EncBasic trapdoors).
        strict[i] False -> lower bound: first sorted pos with col >= v_i;
        strict[i] True  -> upper bound: first sorted pos with col >  v_i.
        taus[i] (optional) is lane i's decode threshold: with a widened
        τ_ε, "col >= v" means "col > v - ε" and "col > v" means
        "col > v + ε" — the ε-aware boundary semantics the ε-band
        predicates lower to.  Every iteration is ONE batched Eval over
        the B probe lanes.
        """
        strict = np.asarray(strict, bool)
        B = values.c0.shape[0]
        assert strict.shape == (B,)
        taus = self._lane_taus(ks, B, taus)
        ev = self._eval(ks)
        lo = np.zeros(B, np.int64)
        hi = np.full(B, self.n_rows, np.int64)
        probes = np.zeros(B, np.int64)
        with obs.span("index.search", column=self.column, lanes=B,
                      rows=self.n_rows) as sp:
            while np.any(lo < hi):
                active = lo < hi
                mid = (lo + hi) // 2
                probe = np.where(active, mid, 0)   # fixed shape; dead lanes
                rows = Ciphertext(self.sorted_ct.c0[probe],
                                  self.sorted_ct.c1[probe])
                obs.jit_launch("index.probe", rows.c0, values.c0)
                obs.count("eval.launches")
                obs.count("eval.lanes", B)
                v = np.asarray(ev(rows, values))              # [B] raw
                c = np.where(np.abs(v) < taus, 0, np.sign(v))  # per-lane τ
                probes += active
                go_left = np.where(strict, c > 0, c >= 0)
                hi = np.where(active & go_left, mid, hi)
                lo = np.where(active & ~go_left, mid + 1, lo)
            sp.set(probes=int(probes.sum()))
        obs.count("index.probes", int(probes.sum()))
        self.search_compares += int(probes.sum())
        self.last_probe_counts = probes            # per-lane attribution
        return lo

    def _eps_taus(self, ks: KeySet, eps: Optional[float]) -> Optional[np.ndarray]:
        return eps_lane_taus(ks, eps)

    def search_range(self, ks: KeySet, ct_lo: Ciphertext, ct_hi: Ciphertext,
                     *, eps: Optional[float] = None) -> np.ndarray:
        """Row ids with lo <= value <= hi — 2 lanes, ~2 log2 n compares.
        `eps` makes the bounds ε-inclusive (float columns)."""
        bounds = _stack_cts([ct_lo, ct_hi])
        l, r = self.search(ks, bounds, np.array([False, True]),
                           self._eps_taus(ks, eps))
        return self.perm[l:r]

    def point_lookup(self, ks: KeySet, ct_value: Ciphertext, *,
                     eps: Optional[float] = None) -> np.ndarray:
        """Row ids with value == v (duplicates included) — 2 lanes.
        `eps` widens to the band |value - v| <= ε (float columns)."""
        bounds = _stack_cts([ct_value, ct_value])
        l, r = self.search(ks, bounds, np.array([False, True]),
                           self._eps_taus(ks, eps))
        return self.perm[l:r]

    def mask_range(self, ks: KeySet, ct_lo: Ciphertext, ct_hi: Ciphertext,
                   n_padded: int, *, eps: Optional[float] = None) -> np.ndarray:
        """search_range as a [n_padded] bool row mask (executor plumbing)."""
        return rows_to_mask(self.search_range(ks, ct_lo, ct_hi, eps=eps),
                            n_padded)

    def mask_eq(self, ks: KeySet, ct_value: Ciphertext, n_padded: int, *,
                eps: Optional[float] = None) -> np.ndarray:
        """point_lookup as a [n_padded] bool row mask (executor plumbing)."""
        return rows_to_mask(self.point_lookup(ks, ct_value, eps=eps),
                            n_padded)

    def __repr__(self) -> str:
        return (f"SortedIndex({self.column!r}, rows={self.n_rows}, "
                f"build_compares={self.build_compares})")
