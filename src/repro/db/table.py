"""Encrypted column-store `Table` for the repro.db engine.

A table owns named `Ciphertext` columns over the same logical rows.  Rows
are padded to the next power of two at ingest (static shapes: every
downstream sort/merge network and fused scan compiles once per table
size), with a host-side validity mask excluding the pad rows from query
results.  The pad rows are real encryptions of 0 — the server cannot
distinguish them from data rows by inspection, only the table's public
row count reveals the split.

Encryption is batched: one `encrypt` call per column, regardless of row
count (the vectorized LPR path in core/encrypt.py).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encrypt as E
from repro.core.compare import next_pow2
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet


def rows_to_mask(rows, n_padded: int) -> np.ndarray:
    """Row-id list -> [n_padded] bool mask (shared by index + executor +
    server so mask construction has exactly one implementation)."""
    mask = np.zeros(n_padded, bool)
    mask[np.asarray(rows, dtype=np.int64)] = True
    return mask


def pad_rows_pow2(arr: np.ndarray, *, n_target: Optional[int] = None,
                  pad_value: float = 0) -> np.ndarray:
    """Pad a host column to a power-of-two row count — THE row-padding
    implementation shared by `Table` and `ShardedTable` ingest.

    `n_target` (default: `next_pow2(len(arr))`) lets a sharded table pad
    every shard to one common block size so the stacks align.  Geometry
    comes from the same `next_pow2` that sizes `encrypted_sort`'s
    ciphertext-level sentinel padding (`core.compare._pad_to_pow2`), so
    ingest padding and sort-network padding can never disagree about the
    padded shape; the pad VALUE here is 0 (excluded via the validity
    mask), while the sort networks pad with in-headroom sentinels.
    """
    arr = np.asarray(arr)
    n_rows = arr.shape[0]
    n_padded = next_pow2(n_rows) if n_target is None else int(n_target)
    if n_padded < n_rows or n_padded != next_pow2(n_padded):
        raise ValueError(
            f"n_target {n_padded} must be a power of two >= {n_rows}")
    is_float = np.issubdtype(arr.dtype, np.floating)
    padded = np.full((n_padded,), pad_value,
                     np.float64 if is_float else np.int64)
    padded[:n_rows] = arr
    return padded


class Table:
    """Named encrypted columns + row-count bookkeeping."""

    def __init__(self, name: str, columns: Dict[str, Ciphertext],
                 n_rows: int):
        if not columns:
            raise ValueError("table needs at least one column")
        shapes = {c: ct.c0.shape[0] for c, ct in columns.items()}
        n_padded = next(iter(shapes.values()))
        if any(v != n_padded for v in shapes.values()):
            raise ValueError(f"ragged columns: {shapes}")
        if n_padded & (n_padded - 1):
            raise ValueError(f"padded row count {n_padded} not a power of two")
        if not (0 < n_rows <= n_padded):
            raise ValueError(f"n_rows {n_rows} outside (0, {n_padded}]")
        self.name = name
        self.columns = dict(columns)
        self.n_rows = int(n_rows)

    # -- construction ------------------------------------------------------

    @classmethod
    def from_arrays(cls, ks: KeySet, name: str,
                    data: Dict[str, np.ndarray], key: jax.Array, *,
                    fae: bool = False,
                    n_padded: Optional[int] = None) -> "Table":
        """Encrypt host arrays into a padded column-store.

        data: {column: [n_rows] int (bfv) or float (ckks)}.  Under a
        CKKS profile every column is a float column (fixed-point encoded
        at Δ_enc; integer input is fine and stays exact within the
        profile's precision).  Under BFV, float input with fractional
        values is rejected — it would silently truncate; use a ckks
        profile for float columns.  `fae=True` uses perturbation-aware
        encryption (Alg. 3) — note this trades away exact
        Eq/point-lookup semantics by design.  `n_padded` overrides the
        default next-power-of-two target (sharded tables pad every
        shard to one common block size).
        """
        lengths = {c: len(v) for c, v in data.items()}
        n_rows = next(iter(lengths.values()))
        if any(v != n_rows for v in lengths.values()):
            raise ValueError(f"ragged input columns: {lengths}")
        enc = E.encrypt_fae if fae else E.encrypt
        is_float = ks.params.profile.scheme == "ckks"
        columns = {}
        for i, (cname, arr) in enumerate(data.items()):
            arr = np.asarray(arr)
            if (not is_float and np.issubdtype(arr.dtype, np.floating)
                    and not np.array_equal(arr, np.trunc(arr))):
                raise ValueError(
                    f"column {cname!r}: fractional float values under a "
                    f"{ks.params.profile.scheme} profile would truncate — "
                    "use a ckks profile for float columns")
            padded = pad_rows_pow2(
                arr.astype(np.float64 if is_float else np.int64),
                n_target=n_padded)
            columns[cname] = enc(ks, jnp.asarray(padded),
                                 jax.random.fold_in(key, i))
        return cls(name, columns, n_rows)

    # -- geometry ----------------------------------------------------------

    @property
    def n_padded(self) -> int:
        """Power-of-two padded row count (every column's leading dim)."""
        return next(iter(self.columns.values())).c0.shape[0]

    @property
    def valid(self) -> np.ndarray:
        """[n_padded] bool — True on data rows, False on pad rows."""
        return np.arange(self.n_padded) < self.n_rows

    @property
    def column_names(self) -> tuple:
        """Names of the encrypted columns."""
        return tuple(self.columns)

    def ciphertext_bytes(self) -> int:
        """Storage footprint of all encrypted columns."""
        return sum(ct.c0.nbytes + ct.c1.nbytes for ct in self.columns.values())

    # -- access ------------------------------------------------------------

    def column(self, name: str) -> Ciphertext:
        """The named column's stacked ciphertext rows."""
        return self.columns[name]

    def gather(self, name: str, rows: Iterable[int]) -> Ciphertext:
        """Ciphertext rows of `name` at host-side row indices."""
        idx = np.asarray(rows, dtype=np.int64)
        ct = self.columns[name]
        return Ciphertext(ct.c0[idx], ct.c1[idx])

    def decrypt_column(self, ks: KeySet, name: str, *,
                       include_padding: bool = False) -> np.ndarray:
        """Client-side helper (tests / verification only — needs sk)."""
        vals = np.asarray(E.decrypt(ks, self.columns[name]))
        return vals if include_padding else vals[:self.n_rows]

    def __repr__(self) -> str:
        return (f"Table({self.name!r}, rows={self.n_rows}"
                f" (padded {self.n_padded}), cols={list(self.columns)})")
