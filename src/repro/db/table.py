"""Encrypted column-store `Table` for the repro.db engine.

A table owns named `Ciphertext` columns over the same logical rows.  Rows
are padded to the next power of two at ingest (static shapes: every
downstream sort/merge network and fused scan compiles once per table
size), with a host-side validity mask excluding the pad rows from query
results.  The pad rows are real encryptions of 0 — the server cannot
distinguish them from data rows by inspection, only the table's public
row count reveals the split.

Encryption is batched: one `encrypt` call per column, regardless of row
count (the vectorized LPR path in core/encrypt.py).

WRITE PATH.  A table is mutable through `insert` / `update` / `delete`:

  * `insert` encrypts the new rows into a small DELTA RUN — a plain
    pow2-padded `Table` hanging off the base (`self.delta`).  Appending
    to an existing run concatenates ciphertext rows and re-pads; base
    rows are NEVER re-encrypted.  New rows take global ids past the end
    of the current id space, so ids are stable across later compaction.
  * `delete` records a host-side TOMBSTONE over global row ids (the
    comparison outcomes are host-visible anyway, so hiding liveness
    would not change the threat model); tombstoned rows stay encrypted
    in place and every read path masks them out.
  * `update` is tombstone + re-insert (the delta-store identity).

Readers answer over base ∪ delta: the SCAN VIEW (`scan_column`,
`slot_valid`, `slot_global_ids`) presents the base block and the delta
block as one concatenated slot space so a fused filter launch covers
both in a single raw-eval program.  `repro.db.delta.compact` folds the
delta run back into the base (and merges it into any `SortedIndex`
through the log-depth merge network) — see that module.
"""
from __future__ import annotations

import zlib
from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encrypt as E
from repro.core.compare import next_pow2
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet

# pad rows appended by ciphertext-level concat/re-pad (delta growth,
# compaction) encrypt 0 under keys folded from this seed — same
# public-key construction as `ShardedTable.from_table`'s 0x5AAD pads
_APPEND_PAD_SEED = 0xDE17A


def rows_to_mask(rows, n_padded: int) -> np.ndarray:
    """Row-id list -> [n_padded] bool mask (shared by index + executor +
    server so mask construction has exactly one implementation)."""
    mask = np.zeros(n_padded, bool)
    mask[np.asarray(rows, dtype=np.int64)] = True
    return mask


def column_key(key: jax.Array, cname: str) -> jax.Array:
    """Per-column encryption key: fold in crc32 of the column NAME, not
    its dict position — a delta run presenting the same columns in a
    different order must encrypt under the same per-column streams as
    the base ingest (same determinism rationale as dataset seeding)."""
    return jax.random.fold_in(key, zlib.crc32(cname.encode()))


def pad_rows_pow2(arr: np.ndarray, *, n_target: Optional[int] = None,
                  pad_value: float = 0) -> np.ndarray:
    """Pad a host column to a power-of-two row count — THE row-padding
    implementation shared by `Table` and `ShardedTable` ingest.

    `n_target` (default: `next_pow2(len(arr))`) lets a sharded table pad
    every shard to one common block size so the stacks align.  Geometry
    comes from the same `next_pow2` that sizes `encrypted_sort`'s
    ciphertext-level sentinel padding (`core.compare._pad_to_pow2`), so
    ingest padding and sort-network padding can never disagree about the
    padded shape; the pad VALUE here is 0 (excluded via the validity
    mask), while the sort networks pad with in-headroom sentinels.
    An EMPTY column pads to the minimum block of one slot
    (`next_pow2(0) == 1`) — empty tables are representable.
    """
    arr = np.asarray(arr)
    n_rows = arr.shape[0]
    n_padded = next_pow2(n_rows) if n_target is None else int(n_target)
    if n_padded < max(n_rows, 1) or n_padded != next_pow2(n_padded):
        raise ValueError(
            f"n_target {n_padded} must be a power of two >= {n_rows}")
    is_float = np.issubdtype(arr.dtype, np.floating)
    padded = np.full((n_padded,), pad_value,
                     np.float64 if is_float else np.int64)
    padded[:n_rows] = arr
    return padded


def concat_ct_rows(*cts: Ciphertext) -> Ciphertext:
    """Concatenate ciphertext row stacks along the leading (row) dim —
    the ciphertext-level append used by delta growth, compaction and the
    union scan view.  Pure slicing/stacking of existing encryptions."""
    return Ciphertext(jnp.concatenate([ct.c0 for ct in cts]),
                      jnp.concatenate([ct.c1 for ct in cts]))


def _zero_pad_rows(ks: KeySet, cname: str, n_pad: int,
                   salt: int) -> Ciphertext:
    """`n_pad` fresh public-key encryptions of 0 (append-path padding)."""
    key = jax.random.fold_in(column_key(jax.random.PRNGKey(_APPEND_PAD_SEED),
                                        cname), salt)
    return E.encrypt(ks, jnp.zeros(n_pad, jnp.int64), key)


class Table:
    """Named encrypted columns + row-count bookkeeping + delta-run state."""

    def __init__(self, name: str, columns: Dict[str, Ciphertext],
                 n_rows: int):
        if not columns:
            raise ValueError("table needs at least one column")
        shapes = {c: ct.c0.shape[0] for c, ct in columns.items()}
        n_padded = next(iter(shapes.values()))
        if any(v != n_padded for v in shapes.values()):
            raise ValueError(f"ragged columns: {shapes}")
        if n_padded < 1 or n_padded & (n_padded - 1):
            raise ValueError(f"padded row count {n_padded} not a power of two")
        # n_rows == 0 is legal: an empty table is one all-pad block (the
        # write path starts from `Table.empty` and freshly-compacted
        # delta runs are empty) — the invariant is 0 <= n_rows <= padded
        if not (0 <= n_rows <= n_padded):
            raise ValueError(f"n_rows {n_rows} outside [0, {n_padded}]")
        self.name = name
        self.columns = dict(columns)
        self.n_rows = int(n_rows)
        # -- write-path state (all host-side) --------------------------
        self.delta: Optional["Table"] = None     # pending insert run
        self._dead = np.zeros(self.n_rows, bool)  # tombstones, global ids
        self.version = 0                          # bumped per mutation
        self._delta_index_cache: Dict[str, tuple] = {}

    # -- construction ------------------------------------------------------

    @classmethod
    def from_arrays(cls, ks: KeySet, name: str,
                    data: Dict[str, np.ndarray], key: jax.Array, *,
                    fae: bool = False,
                    n_padded: Optional[int] = None) -> "Table":
        """Encrypt host arrays into a padded column-store.

        data: {column: [n_rows] int (bfv) or float (ckks)}.  Under a
        CKKS profile every column is a float column (fixed-point encoded
        at Δ_enc; integer input is fine and stays exact within the
        profile's precision).  Under BFV, float input with fractional
        values is rejected — it would silently truncate; use a ckks
        profile for float columns.  `fae=True` uses perturbation-aware
        encryption (Alg. 3) — note this trades away exact
        Eq/point-lookup semantics by design.  `n_padded` overrides the
        default next-power-of-two target (sharded tables pad every
        shard to one common block size).  Zero-length arrays build an
        empty table (one all-pad block); per-column keys fold in the
        column NAME (`column_key`), so ingest is insertion-order
        independent.
        """
        lengths = {c: len(v) for c, v in data.items()}
        n_rows = next(iter(lengths.values()))
        if any(v != n_rows for v in lengths.values()):
            raise ValueError(f"ragged input columns: {lengths}")
        enc = E.encrypt_fae if fae else E.encrypt
        is_float = ks.params.profile.scheme == "ckks"
        columns = {}
        for cname, arr in data.items():
            arr = np.asarray(arr)
            if (not is_float and np.issubdtype(arr.dtype, np.floating)
                    and not np.array_equal(arr, np.trunc(arr))):
                raise ValueError(
                    f"column {cname!r}: fractional float values under a "
                    f"{ks.params.profile.scheme} profile would truncate — "
                    "use a ckks profile for float columns")
            padded = pad_rows_pow2(
                arr.astype(np.float64 if is_float else np.int64),
                n_target=n_padded)
            columns[cname] = enc(ks, jnp.asarray(padded),
                                 column_key(key, cname))
        return cls(name, columns, n_rows)

    @classmethod
    def empty(cls, ks: KeySet, name: str, columns: Iterable[str],
              key: jax.Array) -> "Table":
        """A 0-row table over the named columns (one encrypted all-pad
        slot each) — the write path's starting point: `insert` grows it
        like any other table."""
        return cls.from_arrays(ks, name,
                               {c: np.zeros(0, np.int64) for c in columns},
                               key)

    # -- geometry ----------------------------------------------------------

    @property
    def n_padded(self) -> int:
        """Power-of-two padded row count of the BASE (every base
        column's leading dim; the delta run pads separately)."""
        return next(iter(self.columns.values())).c0.shape[0]

    @property
    def valid(self) -> np.ndarray:
        """[n_padded] bool — True on BASE data rows, False on pad rows
        (delta slots and tombstones are the scan view's concern:
        `slot_valid`)."""
        return np.arange(self.n_padded) < self.n_rows

    @property
    def column_names(self) -> tuple:
        """Names of the encrypted columns."""
        return tuple(self.columns)

    def ciphertext_bytes(self) -> int:
        """Storage footprint of all encrypted columns (base + delta)."""
        total = sum(ct.c0.nbytes + ct.c1.nbytes
                    for ct in self.columns.values())
        if self.delta is not None:
            total += self.delta.ciphertext_bytes()
        return total

    # -- write path --------------------------------------------------------

    @property
    def n_delta(self) -> int:
        """Rows currently pending in the delta run."""
        return 0 if self.delta is None else self.delta.n_rows

    @property
    def n_total(self) -> int:
        """Size of the global row-id space: base rows + delta rows
        (tombstoned rows included — ids are never reused)."""
        return self.n_rows + self.n_delta

    @property
    def has_delta(self) -> bool:
        """True while an uncompacted delta run holds pending inserts."""
        return self.n_delta > 0

    @property
    def alive(self) -> np.ndarray:
        """[n_total] bool — False exactly on tombstoned global ids."""
        return ~self._dead

    @property
    def is_mutated(self) -> bool:
        """True if any mutation is outstanding (delta rows or
        tombstones) — operators without union-read support (joins)
        check this and ask for a compaction first."""
        return self.has_delta or bool(self._dead.any())

    def insert(self, ks: KeySet, data: Dict[str, np.ndarray],
               key: jax.Array) -> np.ndarray:
        """Append new rows to the delta run; returns their global ids.

        One batched encrypt per column for the NEW rows only; growing an
        existing run concatenates ciphertext rows and re-pads to the
        next power of two — base rows are never touched, let alone
        re-encrypted.
        """
        if set(data) != set(self.columns):
            raise ValueError(
                f"insert columns {sorted(data)} != table columns "
                f"{sorted(self.columns)}")
        new = Table.from_arrays(ks, f"{self.name}.delta", data, key)
        start = self.n_total
        if new.n_rows == 0:
            return np.zeros(0, np.int64)
        if self.delta is None:
            self.delta = new
        else:
            self.delta = append_rows(ks, self.delta, new)
        self._dead = np.concatenate(
            [self._dead, np.zeros(new.n_rows, bool)])
        self._invalidate()
        return start + np.arange(new.n_rows, dtype=np.int64)

    def delete(self, rows) -> int:
        """Tombstone the given GLOBAL row ids (host-side mask; the
        ciphertext rows stay in place and every read path excludes
        them).  Returns the number of newly-dead rows."""
        idx = np.asarray(rows, np.int64)
        if idx.size and (idx.min() < 0 or idx.max() >= self.n_total):
            raise IndexError(
                f"row ids outside [0, {self.n_total}): {idx}")
        newly = int((~self._dead[idx]).sum())
        self._dead[idx] = True
        self._invalidate()
        return newly

    def update(self, ks: KeySet, rows, data: Dict[str, np.ndarray],
               key: jax.Array) -> np.ndarray:
        """Replace rows: tombstone `rows`, insert their new versions
        into the delta run (the delta-store update identity).  Returns
        the replacement rows' global ids."""
        self.delete(rows)
        return self.insert(ks, data, key)

    def _invalidate(self) -> None:
        self.version += 1
        self._delta_index_cache.clear()

    # -- scan view (base ∪ delta as one slot space) ------------------------

    @property
    def scan_width(self) -> int:
        """Width of the union scan: base block + delta block slots."""
        return self.n_padded + (0 if self.delta is None
                                else self.delta.n_padded)

    def scan_column(self, name: str) -> Ciphertext:
        """The named column over the UNION slot space — base block then
        delta block, concatenated ciphertext rows (what the fused filter
        launch scans, so base and delta ride ONE raw-eval program)."""
        ct = self.columns[name]
        if self.delta is None:
            return ct
        return concat_ct_rows(ct, self.delta.columns[name])

    @property
    def slot_global_ids(self) -> np.ndarray:
        """[scan_width] global row id per scan slot (-1 on pad slots).
        Base slot i -> id i; delta slot j -> id n_rows + j."""
        ids = np.full(self.scan_width, -1, np.int64)
        ids[:self.n_rows] = np.arange(self.n_rows)
        if self.delta is not None:
            d = self.delta.n_rows
            ids[self.n_padded:self.n_padded + d] = self.n_rows + np.arange(d)
        return ids

    @property
    def slot_valid(self) -> np.ndarray:
        """[scan_width] bool — True on live data slots: pad slots AND
        tombstoned rows excluded (the mask every filter result is ANDed
        with)."""
        gids = self.slot_global_ids
        ok = gids >= 0
        ok[ok] &= self.alive[gids[ok]]
        return ok

    def delta_index(self, ks: KeySet, column: str):
        """Per-run `SortedIndex` over the CURRENT delta run, built
        lazily and cached until the next mutation.  Index probes answer
        base ∪ delta as base-search + this per-run binary search —
        <= 2·ceil(log2 |delta|) extra compares per Range/Eq.  Returns
        None when there is no pending delta."""
        if not self.has_delta:
            return None
        from repro.db.index import SortedIndex   # circular at module scope
        hit = self._delta_index_cache.get(column)
        if hit is not None and hit[0] == self.version:
            return hit[1]
        idx = SortedIndex.build(ks, self.delta, column)
        self._delta_index_cache[column] = (self.version, idx)
        return idx

    # -- access ------------------------------------------------------------

    def column(self, name: str) -> Ciphertext:
        """The named column's stacked BASE ciphertext rows (see
        `scan_column` for the base ∪ delta view)."""
        return self.columns[name]

    def gather(self, name: str, rows: Iterable[int]) -> Ciphertext:
        """Ciphertext rows of `name` at GLOBAL row ids — ids past
        `n_rows` resolve into the delta run."""
        idx = np.asarray(rows, dtype=np.int64)
        ct = self.columns[name]
        if self.delta is None or idx.size == 0 or (idx < self.n_rows).all():
            return Ciphertext(ct.c0[idx], ct.c1[idx])
        dct = self.delta.columns[name]
        bi = np.nonzero(idx < self.n_rows)[0]
        di = np.nonzero(idx >= self.n_rows)[0]
        c0 = jnp.zeros((idx.size,) + ct.c0.shape[1:], ct.c0.dtype)
        c1 = jnp.zeros((idx.size,) + ct.c1.shape[1:], ct.c1.dtype)
        c0 = c0.at[bi].set(ct.c0[idx[bi]])
        c1 = c1.at[bi].set(ct.c1[idx[bi]])
        c0 = c0.at[di].set(dct.c0[idx[di] - self.n_rows])
        c1 = c1.at[di].set(dct.c1[idx[di] - self.n_rows])
        return Ciphertext(c0, c1)

    def decrypt_column(self, ks: KeySet, name: str, *,
                       include_padding: bool = False) -> np.ndarray:
        """Client-side helper (tests / verification only — needs sk).
        Returns ALL rows of the global id space in id order (base rows
        then delta rows; tombstoned rows included — filter with
        `alive`)."""
        if include_padding and self.delta is not None:
            raise ValueError("include_padding only applies to an "
                             "uncompacted-delta-free table")
        vals = np.asarray(E.decrypt(ks, self.columns[name]))
        if include_padding:
            return vals
        vals = vals[:self.n_rows]
        if self.delta is not None:
            vals = np.concatenate(
                [vals, self.delta.decrypt_column(ks, name)])
        return vals

    def __repr__(self) -> str:
        return (f"Table({self.name!r}, rows={self.n_rows}"
                f" (padded {self.n_padded}), cols={list(self.columns)}"
                + (f", delta={self.n_delta}" if self.has_delta else "")
                + (f", dead={int(self._dead.sum())}"
                   if self._dead.any() else "") + ")")


def append_rows(ks: KeySet, base: "Table", new: "Table") -> "Table":
    """Ciphertext-level append: `base`'s valid rows + `new`'s valid
    rows, re-padded to the next power of two with fresh encryptions of
    0.  No row is re-encrypted — existing ciphertexts are sliced and
    concatenated (the same trick as `ShardedTable.from_table`).  Used to
    grow a delta run and to fold a delta back into the base at
    compaction."""
    if set(base.columns) != set(new.columns):
        raise ValueError("column mismatch between runs")
    n_total = base.n_rows + new.n_rows
    n_pad = next_pow2(n_total)
    columns = {}
    for cname, ct in base.columns.items():
        nct = new.columns[cname]
        parts = [Ciphertext(ct.c0[:base.n_rows], ct.c1[:base.n_rows]),
                 Ciphertext(nct.c0[:new.n_rows], nct.c1[:new.n_rows])]
        if n_total < n_pad:
            parts.append(_zero_pad_rows(ks, cname, n_pad - n_total,
                                        salt=n_total))
        columns[cname] = concat_ct_rows(*parts)
    return Table(base.name, columns, n_total)
