"""Delta-run compaction: fold pending inserts into base + sorted index.

The write path (`Table.insert` / `ShardedTable.insert`) accumulates new
rows in small pow2-padded delta runs that every read unions in (fused
scans widen by the delta block, index probes add a per-run binary
search).  `compact` is the background step that retires a delta run:

  1. BASE APPEND — the delta's ciphertext rows concatenate onto the
     base columns and the block re-pads to the next power of two with
     fresh encryptions of 0 (`table.append_rows`).  Pure ciphertext
     slicing: no base row is re-encrypted, and global row ids are
     unchanged (delta ids were assigned past the end of the base id
     space at insert time).
  2. INDEX MERGE — each `SortedIndex` merges its ascending base run
     with the delta run's ascending run (the per-run index the lookups
     were already probing) through the log-depth half-cleaner + bitonic
     merge network `shard.merge.merge_sorted_runs`: both runs pad to a
     common block L = next_pow2(max(n_base, n_delta)) with ascending
     sentinels and ONE merge round costs L·(1 + log2 L) compares —
     O((n_delta + block)·log) versus the O(n log² n) of rebuilding the
     index from scratch.  Sentinels strip by id, never by value.

Tombstones survive compaction untouched: dead rows stay encrypted in
base (and in the merged index runs) and remain masked host-side — a
compaction changes WHERE rows live, never what a query answers.

Sharded tables compact per shard: each shard folds its own delta run
into its base block (growing the common block size if any shard
overflows) and each `ShardedIndex` merges per-shard runs — the same
network, S small merges instead of one big one.

"Background" here is cooperative: `QueryServer.compact()` runs between
drained batches (optionally auto-triggered by a delta-size threshold),
so queries keep answering over base ∪ delta until the merge lands.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compare as C
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db.index import SortedIndex
from repro.db.table import Table, append_rows


@dataclasses.dataclass
class CompactionStats:
    """What one compaction actually did — benchmarks assert the merge
    stays a merge (O((n_delta + block)·log) compares) and never a
    rebuild (`rebuild_compares` is what a from-scratch re-sort would
    have cost; tests require merge_compares strictly below it)."""
    n_base: int = 0                # base rows before the fold
    n_delta: int = 0               # delta rows folded in
    shards: int = 1
    merge_compares: int = 0        # merge-network compares, all indexes
    merge_rounds: int = 0          # pairwise merge invocations
    rebuild_compares: int = 0      # the avoided from-scratch sort cost
    indexes_merged: int = 0

    @property
    def merge_bound(self) -> int:
        """The documented per-merge cost ceiling summed over rounds is
        checked where the numbers are produced; this exposes the
        headline (n_delta + block)·log form for trajectories."""
        block = C.next_pow2(max(self.n_base, 1))
        return (C.next_pow2(max(self.n_delta, 1)) + block) * (
            1 + max(1, block.bit_length() - 1))


def merge_index_runs(ks: KeySet, base: SortedIndex, delta: SortedIndex,
                     *, id_offset: int) -> Tuple[SortedIndex, int]:
    """Merge a base index run with a delta run into one `SortedIndex`.

    `delta.perm` holds delta-LOCAL row ids; `id_offset` lifts them into
    the global id space (base row count at insert time).  Both runs pad
    to L = next_pow2(max(|base|, |delta|)) with ascending sentinels
    (id -1) and ride ONE `merge_sorted_runs` round — L·(1 + log2 L)
    compares, each stage one batched Eval.  Returns the merged index and
    the compare count.
    """
    from repro.db.executor import jitted_comparator
    from repro.db.shard import merge as M
    carried = base.build_compares + delta.build_compares
    if delta.n_rows == 0:
        return base, 0
    if base.n_rows == 0:
        return SortedIndex(base.column, delta.sorted_ct,
                           delta.perm + id_offset,
                           build_compares=carried), 0
    L = C.next_pow2(max(base.n_rows, delta.n_rows))
    with obs.span("compact.merge_index", column=base.column, block=L):
        ct, ids = M.pad_shard_blocks(
            ks, [(base.sorted_ct, base.perm),
                 (delta.sorted_ct, delta.perm + id_offset)],
            block=L, pad_value=ks.params.max_operand // 2, num_blocks=2)
        c0, c1, gid, compares = M.merge_sorted_runs(
            ks, jitted_comparator(ks), ct.c0, ct.c1, jnp.asarray(ids),
            run=L)
    gid = np.asarray(gid)
    keep = np.nonzero(gid >= 0)[0]
    merged = SortedIndex(base.column, Ciphertext(c0[keep], c1[keep]),
                         gid[keep], build_compares=carried)
    merged.search_compares = base.search_compares + delta.search_compares
    return merged, compares


def compact(ks: KeySet, table, indexes: Optional[Dict] = None,
            ) -> CompactionStats:
    """Fold the pending delta run(s) of `table` into its base and merge
    them into every index in `indexes` (updated IN PLACE with the merged
    `SortedIndex` / `ShardedIndex` objects).  Accepts a `Table` or a
    `ShardedTable`; a no-op (zero stats) when nothing is pending."""
    shard_mod = sys.modules.get("repro.db.shard.table")
    if shard_mod is not None and isinstance(table, shard_mod.ShardedTable):
        with obs.span("compact", shards=table.num_shards,
                      n_delta=table.n_delta):
            stats = _compact_sharded(ks, table, indexes)
        obs.absorb_compaction_stats(stats)
        return stats
    indexes = indexes if indexes is not None else {}
    stats = CompactionStats(n_base=table.n_rows, n_delta=table.n_delta)
    if not table.has_delta:
        return stats
    with obs.span("compact", n_base=table.n_rows, n_delta=table.n_delta):
        n_new = table.n_rows + table.n_delta
        for col in list(indexes):
            didx = table.delta_index(ks, col)
            merged, compares = merge_index_runs(ks, indexes[col], didx,
                                                id_offset=table.n_rows)
            indexes[col] = merged
            stats.merge_compares += compares
            stats.merge_rounds += 1
            stats.indexes_merged += 1
            stats.rebuild_compares += C.bitonic_compare_count(n_new)
        folded = append_rows(ks, table, table.delta)
        table.columns = folded.columns
        table.n_rows = folded.n_rows
        table.delta = None
        table._invalidate()
    obs.absorb_compaction_stats(stats)
    return stats


def _compact_sharded(ks: KeySet, stable, indexes: Optional[Dict],
                     ) -> CompactionStats:
    """Per-shard compaction of a `ShardedTable` (see module docstring).

    Every shard folds its own delta run into its base block; if any
    shard's base + delta overflows the common block, ALL shards re-pad
    to the next power of two with fresh encryptions of 0 (ciphertext
    append, no re-encryption — `append_rows` semantics per shard).
    Each `ShardedIndex` then merges per-shard (base run, delta run)
    pairs through the same merge network and is rebuilt as an object
    from the merged per-shard `SortedIndex`es — the sorts themselves
    are never redone."""
    from repro.db.shard.index import ShardedIndex
    indexes = indexes if indexes is not None else {}
    stats = CompactionStats(n_base=stable.n_rows, n_delta=stable.n_delta,
                            shards=stable.num_shards)
    if not stable.has_delta:
        return stats
    for col in list(indexes):
        idx = indexes[col]
        merged_shards = []
        for s in range(stable.num_shards):
            base_s = idx.shards[s]
            didx = stable.delta_index(ks, col, s)
            if didx is None:
                merged_shards.append(base_s)
                continue
            # per-shard index perms are LOCAL slot ids: delta rows land
            # at slots base_rows..base_rows+d-1 after the fold below
            merged, compares = merge_index_runs(
                ks, base_s, didx, id_offset=int(stable.shard_rows[s]))
            merged_shards.append(merged)
            stats.merge_compares += compares
            stats.merge_rounds += 1
            n_new_s = int(stable.shard_rows[s]) + stable.delta_rows(s)
            stats.rebuild_compares += C.bitonic_compare_count(n_new_s)
        indexes[col] = ShardedIndex(col, merged_shards,
                                    build_compares=idx.build_compares)
        stats.indexes_merged += 1
    stable._fold_deltas(ks)
    return stats
