"""Batched multi-query serving for the repro.db engine.

Mirrors `launch/serve.py`'s queue/batch pattern: client queries enqueue,
the server drains them in fixed-size batches, and each batch executes
against one table in a single vectorized pass —

  * every scan atom of every query in the batch joins ONE fused
    [sum(A_i), N] batched Eval (one XLA program for the whole batch's
    filter stage, regardless of how many clients asked);
  * every index-eligible leaf joins ONE lane-batched binary search per
    index (2 lanes per Range/Eq, so K clients cost ~2K·log2 n compares
    resolved in log2 n batched probe Evals);
  * float (CKKS) lanes ride the same launches: each lane carries its
    predicate's decode threshold (ε-band Eq, ε-inclusive Range bounds),
    and scan atoms threshold the shared raw-eval launch per atom — a
    batch mixing exact BFV-style and ε-tolerant predicates still fuses.

Per-query combine / order / limit stages then run on each query's own
mask (they depend on per-query match sets, so they cannot share a
program; they reuse the executor's stage helpers).

Usage:
  PYTHONPATH=src python -m repro.db.query_serve --dataset hg38 \
      --requests 8 --batch 4 --rows 4096
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.ckks import eps_to_tau
from repro.core.keys import KeySet
from repro.db import executor as X
from repro.db import plan as P
from repro.db.index import SortedIndex, _stack_cts
from repro.db.table import Table, rows_to_mask


@dataclasses.dataclass
class BatchStats:
    queries: int = 0
    eval_calls: int = 0
    scan_compares: int = 0
    index_compares: int = 0
    wall_s: float = 0.0


class QueryServer:
    """Queue + batch executor over one encrypted table."""

    def __init__(self, ks: KeySet, table: Table, *,
                 indexes: Optional[Dict[str, SortedIndex]] = None,
                 batch: int = 4, engine: str = "jnp"):
        self.ks = ks
        self.table = table
        self.indexes = indexes or {}
        self.batch = int(batch)
        self.engine = engine
        self._queue: List[Tuple[int, P.Query]] = []
        self._next_id = 0
        self.batch_log: List[BatchStats] = []

    # -- queue -------------------------------------------------------------

    def submit(self, query) -> int:
        """Enqueue a Query (or bare predicate); returns a request id."""
        if isinstance(query, P.Predicate):
            query = P.Query(where=query)
        qid = self._next_id
        self._next_id += 1
        self._queue.append((qid, query))
        return qid

    def run(self) -> Dict[int, X.QueryResult]:
        """Drain the queue in batches; returns {request id: result}."""
        results: Dict[int, X.QueryResult] = {}
        while self._queue:
            chunk, self._queue = (self._queue[:self.batch],
                                  self._queue[self.batch:])
            results.update(self._run_batch(chunk))
        return results

    # -- batch execution ---------------------------------------------------

    def _run_batch(self, chunk: List[Tuple[int, P.Query]],
                   ) -> Dict[int, X.QueryResult]:
        t0 = time.perf_counter()
        ks, table = self.ks, self.table
        N = table.n_padded
        plans = [(qid, P.compile_plan(q)) for qid, q in chunk]
        bstats = BatchStats(queries=len(chunk))

        # partition every query's leaves into index lanes vs scan atoms
        scan_atoms: List[P.Atom] = []
        scan_ref: List[Tuple[int, int, int, int]] = []  # (plan#, leaf, start, count)
        lane_cts: Dict[str, list] = {}                   # column -> [ct, ...]
        lane_strict: Dict[str, list] = {}
        lane_taus: Dict[str, list] = {}                  # per-lane decode τ
        lane_ref: Dict[str, list] = {}                   # -> (plan#, leaf)
        for pi, (_, plan) in enumerate(plans):
            for li, leaf in enumerate(plan.leaves):
                idx = self.indexes.get(leaf.column)
                if idx is not None:
                    lo, hi = ((leaf.lo, leaf.hi) if isinstance(leaf, P.Range)
                              else (leaf.value, leaf.value))
                    tau = (ks.params.tau if leaf.eps is None
                           else eps_to_tau(ks.params, leaf.eps))
                    lane_cts.setdefault(leaf.column, []).extend([lo, hi])
                    lane_strict.setdefault(leaf.column, []).extend(
                        [False, True])
                    lane_taus.setdefault(leaf.column, []).extend([tau, tau])
                    lane_ref.setdefault(leaf.column, []).append((pi, li))
                else:
                    atoms = plan.scan_atoms(li)
                    scan_ref.append((pi, li, len(scan_atoms), len(atoms)))
                    scan_atoms.extend(atoms)

        leaf_masks: List[List[Optional[np.ndarray]]] = [
            [None] * plan.num_leaves for _, plan in plans]

        # per-query stats: each query is billed its own leaves/compares,
        # shared launches (the fused Eval, the lane-batched searches) are
        # counted once in BatchStats — the two views must not be conflated
        qstats = [X.ExecStats() for _ in plans]

        # ONE lane-batched binary search per index (all queries together)
        for column, cts in lane_cts.items():
            idx = self.indexes[column]
            before = idx.search_compares
            pos = idx.search(ks, _stack_cts(cts),
                             np.asarray(lane_strict[column]),
                             np.asarray(lane_taus[column], np.int64))
            bstats.index_compares += idx.search_compares - before
            for j, (pi, li) in enumerate(lane_ref[column]):
                l, r = int(pos[2 * j]), int(pos[2 * j + 1])
                leaf_masks[pi][li] = rows_to_mask(idx.perm[l:r], N)
                qstats[pi].indexed_leaves += 1
                qstats[pi].index_compares += int(
                    idx.last_probe_counts[2 * j]
                    + idx.last_probe_counts[2 * j + 1])

        # ONE fused Eval for every scan atom of every query in the batch
        if scan_atoms:
            vals = X.fused_eval(ks, table, scan_atoms, engine=self.engine)
            bstats.eval_calls += 1
            bstats.scan_compares += len(scan_atoms) * N
            for pi, li, start, count in scan_ref:
                leaf_masks[pi][li] = X.scan_leaf_mask(ks, scan_atoms, vals,
                                                      start, count)
                qstats[pi].scan_leaves += 1
                qstats[pi].scan_compares += count * N
                qstats[pi].eval_calls = 1     # its share of the fused launch

        # per-query combine + order/limit/project
        results: Dict[int, X.QueryResult] = {}
        for pi, (qid, plan) in enumerate(plans):
            stats = qstats[pi]
            mask = X.combine_tree(plan.tree, leaf_masks[pi], N)
            mask &= table.valid
            row_ids = np.nonzero(mask)[0]
            row_ids = X.order_rows(ks, table, plan.query, row_ids, stats)
            columns = {c: table.gather(c, row_ids)
                       for c in plan.query.select}
            results[qid] = X.QueryResult(
                row_ids=row_ids, mask=mask[:table.n_rows],
                columns=columns, stats=stats)
        bstats.wall_s = time.perf_counter() - t0
        self.batch_log.append(bstats)
        return results


# ---------------------------------------------------------------------------
# CLI demo: random range queries against a paper dataset
# ---------------------------------------------------------------------------

def main(argv=None) -> dict:
    import jax.numpy as jnp

    from repro.core import encrypt as E
    from repro.core.keys import keygen
    from repro.core.params import make_params
    from repro.data import load_dataset

    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hg38")
    ap.add_argument("--rows", type=int, default=4096,
                    help="0 = full dataset")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--index", action="store_true",
                    help="build a sorted index and serve lookups through it")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(args.seed))
    vals = load_dataset(args.dataset, scheme="bfv", t=params.t)
    if args.rows:
        vals = vals[:args.rows]
    vals = (vals % (params.max_operand // 2)).astype(np.int64)

    table = Table.from_arrays(ks, args.dataset, {"value": vals},
                              jax.random.PRNGKey(args.seed + 1))
    indexes = {}
    t_build = 0.0
    if args.index:
        t0 = time.perf_counter()
        indexes["value"] = SortedIndex.build(ks, table, "value")
        t_build = time.perf_counter() - t0

    rng = np.random.default_rng(args.seed)
    server = QueryServer(ks, table, indexes=indexes, batch=args.batch)
    truth = {}
    for _ in range(args.requests):
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        ct_lo = E.encrypt(ks, jnp.asarray(int(lo)),
                          jax.random.PRNGKey(int(rng.integers(1 << 30))))
        ct_hi = E.encrypt(ks, jnp.asarray(int(hi)),
                          jax.random.PRNGKey(int(rng.integers(1 << 30))))
        qid = server.submit(P.Range("value", ct_lo, ct_hi))
        truth[qid] = int(((vals >= lo) & (vals <= hi)).sum())

    t0 = time.perf_counter()
    results = server.run()
    wall = time.perf_counter() - t0
    correct = sum(int(len(r) == truth[qid]) for qid, r in results.items())
    out = {
        "dataset": args.dataset, "rows": int(len(vals)),
        "requests": args.requests, "batch": args.batch,
        "indexed": bool(args.index),
        "index_build_s": round(t_build, 3),
        "wall_s": round(wall, 3),
        "queries_per_s": round(args.requests / wall, 2),
        "fused_eval_calls": sum(b.eval_calls for b in server.batch_log),
        "scan_compares": sum(b.scan_compares for b in server.batch_log),
        "index_compares": sum(b.index_compares for b in server.batch_log),
        "correct": f"{correct}/{args.requests}",
    }
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
