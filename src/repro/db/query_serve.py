"""Batched multi-query serving for the repro.db engine.

Mirrors `launch/serve.py`'s queue/batch pattern: client queries enqueue,
the server drains them in fixed-size batches, and each batch executes
against one table in a single vectorized pass —

  * every scan atom of every query in the batch joins ONE fused
    [sum(A_i), N] batched Eval (one XLA program for the whole batch's
    filter stage, regardless of how many clients asked);
  * every index-eligible leaf joins ONE lane-batched binary search per
    index (2 lanes per Range/Eq, so K clients cost ~2K·log2 n compares
    resolved in log2 n batched probe Evals);
  * float (CKKS) lanes ride the same launches: each lane carries its
    predicate's decode threshold (ε-band Eq, ε-inclusive Range bounds),
    and scan atoms threshold the shared raw-eval launch per atom — a
    batch mixing exact BFV-style and ε-tolerant predicates still fuses;
  * JOINS batch too (`submit_join`): a join's left-side filter leaves
    bind into the SAME shared scan/index launches as plain queries (the
    leaf partition is agnostic to which plan kind owns a leaf), and
    nested-loop pair grids dedupe across the batch — K joins against
    the same right table and key columns share ONE tiled raw-eval grid,
    each join applying its own τ/ε and masks host-side.

Per-query combine / order / limit stages then run on each query's own
mask (they depend on per-query match sets, so they cannot share a
program; they reuse the executor's stage helpers).

MUTATIONS interleave with queries on the same queue (`submit_insert` /
`submit_delete` / `submit_update`): the drain splits the queue into
maximal same-kind runs — submit order is preserved, so a query enqueued
after an insert sees the inserted rows — and each query batch answers
over base ∪ delta (the shared fused scan widens by the delta block; the
lane-batched index searches add ONE per-delta-run search per column).
`compact()` retires the pending delta between batches through the merge
network (`repro.db.delta.compact`) — cooperative "background"
compaction; `compact_threshold` triggers it automatically once the
delta outgrows the threshold.

Usage:
  PYTHONPATH=src python -m repro.db.query_serve --dataset hg38 \
      --requests 8 --batch 4 --rows 4096
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import time
import weakref
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.core.ckks import eps_to_tau
from repro.core.keys import KeySet
from repro.db import executor as X
from repro.db import join as J
from repro.db import plan as P
from repro.db.index import SortedIndex, _stack_cts
from repro.db.table import Table, rows_to_mask


@dataclasses.dataclass
class BatchStats:
    """Shared-launch accounting for one drained batch (the fused Eval,
    the lane-batched searches and the deduped join grids are counted
    ONCE here; per-query shares live on each result's own stats)."""
    queries: int = 0
    joins: int = 0
    eval_calls: int = 0
    scan_compares: int = 0
    index_compares: int = 0
    delta_build_compares: int = 0  # lazy per-delta-run index builds
    grid_evals: int = 0            # deduped nested-join pair-grid launches
    pair_compares: int = 0         # deduped pair-grid lanes
    wall_s: float = 0.0


@dataclasses.dataclass
class MutationResult:
    """Outcome of one queued mutation: the inserted rows' global ids
    (empty for a pure delete) and the newly-tombstoned row count."""
    kind: str                      # "insert" | "delete" | "update"
    row_ids: np.ndarray
    deleted: int = 0


@dataclasses.dataclass
class _QueuedMutation:
    """A submitted write: insert data, delete rows, or both (update)."""
    kind: str
    rows: Optional[np.ndarray] = None
    data: Optional[Dict[str, np.ndarray]] = None
    key: Optional[jax.Array] = None


@dataclasses.dataclass
class _QueuedJoin:
    """A submitted join: the plan plus its right-hand table context."""
    join: P.Join
    right: Table
    right_indexes: Dict[str, SortedIndex]
    strategy: str


class QueryServer:
    """Queue + batch executor over one encrypted table."""

    def __init__(self, ks: KeySet, table: Table, *,
                 indexes: Optional[Dict[str, SortedIndex]] = None,
                 batch: int = 4, engine: str = "jnp",
                 compact_threshold: Optional[int] = None,
                 lane_budget: Optional[int] = None):
        self.ks = ks
        self.table = table
        self.indexes = indexes or {}
        self.batch = int(batch)
        self.engine = engine
        self.compact_threshold = compact_threshold
        # per-launch eval-lane cap for the shared fused scans AND the
        # deduped join pair grids (None = the kernels.ops policy default)
        self.lane_budget = lane_budget
        self._queue: List[Tuple[int, P.Query]] = []
        self._next_id = 0
        self.batch_log: List[BatchStats] = []
        self.compaction_log: list = []
        self._tenants: Dict[int, str] = {}     # request id -> tenant label
        # server-scope memo of on-the-fly sort-merge runs:
        # (id(table), column) -> (weakref to the table, version at
        # build, sorted run).  The weakref guards against id reuse — a
        # transient right table can be GC'd and its id recycled by a
        # fresh Table (which also starts at version 0), so a hit is
        # valid only if the referent is STILL the probing table AND the
        # version matches; the ref's callback evicts the entry when the
        # table dies, so dead runs are not pinned either
        self._run_cache: Dict[Tuple[int, str],
                              Tuple["weakref.ref", int, tuple]] = {}

    # -- queue -------------------------------------------------------------

    def _enqueue(self, item, tenant: Optional[str]) -> int:
        """Assign the next request id, remember its tenant, enqueue."""
        qid = self._next_id
        self._next_id += 1
        if tenant is not None:
            self._tenants[qid] = tenant
        self._queue.append((qid, item))
        return qid

    def clear_queue(self) -> int:
        """Drop every queued, not-yet-drained request; returns how many
        were dropped.  The fault-recovery reset: after `run()` raises,
        the queue may hold a partially-consumed drain — callers that
        retry (e.g. `ServeLoop`) clear it before re-submitting."""
        dropped = len(self._queue)
        self._queue = []
        return dropped

    @contextlib.contextmanager
    def batch_size(self, n: int):
        """Temporarily set the drain batch size (restored on exit, even
        if the drain raises) — how `ServeLoop` runs a drafted batch as
        ONE shared launch without clobbering the configured size."""
        old, self.batch = self.batch, max(1, int(n))
        try:
            yield self
        finally:
            self.batch = old

    def _bill_tenant(self, qid: int, stats) -> None:
        """Per-tenant served-query + compare-lane attribution (counted
        only when the obs layer is enabled)."""
        if not obs.is_enabled():
            return
        tenant = self._tenants.get(qid, "default")
        obs.count("server.queries", 1, tenant=tenant)
        compares = getattr(stats, "filter_compares",
                           getattr(stats, "join_compares", 0))
        obs.count("server.compares", compares, tenant=tenant)

    def submit(self, query, *, tenant: Optional[str] = None) -> int:
        """Enqueue a Query (or bare predicate); returns a request id.
        `tenant` labels the request for per-tenant metrics attribution."""
        if isinstance(query, P.Predicate):
            query = P.Query(where=query)
        return self._enqueue(query, tenant)

    def submit_join(self, join: P.Join, right: Table, *,
                    right_indexes: Optional[Dict[str, SortedIndex]] = None,
                    strategy: str = "auto",
                    tenant: Optional[str] = None) -> int:
        """Enqueue a Join of the server's table (left side) against
        `right`; returns a request id resolving to a `JoinResult`.

        The join's LEFT filter leaves fuse into the batch's shared
        scan/index launches exactly like plain queries' leaves; its
        nested-loop pair grid dedupes with every other queued join that
        names the same `right` table and key columns — K such joins cost
        ONE tiled grid launch.  `right_indexes` serve the right-side
        filters and (with a left index on the server) enable the
        sort-merge strategy.
        """
        P.compile_join(join)          # validate kind/on shape at submit time
        return self._enqueue(_QueuedJoin(join, right,
                                         dict(right_indexes or {}),
                                         strategy), tenant)

    def submit_insert(self, data: Dict[str, np.ndarray], key: jax.Array, *,
                      tenant: Optional[str] = None) -> int:
        """Enqueue an insert of new rows; resolves to a `MutationResult`
        carrying the rows' global ids.  Queries submitted AFTER this see
        the new rows (FIFO order survives batching)."""
        return self._enqueue(_QueuedMutation("insert", data=data, key=key),
                             tenant)

    def submit_delete(self, rows, *, tenant: Optional[str] = None) -> int:
        """Enqueue a tombstone of the given global row ids; resolves to
        a `MutationResult` with the newly-dead count."""
        return self._enqueue(_QueuedMutation(
            "delete", rows=np.asarray(rows, np.int64)), tenant)

    def submit_update(self, rows, data: Dict[str, np.ndarray],
                      key: jax.Array, *,
                      tenant: Optional[str] = None) -> int:
        """Enqueue an update (tombstone `rows` + insert replacements);
        resolves to a `MutationResult` with the replacement global ids."""
        return self._enqueue(_QueuedMutation(
            "update", rows=np.asarray(rows, np.int64), data=data, key=key),
            tenant)

    def run(self) -> Dict[int, X.QueryResult]:
        """Drain the queue; returns {request id: result} (a `QueryResult`
        per query, a `JoinResult` per join, a `MutationResult` per
        mutation).  The queue splits into maximal same-kind runs in
        submit order: query runs drain in shared-launch batches,
        mutation runs apply sequentially — so reads always observe
        exactly the writes submitted before them.  After a mutation run,
        `compact_threshold` may trigger a cooperative compaction."""
        results: Dict[int, X.QueryResult] = {}
        while self._queue:
            is_mut = isinstance(self._queue[0][1], _QueuedMutation)
            n = 1
            while (n < len(self._queue) and isinstance(
                    self._queue[n][1], _QueuedMutation) == is_mut):
                n += 1
            chunk, self._queue = self._queue[:n], self._queue[n:]
            if is_mut:
                for qid, m in chunk:
                    results[qid] = self._apply_mutation(m)
                if (self.compact_threshold is not None
                        and self.table.n_delta >= self.compact_threshold):
                    self.compact()
            else:
                for i in range(0, len(chunk), self.batch):
                    results.update(self._run_batch(chunk[i:i + self.batch]))
        return results

    # -- mutations ---------------------------------------------------------

    def _apply_mutation(self, m: _QueuedMutation) -> MutationResult:
        table = self.table
        with obs.span("server.mutation", kind=m.kind):
            deleted = 0
            if m.rows is not None:
                deleted = table.delete(m.rows)
            row_ids = np.zeros(0, np.int64)
            if m.data is not None:
                row_ids = table.insert(self.ks, m.data, m.key)
        return MutationResult(m.kind, row_ids, deleted=deleted)

    def compact(self):
        """Retire the pending delta run NOW: fold it into base and merge
        it into every served index through the log-depth merge network
        (`repro.db.delta.compact`) — between batches, so in-flight
        submissions still answered over base ∪ delta stay correct.
        Returns the `CompactionStats`, also appended to
        `compaction_log`."""
        from repro.db.delta import compact as _compact
        stats = _compact(self.ks, self.table, self.indexes)
        self.compaction_log.append(stats)
        return stats

    # -- batch execution ---------------------------------------------------

    def _run_batch(self, chunk: List[Tuple[int, object]],
                   ) -> Dict[int, X.QueryResult]:
        with obs.span("server.batch", size=len(chunk)) as bsp:
            return self._run_batch_traced(chunk, bsp)

    def _run_batch_traced(self, chunk: List[Tuple[int, object]], bsp,
                          ) -> Dict[int, X.QueryResult]:
        t0 = time.perf_counter()
        ks, table = self.ks, self.table
        W = table.scan_width         # base block ∪ pending delta block
        queries: List[Tuple[int, P.CompiledPlan]] = []
        joins: List[Tuple[int, P.CompiledJoin, _QueuedJoin]] = []
        for qid, item in chunk:
            if isinstance(item, _QueuedJoin):
                joins.append((qid, P.compile_join(item.join), item))
            else:
                queries.append((qid, P.compile_plan(item)))
        bstats = BatchStats(queries=len(queries), joins=len(joins))

        # slots: every left-table plan whose leaves ride the shared
        # launches — plain queries first, then joins' left sub-plans (the
        # leaf partition below is agnostic to which kind owns a leaf)
        plans: List[Tuple[Optional[int], P.CompiledPlan]] = [
            (qid, plan) for qid, plan in queries]
        join_slot: List[Optional[int]] = []
        for _, cj, _ in joins:
            if cj.left_plan is not None:
                join_slot.append(len(plans))
                plans.append((None, cj.left_plan))
            else:
                join_slot.append(None)

        # partition every slot's leaves into index lanes vs scan atoms
        scan_atoms: List[P.Atom] = []
        scan_ref: List[Tuple[int, int, int, int]] = []  # (plan#, leaf, start, count)
        lane_cts: Dict[str, list] = {}                   # column -> [ct, ...]
        lane_strict: Dict[str, list] = {}
        lane_taus: Dict[str, list] = {}                  # per-lane decode τ
        lane_ref: Dict[str, list] = {}                   # -> (plan#, leaf)
        for pi, (_, plan) in enumerate(plans):
            for li, leaf in enumerate(plan.leaves):
                idx = self.indexes.get(leaf.column)
                if idx is not None:
                    lo, hi = ((leaf.lo, leaf.hi) if isinstance(leaf, P.Range)
                              else (leaf.value, leaf.value))
                    tau = (ks.params.tau if leaf.eps is None
                           else eps_to_tau(ks.params, leaf.eps))
                    lane_cts.setdefault(leaf.column, []).extend([lo, hi])
                    lane_strict.setdefault(leaf.column, []).extend(
                        [False, True])
                    lane_taus.setdefault(leaf.column, []).extend([tau, tau])
                    lane_ref.setdefault(leaf.column, []).append((pi, li))
                else:
                    atoms = plan.scan_atoms(li)
                    scan_ref.append((pi, li, len(scan_atoms), len(atoms)))
                    scan_atoms.extend(atoms)

        leaf_masks: List[List[Optional[np.ndarray]]] = [
            [None] * plan.num_leaves for _, plan in plans]

        # per-query stats: each query is billed its own leaves/compares,
        # shared launches (the fused Eval, the lane-batched searches) are
        # counted once in BatchStats — the two views must not be conflated
        qstats = [X.ExecStats() for _ in plans]

        # ONE lane-batched binary search per index (all queries together);
        # a pending delta run adds ONE more lane-batched search per column
        # against its own (lazily built, cached) sorted run
        for column, cts in lane_cts.items():
            idx = self.indexes[column]
            lanes = _stack_cts(cts)
            strict = np.asarray(lane_strict[column])
            taus = np.asarray(lane_taus[column], np.int64)
            before = idx.search_compares
            pos = idx.search(ks, lanes, strict, taus)
            bstats.index_compares += idx.search_compares - before
            base_counts = idx.last_probe_counts.copy()
            didx = X.delta_probe_index(ks, table, column, bstats)
            dpos = dcounts = None
            if didx is not None:
                before = didx.search_compares
                dpos = didx.search(ks, lanes, strict, taus)
                bstats.index_compares += didx.search_compares - before
                dcounts = didx.last_probe_counts.copy()
            for j, (pi, li) in enumerate(lane_ref[column]):
                l, r = int(pos[2 * j]), int(pos[2 * j + 1])
                slots = [np.asarray(idx.perm[l:r], np.int64)]
                qstats[pi].indexed_leaves += 1
                qstats[pi].index_compares += int(
                    base_counts[2 * j] + base_counts[2 * j + 1])
                if dpos is not None:
                    dl, dr = int(dpos[2 * j]), int(dpos[2 * j + 1])
                    slots.append(table.n_padded
                                 + np.asarray(didx.perm[dl:dr], np.int64))
                    qstats[pi].index_compares += int(
                        dcounts[2 * j] + dcounts[2 * j + 1])
                leaf_masks[pi][li] = rows_to_mask(np.concatenate(slots), W)

        # ONE fused Eval pass for every scan atom of every query in the
        # batch (deduped columns, lane-budgeted tiles)
        if scan_atoms:
            vals = X.fused_eval(ks, table, scan_atoms, engine=self.engine,
                                lane_budget=self.lane_budget)
            bstats.eval_calls += 1
            bstats.scan_compares += len(scan_atoms) * W
            for pi, li, start, count in scan_ref:
                leaf_masks[pi][li] = X.scan_leaf_mask(ks, scan_atoms, vals,
                                                      start, count)
                qstats[pi].scan_leaves += 1
                qstats[pi].scan_compares += count * W
                qstats[pi].eval_calls = 1     # its share of the fused launch

        # per-query combine + order/limit/project over the union slot
        # space (join slots skip — their masks resolve inside the join
        # section below); pads and tombstones drop via slot_valid
        results: Dict[int, X.QueryResult] = {}
        for pi, (qid, plan) in enumerate(plans):
            if qid is None:
                continue
            stats = qstats[pi]
            slot_mask = X.combine_tree(plan.tree, leaf_masks[pi], W)
            slot_mask &= table.slot_valid
            row_ids = table.slot_global_ids[np.nonzero(slot_mask)[0]]
            gmask = rows_to_mask(row_ids, table.n_total)
            row_ids = X.order_rows(ks, table, plan.query, row_ids, stats)
            columns = {c: table.gather(c, row_ids)
                       for c in plan.query.select}
            results[qid] = X.QueryResult(
                row_ids=row_ids, mask=gmask, columns=columns, stats=stats)
            self._bill_tenant(qid, stats)

        if joins:
            with obs.span("server.joins", joins=len(joins)):
                jres = self._run_joins(joins, join_slot, leaf_masks,
                                       qstats, bstats)
            for qid, r in jres.items():
                self._bill_tenant(qid, r.stats)
            results.update(jres)
        bstats.wall_s = time.perf_counter() - t0
        bsp.set(queries=bstats.queries, joins=bstats.joins,
                eval_calls=bstats.eval_calls)
        obs.absorb_batch_stats(bstats)
        if obs.is_enabled() and table.n_rows:
            obs.observe("pad.waste", table.n_padded / table.n_rows)
        self.batch_log.append(bstats)
        return results

    def _run_joins(self, joins, join_slot, leaf_masks, qstats,
                   bstats: BatchStats) -> Dict[int, J.JoinResult]:
        """Resolve the batch's joins after the shared leaf launches.

        Nested-loop pair grids dedupe by (right table, key columns):
        each distinct triple costs ONE tiled raw-eval grid for the whole
        batch, every join decoding it under its own τ/ε and masks.
        Sort-merge runs come from per-side indexes when provided; runs
        built on the fly are memoized per (table, column) at SERVER
        scope in `self._run_cache`, guarded by a weakref to the table
        plus its mutation version — so consecutive batches joining on
        the same un-indexed column pay the O(n log² n) sort once, any
        insert/delete/update (which bumps `table.version`) invalidates
        the entry, and a recycled `id()` from a dead transient table
        can never alias a live one's entry.
        """
        ks, table = self.ks, self.table
        grids: Dict[Tuple[int, str, str], np.ndarray] = {}
        out: Dict[int, J.JoinResult] = {}

        def side_run(side_table, col, index, jstats):
            if index is not None:
                return index.sorted_run()
            key = (id(side_table), col)
            hit = self._run_cache.get(key)
            if (hit is not None and hit[0]() is side_table
                    and hit[1] == side_table.version):
                return hit[2]
            run = J._sorted_run(ks, side_table, col, None, jstats)

            def evict(ref, key=key, cache=self._run_cache):
                ent = cache.get(key)
                if ent is not None and ent[0] is ref:
                    del cache[key]
            self._run_cache[key] = (weakref.ref(side_table, evict),
                                    side_table.version, run)
            return run
        for (qid, cj, item), slot in zip(joins, join_slot):
            lcol, rcol = cj.on_columns
            right = item.right
            jstats = J.JoinStats()
            jstats.strategy = J.resolve_strategy(
                item.strategy, lcol in self.indexes,
                rcol in item.right_indexes)
            lmask = J._side_mask(
                ks, table, cj.left_plan, indexes=self.indexes,
                engine=self.engine, stats=jstats.left,
                leaf_masks=None if slot is None else leaf_masks[slot])
            if slot is not None:      # its leaves rode the shared launches
                jstats.left.scan_leaves += qstats[slot].scan_leaves
                jstats.left.indexed_leaves += qstats[slot].indexed_leaves
                jstats.left.scan_compares += qstats[slot].scan_compares
                jstats.left.index_compares += qstats[slot].index_compares
            rmask = J._side_mask(ks, right, cj.right_plan,
                                 indexes=item.right_indexes,
                                 engine=self.engine, stats=jstats.right)
            tau = J.join_tau(ks, item.join)
            if jstats.strategy == "nested":
                key = (id(right), lcol, rcol)
                if key not in grids:
                    scratch = J.JoinStats()
                    grids[key] = J.pair_eval_values(
                        ks, table.column(lcol), right.column(rcol),
                        engine=self.engine, block_pairs=self.lane_budget,
                        stats=scratch)
                    bstats.grid_evals += scratch.eval_calls
                    bstats.pair_compares += scratch.pair_compares
                jstats.pair_compares += table.n_padded * right.n_padded
                jstats.eval_calls = 1      # its share of the deduped grid
                pairs = J.pairs_from_grid(grids[key], tau, lmask, rmask)
            else:
                lrun = side_run(table, lcol, self.indexes.get(lcol), jstats)
                rrun_ct, rrun_ids = side_run(
                    right, rcol, item.right_indexes.get(rcol), jstats)
                pairs = J.merge_runs_to_pairs(
                    ks, [lrun, (rrun_ct, rrun_ids + table.n_padded)],
                    table.n_padded, tau,
                    verify=J.needs_verify(ks, item.join),
                    gather_left=lambda rows: table.gather(lcol, rows),
                    gather_right=lambda rows, r=right: r.gather(rcol, rows),
                    left_mask=lmask, right_mask=rmask, stats=jstats)
            columns = J._project(cj, table.gather, right.gather, pairs)
            out[qid] = J.JoinResult(
                pairs=pairs, left_mask=lmask[:table.n_rows],
                right_mask=rmask[:right.n_rows], columns=columns,
                stats=jstats)
        return out


# ---------------------------------------------------------------------------
# CLI demo: random range queries against a paper dataset
# ---------------------------------------------------------------------------

def main(argv=None) -> dict:
    """CLI demo: serve random encrypted range queries over a paper
    dataset in batches (see the module docstring for usage)."""
    import jax.numpy as jnp

    from repro.core import encrypt as E
    from repro.core.keys import keygen
    from repro.core.params import make_params
    from repro.data import load_dataset

    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="hg38")
    ap.add_argument("--rows", type=int, default=4096,
                    help="0 = full dataset")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--index", action="store_true",
                    help="build a sorted index and serve lookups through it")
    ap.add_argument("--lane-budget", type=int, default=0,
                    help="eval lanes per fused-scan launch "
                         "(0 = kernels.ops policy default)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(args.seed))
    vals = load_dataset(args.dataset, scheme="bfv", t=params.t)
    if args.rows:
        vals = vals[:args.rows]
    vals = (vals % (params.max_operand // 2)).astype(np.int64)

    table = Table.from_arrays(ks, args.dataset, {"value": vals},
                              jax.random.PRNGKey(args.seed + 1))
    indexes = {}
    t_build = 0.0
    if args.index:
        t0 = time.perf_counter()
        indexes["value"] = SortedIndex.build(ks, table, "value")
        t_build = time.perf_counter() - t0

    rng = np.random.default_rng(args.seed)
    server = QueryServer(ks, table, indexes=indexes, batch=args.batch,
                         lane_budget=args.lane_budget or None)
    truth = {}
    for _ in range(args.requests):
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        ct_lo = E.encrypt(ks, jnp.asarray(int(lo)),
                          jax.random.PRNGKey(int(rng.integers(1 << 30))))
        ct_hi = E.encrypt(ks, jnp.asarray(int(hi)),
                          jax.random.PRNGKey(int(rng.integers(1 << 30))))
        qid = server.submit(P.Range("value", ct_lo, ct_hi))
        truth[qid] = int(((vals >= lo) & (vals <= hi)).sum())

    t0 = time.perf_counter()
    results = server.run()
    wall = time.perf_counter() - t0
    correct = sum(int(len(r) == truth[qid]) for qid, r in results.items())
    out = {
        "dataset": args.dataset, "rows": int(len(vals)),
        "requests": args.requests, "batch": args.batch,
        "indexed": bool(args.index),
        "index_build_s": round(t_build, 3),
        "wall_s": round(wall, 3),
        "queries_per_s": round(args.requests / wall, 2),
        "fused_eval_calls": sum(b.eval_calls for b in server.batch_log),
        "scan_compares": sum(b.scan_compares for b in server.batch_log),
        "index_compares": sum(b.index_compares for b in server.batch_log),
        "correct": f"{correct}/{args.requests}",
    }
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
