"""`ShardedTable`: an encrypted column-store partitioned across shards.

Rows split into S contiguous, balanced chunks; every chunk pads to ONE
common power-of-two block size N_sp (`pad_rows_pow2` — the same helper
and sentinel-geometry `Table` uses), so each column is a single stacked
ciphertext `[S, N_sp, K, n]` whose leading dim places on the shard mesh
(`ShardSpec.place`).  Uneven partitions (non-power-of-two row counts)
just mean shards carry different validity masks over the same block
size — static shapes survive, which is what lets every fused filter
stage compile once and run shard-parallel.

Global row ids are the original ingest order: at construction shard s
owns the contiguous id range [offsets[s], offsets[s+1]), so
`from_table` — which re-partitions an existing `Table`'s ciphertext
ROWS without touching plaintext — produces bit-identical per-row
ciphertexts, the anchor of the byte-level shard-invariance tests.

WRITE PATH.  `insert` routes new rows to the least-loaded shards and
appends them to a per-shard DELTA RUN (a plain `Table`, pow2-padded);
`delete` tombstones global ids host-side; `update` is delete+insert.
New rows take ids past the end of the id space, and compaction
(`repro.db.delta.compact`) folds each shard's delta rows onto the end
of that shard's base block — after which shard ownership is no longer
contiguous in id space.  The table therefore keeps an EXPLICIT id map
(`_gid_shard` / `_gid_pos` / `_gid_in_delta`, plus the per-shard
slot -> id map `_slot_gid`) that starts out equal to the contiguous
arithmetic and stays authoritative through every mutation; all row-id
algebra below reads the map, never the offsets.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encrypt as E
from repro.core.compare import next_pow2
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db.shard.spec import ShardSpec
from repro.db.table import Table, append_rows, concat_ct_rows

# compaction-fold pad rows (encryptions of 0) derive keys from this seed
_FOLD_PAD_SEED = 0xC0FD


def partition_offsets(n_rows: int, num_shards: int) -> np.ndarray:
    """[S+1] contiguous balanced split boundaries (first n%S chunks get
    the extra row)."""
    if not (1 <= num_shards <= n_rows):
        raise ValueError(
            f"num_shards {num_shards} outside [1, {n_rows}] rows")
    base, extra = divmod(n_rows, num_shards)
    sizes = np.full(num_shards, base, np.int64)
    sizes[:extra] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


class ShardedTable:
    """Stacked encrypted columns `[S, N_sp, ...]` + partition bookkeeping."""

    def __init__(self, name: str, columns: Dict[str, Ciphertext],
                 offsets: np.ndarray, spec: ShardSpec):
        if not columns:
            raise ValueError("sharded table needs at least one column")
        shapes = {c: ct.c0.shape[:2] for c, ct in columns.items()}
        S, n_sp = next(iter(shapes.values()))
        if any(v != (S, n_sp) for v in shapes.values()):
            raise ValueError(f"ragged column stacks: {shapes}")
        if S != spec.num_shards:
            raise ValueError(f"stack has {S} shards, spec {spec.num_shards}")
        if n_sp != next_pow2(n_sp):
            raise ValueError(f"per-shard block {n_sp} not a power of two")
        self.name = name
        self.columns = dict(columns)
        self.offsets = np.asarray(offsets, np.int64)
        self.spec = spec
        self.shard_rows = np.diff(self.offsets)          # [S] valid counts
        # empty shards (0 rows) are legal — a shard can drain to empty
        # through deletes; only overflow is a geometry error
        if int(self.shard_rows.max()) > n_sp or int(self.shard_rows.min()) < 0:
            raise ValueError(
                f"shard sizes {self.shard_rows} outside [0, {n_sp}]")
        # -- id map: starts contiguous, stays authoritative ------------
        n = int(self.offsets[-1])
        self._n_base = n
        self._gid_shard = np.repeat(np.arange(S, dtype=np.int64),
                                    self.shard_rows)
        self._gid_pos = np.concatenate(
            [np.arange(int(c), dtype=np.int64) for c in self.shard_rows]
            or [np.zeros(0, np.int64)])
        self._gid_in_delta = np.zeros(n, bool)
        slot_gid = np.full((S, n_sp), -1, np.int64)
        for s in range(S):
            c = int(self.shard_rows[s])
            slot_gid[s, :c] = np.arange(int(self.offsets[s]),
                                        int(self.offsets[s]) + c)
        self._slot_gid = slot_gid
        # -- write-path state ------------------------------------------
        self.deltas: List[Optional[Table]] = [None] * S
        self._delta_gids: List[np.ndarray] = [np.zeros(0, np.int64)
                                              for _ in range(S)]
        self._dead = np.zeros(n, bool)
        self.version = 0
        self._delta_index_cache: Dict[tuple, tuple] = {}

    # -- construction ------------------------------------------------------

    @classmethod
    def from_arrays(cls, ks: KeySet, name: str,
                    data: Dict[str, np.ndarray], key: jax.Array, *,
                    spec: ShardSpec) -> "ShardedTable":
        """Encrypt host arrays straight into the sharded layout.

        Each shard's chunk encrypts under its own fold_in key via
        `Table.from_arrays` (one batched encrypt per column per shard),
        all padded to the common N_sp block.
        """
        n_rows = len(next(iter(data.values())))
        offsets = partition_offsets(n_rows, spec.num_shards)
        n_sp = next_pow2(int(np.diff(offsets).max()))
        stacks: Dict[str, list] = {c: [] for c in data}
        for s in range(spec.num_shards):
            lo, hi = int(offsets[s]), int(offsets[s + 1])
            chunk = {c: np.asarray(v)[lo:hi] for c, v in data.items()}
            t = Table.from_arrays(ks, f"{name}.s{s}", chunk,
                                  jax.random.fold_in(key, s), n_padded=n_sp)
            for c in data:
                stacks[c].append(t.columns[c])
        columns = {c: Ciphertext(jnp.stack([ct.c0 for ct in cts]),
                                 jnp.stack([ct.c1 for ct in cts]))
                   for c, cts in stacks.items()}
        return cls(name, spec.place(columns), offsets, spec)

    @classmethod
    def from_table(cls, ks: KeySet, table: Table, *,
                   spec: ShardSpec) -> "ShardedTable":
        """Re-partition an existing `Table`'s ciphertext rows (server-side:
        slices existing encryptions, pads with public-key encryptions of 0
        exactly like `Table` ingest — no plaintext access needed).
        Tombstones carry over; a pending delta run is refused (compact
        first — the partitioner slices base slots)."""
        if table.has_delta:
            raise ValueError(
                f"table {table.name!r} has {table.n_delta} uncompacted "
                "delta rows — compact before re-partitioning "
                "(repro.db.delta.compact)")
        offsets = partition_offsets(table.n_rows, spec.num_shards)
        n_sp = next_pow2(int(np.diff(offsets).max()))
        pad_key = jax.random.PRNGKey(0x5AAD)
        columns = {}
        for ci, (cname, ct) in enumerate(table.columns.items()):
            c0s, c1s = [], []
            for s in range(spec.num_shards):
                lo, hi = int(offsets[s]), int(offsets[s + 1])
                c0, c1 = ct.c0[lo:hi], ct.c1[lo:hi]
                if hi - lo < n_sp:
                    # same pad semantics as `Table` ingest (pad_rows_pow2
                    # with pad_value=0): genuine encryptions of 0, masked
                    # out by shard validity
                    pad = E.encrypt(
                        ks, jnp.zeros(n_sp - (hi - lo), jnp.int64),
                        jax.random.fold_in(pad_key, ci * 1024 + s))
                    c0 = jnp.concatenate([c0, pad.c0])
                    c1 = jnp.concatenate([c1, pad.c1])
                c0s.append(c0)
                c1s.append(c1)
            columns[cname] = Ciphertext(jnp.stack(c0s), jnp.stack(c1s))
        st = cls(table.name, spec.place(columns), offsets, spec)
        st._dead = table._dead.copy()
        return st

    # -- geometry ----------------------------------------------------------

    @property
    def num_shards(self) -> int:
        """Logical shard count S (the stacks' leading dim)."""
        return int(self.spec.num_shards)

    @property
    def n_rows(self) -> int:
        """Total BASE rows across all shards (excludes pending delta
        rows — see `n_total` for the full global id space)."""
        return self._n_base

    @property
    def n_padded_per_shard(self) -> int:
        """The common power-of-two per-shard block size N_sp."""
        return next(iter(self.columns.values())).c0.shape[1]

    @property
    def column_names(self) -> tuple:
        """Names of the encrypted columns."""
        return tuple(self.columns)

    def shard_valid(self, s: int) -> np.ndarray:
        """[N_sp] bool — BASE data slots of shard s."""
        return np.arange(self.n_padded_per_shard) < int(self.shard_rows[s])

    def ciphertext_bytes(self) -> int:
        """Storage footprint of all encrypted column stacks + deltas."""
        total = sum(ct.c0.nbytes + ct.c1.nbytes
                    for ct in self.columns.values())
        for d in self.deltas:
            if d is not None:
                total += d.ciphertext_bytes()
        return total

    # -- write path --------------------------------------------------------

    def delta_rows(self, s: int) -> int:
        """Rows pending in shard s's delta run."""
        d = self.deltas[s]
        return 0 if d is None else d.n_rows

    @property
    def n_delta(self) -> int:
        """Total pending delta rows across all shards."""
        return sum(self.delta_rows(s) for s in range(self.num_shards))

    @property
    def n_total(self) -> int:
        """Size of the global row-id space: base + delta rows."""
        return self._n_base + self.n_delta

    @property
    def has_delta(self) -> bool:
        """True while any shard holds an uncompacted delta run."""
        return self.n_delta > 0

    @property
    def alive(self) -> np.ndarray:
        """[n_total] bool — False exactly on tombstoned global ids."""
        return ~self._dead

    @property
    def is_mutated(self) -> bool:
        """True if any mutation is outstanding (delta rows or
        tombstones)."""
        return self.has_delta or bool(self._dead.any())

    @property
    def delta_block(self) -> int:
        """Common scan-block size for the shards' delta runs: the
        largest run's padded size (shards with smaller/no runs zero-pad
        their scan lanes — those slots are invalid and never decoded)."""
        return max((d.n_padded for d in self.deltas if d is not None),
                   default=0)

    def insert(self, ks: KeySet, data: Dict[str, np.ndarray],
               key: jax.Array) -> np.ndarray:
        """Append new rows, routed to the least-loaded shards (keeps the
        partition balanced without moving any existing row); returns
        their global ids.  Each receiving shard encrypts its chunk into
        its own delta run under `fold_in(key, s)` — one batched encrypt
        per column per touched shard."""
        if set(data) != set(self.columns):
            raise ValueError(
                f"insert columns {sorted(data)} != table columns "
                f"{sorted(self.columns)}")
        m = len(next(iter(data.values())))
        if m == 0:
            return np.zeros(0, np.int64)
        S = self.num_shards
        loads = self.shard_rows.astype(np.int64).copy()
        loads += np.asarray([self.delta_rows(s) for s in range(S)])
        counts = np.zeros(S, np.int64)
        for _ in range(m):
            s = int(np.argmin(loads))
            loads[s] += 1
            counts[s] += 1
        offs = np.concatenate([[0], np.cumsum(counts)])
        start = self.n_total
        new_pos = np.zeros(m, np.int64)
        for s in range(S):
            c = int(counts[s])
            if c == 0:
                continue
            sl = slice(int(offs[s]), int(offs[s + 1]))
            chunk = {cn: np.asarray(v)[sl] for cn, v in data.items()}
            dt = Table.from_arrays(ks, f"{self.name}.s{s}.delta", chunk,
                                   jax.random.fold_in(key, s))
            prev = self.delta_rows(s)
            self.deltas[s] = (dt if self.deltas[s] is None
                              else append_rows(ks, self.deltas[s], dt))
            gids = start + np.arange(sl.start, sl.stop, dtype=np.int64)
            self._delta_gids[s] = np.concatenate([self._delta_gids[s], gids])
            new_pos[sl] = prev + np.arange(c)
        self._gid_shard = np.concatenate(
            [self._gid_shard, np.repeat(np.arange(S, dtype=np.int64),
                                        counts)])
        self._gid_pos = np.concatenate([self._gid_pos, new_pos])
        self._gid_in_delta = np.concatenate(
            [self._gid_in_delta, np.ones(m, bool)])
        self._dead = np.concatenate([self._dead, np.zeros(m, bool)])
        self._invalidate()
        return start + np.arange(m, dtype=np.int64)

    def delete(self, rows) -> int:
        """Tombstone the given GLOBAL row ids (host-side; ciphertext
        rows stay in place and every read path masks them out).
        Returns the number of newly-dead rows."""
        idx = np.asarray(rows, np.int64)
        if idx.size and (idx.min() < 0 or idx.max() >= self.n_total):
            raise IndexError(f"row ids outside [0, {self.n_total}): {idx}")
        newly = int((~self._dead[idx]).sum())
        self._dead[idx] = True
        self._invalidate()
        return newly

    def update(self, ks: KeySet, rows, data: Dict[str, np.ndarray],
               key: jax.Array) -> np.ndarray:
        """Replace rows: tombstone `rows`, insert their new versions
        (delta-store update identity).  Returns the new global ids."""
        self.delete(rows)
        return self.insert(ks, data, key)

    def _invalidate(self) -> None:
        self.version += 1
        self._delta_index_cache.clear()

    def delta_index(self, ks: KeySet, column: str, s: int):
        """Per-shard, per-run `SortedIndex` over shard s's CURRENT delta
        run (lazily built, cached until the next mutation); None when
        shard s has no pending rows.  Probes cost <= 2·ceil(log2 d_s)
        compares per Range/Eq on top of the base fan-out search."""
        if self.delta_rows(s) == 0:
            return None
        from repro.db.index import SortedIndex
        hit = self._delta_index_cache.get((column, s))
        if hit is not None and hit[0] == self.version:
            return hit[1]
        idx = SortedIndex.build(ks, self.deltas[s], column)
        self._delta_index_cache[(column, s)] = (self.version, idx)
        return idx

    def _fold_deltas(self, ks: KeySet) -> None:
        """Compaction fold (called by `repro.db.delta.compact` AFTER the
        index merges): append each shard's delta ciphertext rows onto
        the end of that shard's base block, growing the common block to
        the next power of two if any shard overflows — fresh encryptions
        of 0 pad the slack, no existing row is re-encrypted.  Global ids
        are unchanged; the id map flips the folded rows from delta to
        base ownership."""
        if not self.has_delta:
            return
        S, n_sp = self.num_shards, self.n_padded_per_shard
        d = np.asarray([self.delta_rows(s) for s in range(S)], np.int64)
        new_rows = self.shard_rows + d
        new_sp = next_pow2(int(new_rows.max()))
        pad_key = jax.random.PRNGKey(_FOLD_PAD_SEED)
        columns = {}
        for ci, (cname, ct) in enumerate(self.columns.items()):
            c0s, c1s = [], []
            for s in range(S):
                b, ds = int(self.shard_rows[s]), int(d[s])
                parts = [Ciphertext(ct.c0[s, :b], ct.c1[s, :b])]
                if ds:
                    dct = self.deltas[s].columns[cname]
                    parts.append(Ciphertext(dct.c0[:ds], dct.c1[:ds]))
                if b + ds < new_sp:
                    salt = ci * 65536 + s * 256 + self.version % 256
                    parts.append(E.encrypt(
                        ks, jnp.zeros(new_sp - b - ds, jnp.int64),
                        jax.random.fold_in(pad_key, salt)))
                stacked = concat_ct_rows(*parts)
                c0s.append(stacked.c0)
                c1s.append(stacked.c1)
            columns[cname] = Ciphertext(jnp.stack(c0s), jnp.stack(c1s))
        self.columns = self.spec.place(columns)
        slot_gid = np.full((S, new_sp), -1, np.int64)
        slot_gid[:, :n_sp] = self._slot_gid
        for s in range(S):
            gids = self._delta_gids[s]
            b = int(self.shard_rows[s])
            slot_gid[s, b:b + gids.size] = gids
            self._gid_in_delta[gids] = False
            self._gid_pos[gids] = b + np.arange(gids.size)
        self._slot_gid = slot_gid
        self.shard_rows = new_rows
        self._n_base = int(new_rows.sum())
        self.deltas = [None] * S
        self._delta_gids = [np.zeros(0, np.int64) for _ in range(S)]
        self._invalidate()

    # -- row-id algebra ----------------------------------------------------

    def global_ids(self, s: int) -> np.ndarray:
        """[N_sp] global row id per BASE slot of shard s (-1 on pads)."""
        return self._slot_gid[s]

    @property
    def shard_scan_width(self) -> int:
        """Uniform per-shard scan width: base block + delta block."""
        return self.n_padded_per_shard + self.delta_block

    def shard_slot_gids(self, s: int) -> np.ndarray:
        """[shard_scan_width] global id per UNION scan slot of shard s
        (-1 on pads and on other shards' share of the delta block)."""
        ids = np.full(self.shard_scan_width, -1, np.int64)
        ids[:self.n_padded_per_shard] = self._slot_gid[s]
        gids = self._delta_gids[s]
        ids[self.n_padded_per_shard:self.n_padded_per_shard + gids.size] = gids
        return ids

    def shard_slot_valid(self, s: int) -> np.ndarray:
        """[shard_scan_width] bool — live union slots of shard s (pads
        AND tombstones excluded)."""
        gids = self.shard_slot_gids(s)
        ok = gids >= 0
        ok[ok] &= self.alive[gids[ok]]
        return ok

    def shard_of(self, global_rows) -> np.ndarray:
        """Owning shard per global row id (map lookup — valid for base
        and delta rows alike)."""
        return self._gid_shard[np.asarray(global_rows, np.int64)]

    def locate(self, global_rows) -> tuple:
        """global ids -> (shard idx, position) arrays.  The position is
        a BASE slot for base-resident rows and a delta-run-local index
        for rows still pending in a delta (`_gid_in_delta`); use
        `gather_global` for ciphertext access that handles both."""
        gids = np.asarray(global_rows, np.int64)
        return self._gid_shard[gids], self._gid_pos[gids]

    # -- access ------------------------------------------------------------

    def shard(self, s: int) -> Table:
        """Shard s's BASE block as a plain `Table` view (per-shard index
        builds etc.)."""
        cols = {c: Ciphertext(ct.c0[s], ct.c1[s])
                for c, ct in self.columns.items()}
        return Table(f"{self.name}.s{s}", cols, int(self.shard_rows[s]))

    def gather(self, name: str, s: int, local_rows) -> Ciphertext:
        """Ciphertext rows of shard s's BASE block at local slots."""
        idx = np.asarray(local_rows, np.int64)
        ct = self.columns[name]
        return Ciphertext(ct.c0[s, idx], ct.c1[s, idx])

    def scan_stack(self, name: str) -> Ciphertext:
        """The named column over the UNION scan: `[S, shard_scan_width,
        ...]` — each shard's base block then its delta run, zero-padded
        to the common delta block (pad lanes are never decoded: the
        per-shard validity masks them before any host-side threshold).
        With no pending delta this is the base stack unchanged, so the
        fused launch shape — and its jit cache entry — is stable across
        the compacted steady state."""
        ct = self.columns[name]
        D = self.delta_block
        if D == 0:
            return ct
        S = self.num_shards
        dc0s, dc1s = [], []
        for s in range(S):
            d = self.deltas[s]
            z0 = jnp.zeros((D,) + ct.c0.shape[2:], ct.c0.dtype)
            z1 = jnp.zeros((D,) + ct.c1.shape[2:], ct.c1.dtype)
            if d is None:
                dc0s.append(z0)
                dc1s.append(z1)
            else:
                dct = d.columns[name]
                dc0s.append(z0.at[:dct.c0.shape[0]].set(dct.c0))
                dc1s.append(z1.at[:dct.c1.shape[0]].set(dct.c1))
        return Ciphertext(
            jnp.concatenate([ct.c0, jnp.stack(dc0s)], axis=1),
            jnp.concatenate([ct.c1, jnp.stack(dc1s)], axis=1))

    def gather_global(self, name: str, global_rows) -> Ciphertext:
        """Ciphertext rows at GLOBAL row ids (cross-shard projection;
        resolves base slots and pending delta rows alike)."""
        gids = np.asarray(global_rows, np.int64)
        ct = self.columns[name]
        s, pos = self._gid_shard[gids], self._gid_pos[gids]
        in_delta = self._gid_in_delta[gids]
        if not in_delta.any():
            return Ciphertext(ct.c0[s, pos], ct.c1[s, pos])
        c0 = jnp.zeros((gids.size,) + ct.c0.shape[2:], ct.c0.dtype)
        c1 = jnp.zeros((gids.size,) + ct.c1.shape[2:], ct.c1.dtype)
        bi = np.nonzero(~in_delta)[0]
        if bi.size:
            c0 = c0.at[bi].set(ct.c0[s[bi], pos[bi]])
            c1 = c1.at[bi].set(ct.c1[s[bi], pos[bi]])
        for sh in np.unique(s[in_delta]):
            di = np.nonzero(in_delta & (s == sh))[0]
            dct = self.deltas[int(sh)].columns[name]
            c0 = c0.at[di].set(dct.c0[pos[di]])
            c1 = c1.at[di].set(dct.c1[pos[di]])
        return Ciphertext(c0, c1)

    def decrypt_column(self, ks: KeySet, name: str) -> np.ndarray:
        """Client-side helper (tests only — needs sk): ALL rows of the
        global id space in id order (pending delta rows included;
        tombstoned rows included — filter with `alive`)."""
        ct = self.columns[name]
        vals = np.asarray(E.decrypt(
            ks, Ciphertext(ct.c0.reshape((-1,) + ct.c0.shape[2:]),
                           ct.c1.reshape((-1,) + ct.c1.shape[2:]))))
        vals = vals.reshape(self.num_shards, self.n_padded_per_shard)
        out = np.zeros(self.n_total, vals.dtype)
        base = ~self._gid_in_delta
        g = np.nonzero(base)[0]
        out[g] = vals[self._gid_shard[g], self._gid_pos[g]]
        for s in range(self.num_shards):
            if self.delta_rows(s):
                out[self._delta_gids[s]] = (
                    self.deltas[s].decrypt_column(ks, name))
        return out

    def __repr__(self) -> str:
        return (f"ShardedTable({self.name!r}, rows={self.n_rows}, "
                f"shards={self.num_shards}x{self.n_padded_per_shard}, "
                f"cols={list(self.columns)}, spec={self.spec}"
                + (f", delta={self.n_delta}" if self.has_delta else "")
                + ")")
