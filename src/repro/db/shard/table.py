"""`ShardedTable`: an encrypted column-store partitioned across shards.

Rows split into S contiguous, balanced chunks; every chunk pads to ONE
common power-of-two block size N_sp (`pad_rows_pow2` — the same helper
and sentinel-geometry `Table` uses), so each column is a single stacked
ciphertext `[S, N_sp, K, n]` whose leading dim places on the shard mesh
(`ShardSpec.place`).  Uneven partitions (non-power-of-two row counts)
just mean shards carry different validity masks over the same block
size — static shapes survive, which is what lets every fused filter
stage compile once and run shard-parallel.

Global row ids are the original ingest order: shard s owns the
contiguous id range [offsets[s], offsets[s+1]), so `from_table` — which
re-partitions an existing `Table`'s ciphertext ROWS without touching
plaintext — produces bit-identical per-row ciphertexts, the anchor of
the byte-level shard-invariance tests.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encrypt as E
from repro.core.compare import next_pow2
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db.shard.spec import ShardSpec
from repro.db.table import Table


def partition_offsets(n_rows: int, num_shards: int) -> np.ndarray:
    """[S+1] contiguous balanced split boundaries (first n%S chunks get
    the extra row)."""
    if not (1 <= num_shards <= n_rows):
        raise ValueError(
            f"num_shards {num_shards} outside [1, {n_rows}] rows")
    base, extra = divmod(n_rows, num_shards)
    sizes = np.full(num_shards, base, np.int64)
    sizes[:extra] += 1
    return np.concatenate([[0], np.cumsum(sizes)])


class ShardedTable:
    """Stacked encrypted columns `[S, N_sp, ...]` + partition bookkeeping."""

    def __init__(self, name: str, columns: Dict[str, Ciphertext],
                 offsets: np.ndarray, spec: ShardSpec):
        if not columns:
            raise ValueError("sharded table needs at least one column")
        shapes = {c: ct.c0.shape[:2] for c, ct in columns.items()}
        S, n_sp = next(iter(shapes.values()))
        if any(v != (S, n_sp) for v in shapes.values()):
            raise ValueError(f"ragged column stacks: {shapes}")
        if S != spec.num_shards:
            raise ValueError(f"stack has {S} shards, spec {spec.num_shards}")
        if n_sp != next_pow2(n_sp):
            raise ValueError(f"per-shard block {n_sp} not a power of two")
        self.name = name
        self.columns = dict(columns)
        self.offsets = np.asarray(offsets, np.int64)
        self.spec = spec
        self.shard_rows = np.diff(self.offsets)          # [S] valid counts
        if int(self.shard_rows.max()) > n_sp or int(self.shard_rows.min()) < 1:
            raise ValueError(
                f"shard sizes {self.shard_rows} outside (0, {n_sp}]")

    # -- construction ------------------------------------------------------

    @classmethod
    def from_arrays(cls, ks: KeySet, name: str,
                    data: Dict[str, np.ndarray], key: jax.Array, *,
                    spec: ShardSpec) -> "ShardedTable":
        """Encrypt host arrays straight into the sharded layout.

        Each shard's chunk encrypts under its own fold_in key via
        `Table.from_arrays` (one batched encrypt per column per shard),
        all padded to the common N_sp block.
        """
        n_rows = len(next(iter(data.values())))
        offsets = partition_offsets(n_rows, spec.num_shards)
        n_sp = next_pow2(int(np.diff(offsets).max()))
        stacks: Dict[str, list] = {c: [] for c in data}
        for s in range(spec.num_shards):
            lo, hi = int(offsets[s]), int(offsets[s + 1])
            chunk = {c: np.asarray(v)[lo:hi] for c, v in data.items()}
            t = Table.from_arrays(ks, f"{name}.s{s}", chunk,
                                  jax.random.fold_in(key, s), n_padded=n_sp)
            for c in data:
                stacks[c].append(t.columns[c])
        columns = {c: Ciphertext(jnp.stack([ct.c0 for ct in cts]),
                                 jnp.stack([ct.c1 for ct in cts]))
                   for c, cts in stacks.items()}
        return cls(name, spec.place(columns), offsets, spec)

    @classmethod
    def from_table(cls, ks: KeySet, table: Table, *,
                   spec: ShardSpec) -> "ShardedTable":
        """Re-partition an existing `Table`'s ciphertext rows (server-side:
        slices existing encryptions, pads with public-key encryptions of 0
        exactly like `Table` ingest — no plaintext access needed)."""
        offsets = partition_offsets(table.n_rows, spec.num_shards)
        n_sp = next_pow2(int(np.diff(offsets).max()))
        pad_key = jax.random.PRNGKey(0x5AAD)
        columns = {}
        for ci, (cname, ct) in enumerate(table.columns.items()):
            c0s, c1s = [], []
            for s in range(spec.num_shards):
                lo, hi = int(offsets[s]), int(offsets[s + 1])
                c0, c1 = ct.c0[lo:hi], ct.c1[lo:hi]
                if hi - lo < n_sp:
                    # same pad semantics as `Table` ingest (pad_rows_pow2
                    # with pad_value=0): genuine encryptions of 0, masked
                    # out by shard validity
                    pad = E.encrypt(
                        ks, jnp.zeros(n_sp - (hi - lo), jnp.int64),
                        jax.random.fold_in(pad_key, ci * 1024 + s))
                    c0 = jnp.concatenate([c0, pad.c0])
                    c1 = jnp.concatenate([c1, pad.c1])
                c0s.append(c0)
                c1s.append(c1)
            columns[cname] = Ciphertext(jnp.stack(c0s), jnp.stack(c1s))
        return cls(table.name, spec.place(columns), offsets, spec)

    # -- geometry ----------------------------------------------------------

    @property
    def num_shards(self) -> int:
        """Logical shard count S (the stacks' leading dim)."""
        return int(self.spec.num_shards)

    @property
    def n_rows(self) -> int:
        """Total valid rows across all shards (global id space size)."""
        return int(self.offsets[-1])

    @property
    def n_padded_per_shard(self) -> int:
        """The common power-of-two per-shard block size N_sp."""
        return next(iter(self.columns.values())).c0.shape[1]

    @property
    def column_names(self) -> tuple:
        """Names of the encrypted columns."""
        return tuple(self.columns)

    def shard_valid(self, s: int) -> np.ndarray:
        """[N_sp] bool — data slots of shard s."""
        return np.arange(self.n_padded_per_shard) < int(self.shard_rows[s])

    def ciphertext_bytes(self) -> int:
        """Storage footprint of all encrypted column stacks."""
        return sum(ct.c0.nbytes + ct.c1.nbytes
                   for ct in self.columns.values())

    # -- row-id algebra ----------------------------------------------------

    def global_ids(self, s: int) -> np.ndarray:
        """[N_sp] global row id per slot of shard s (-1 on pad slots)."""
        ids = np.arange(self.n_padded_per_shard) + int(self.offsets[s])
        return np.where(self.shard_valid(s), ids, -1)

    def locate(self, global_rows) -> tuple:
        """global ids -> (shard idx, local slot idx) arrays."""
        gids = np.asarray(global_rows, np.int64)
        s = np.searchsorted(self.offsets[1:], gids, side="right")
        return s, gids - self.offsets[s]

    # -- access ------------------------------------------------------------

    def shard(self, s: int) -> Table:
        """Shard s as a plain `Table` view (per-shard index builds etc.)."""
        cols = {c: Ciphertext(ct.c0[s], ct.c1[s])
                for c, ct in self.columns.items()}
        return Table(f"{self.name}.s{s}", cols, int(self.shard_rows[s]))

    def gather(self, name: str, s: int, local_rows) -> Ciphertext:
        """Ciphertext rows of shard s at local slot indices."""
        idx = np.asarray(local_rows, np.int64)
        ct = self.columns[name]
        return Ciphertext(ct.c0[s, idx], ct.c1[s, idx])

    def gather_global(self, name: str, global_rows) -> Ciphertext:
        """Ciphertext rows at GLOBAL row ids (cross-shard projection)."""
        s, slot = self.locate(global_rows)
        ct = self.columns[name]
        return Ciphertext(ct.c0[s, slot], ct.c1[s, slot])

    def decrypt_column(self, ks: KeySet, name: str) -> np.ndarray:
        """Client-side helper (tests only — needs sk): valid rows in
        global id order."""
        ct = self.columns[name]
        vals = np.asarray(E.decrypt(
            ks, Ciphertext(ct.c0.reshape((-1,) + ct.c0.shape[2:]),
                           ct.c1.reshape((-1,) + ct.c1.shape[2:]))))
        vals = vals.reshape(self.num_shards, self.n_padded_per_shard)
        return np.concatenate([vals[s, :int(self.shard_rows[s])]
                               for s in range(self.num_shards)])

    def __repr__(self) -> str:
        return (f"ShardedTable({self.name!r}, rows={self.n_rows}, "
                f"shards={self.num_shards}x{self.n_padded_per_shard}, "
                f"cols={list(self.columns)}, spec={self.spec})")
