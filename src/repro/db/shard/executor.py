"""Sharded plan executor: shard-parallel fused filtering + merge stages.

The execution model mirrors `db/executor.py` stage for stage, with the
shard dim threaded through every launch:

  1. FILTER.  All scan atoms of the plan stack into ONE raw-eval launch
     over the `[S, A, N_sp]` stacked columns.  On a usable shard mesh
     the launch runs under `shard_map` (`kernels.ops.shard_eval_values`,
     no cross-shard collectives — HADES eval is row-local); otherwise it
     is the same fused program on one device.  Decode thresholds apply
     host-side per shard per atom, exactly the single-device semantics.
  2. COMBINE.  The boolean tree folds per shard over per-shard leaf
     masks; global row masks come from the contiguous id map.
  3. ORDER / TOPK.  Per-shard bitonic networks + log-depth cross-shard
     merges (`shard/merge.py`) — a global top-k touches each shard for
     O(M·log²kp) compares and pays only O(kp·S·log kp) in the merge,
     never gathering all rows.
  4. LIMIT + PROJECT.  Global row ids slice/gather across shards.

`db.execute` dispatches here automatically when handed a `ShardedTable`,
so call sites are placement-agnostic.  Invariance contract: for any
plan, the decrypted answer (mask; ordered value sequence) is identical
for every shard count — `tests/test_db_shard.py` asserts it for
S ∈ {1, 2, 4} on both schemes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compare as C
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db import executor as X
from repro.db import plan as P
from repro.db.shard import merge as M
from repro.db.shard.table import ShardedTable


@dataclasses.dataclass
class ShardedExecStats(X.ExecStats):
    """ExecStats + shard attribution (benchmarks assert on the split)."""
    shards: int = 0
    mesh_devices: int = 1
    per_shard_scan_compares: int = 0     # one shard's slice of the scan
    per_shard_order_compares: int = 0    # per-shard sort/top-k phases
    merge_compares: int = 0              # cross-shard merge networks only


def sharded_fused_eval(ks: KeySet, stable: ShardedTable,
                       atoms: List[P.Atom], *,
                       engine: str = "jnp",
                       lane_budget: Optional[int] = None) -> np.ndarray:
    """RAW eval values for all atoms over all shards' fused scan:
    [S, A, shard_scan_width] int64 — each shard's lane covers its base
    block AND its pending delta run (`scan_stack`), so the write path
    never costs a second pass.  Thresholds are NOT applied here (same
    contract as `db.executor.fused_eval`).

    Same dedup + lane-tiling discipline as the single-table scan: each
    DISTINCT column's shard stack moves once ([S, U, N] bytes), the
    per-atom gather runs inside the program (under `shard_map` on a
    usable mesh — `sel` rides as a replicated operand), and the shard
    row axis tiles into power-of-two chunks with S·A·T lanes within the
    lane budget."""
    from repro.kernels import ops as KO
    with obs.span("shard.fused_eval", shards=stable.num_shards,
                  atoms=len(atoms), rows=stable.shard_scan_width) as sp:
        S, A = stable.num_shards, len(atoms)
        W = stable.shard_scan_width
        uniq, sel = X.dedup_atom_columns(stable, atoms, stable.scan_stack)
        bounds = X.stack_atom_bounds(atoms)
        T = KO.lane_tile(W, S * A, lane_budget)
        obs.count("bytes.moved", 2 * (uniq.c0.nbytes + bounds.c0.nbytes))
        use_kernel = X._use_kernel(engine)
        spec = stable.spec
        if spec.shard_map_ok:
            sp.set(shard_map=True)
        sel_j = jnp.asarray(sel)
        out = np.empty((S, A, W), dtype=np.int64)
        for lo in range(0, W, T):
            t = min(T, W - lo)
            with obs.span("shard.eval_tile", offset=lo, rows=t) as tsp:
                tile = Ciphertext(uniq.c0[:, :, lo:lo + t],
                                  uniq.c1[:, :, lo:lo + t])
                obs.jit_launch("shard.fused_eval", tile.c0, bounds.c0)
                obs.count("eval.launches")
                obs.count("eval.tiles")
                obs.count("eval.lanes", S * A * t)
                if spec.shard_map_ok:
                    vals = tsp.sync(KO.shard_eval_values(
                        ks, tile, bounds, mesh=spec.mesh,
                        axis_name=spec.axis, use_kernel=use_kernel,
                        sel=sel_j))
                elif use_kernel:
                    col = Ciphertext(jnp.take(tile.c0, sel_j, axis=1),
                                     jnp.take(tile.c1, sel_j, axis=1))
                    vals = tsp.sync(KO.broadcast_eval_values(ks, col,
                                                             bounds))
                else:
                    vals = tsp.sync(X.jitted_dedup_eval(ks, axis=1)(
                        tile.c0, tile.c1, sel_j, bounds.c0, bounds.c1))
                out[:, :, lo:lo + t] = np.asarray(vals)
        return out


def shard_delta_probe_index(ks: KeySet, stable: ShardedTable, column: str,
                            s: int, stats: ShardedExecStats):
    """Shard s's per-delta-run `SortedIndex` for an indexed union probe,
    with lazy-build compares attributed exactly once per delta state
    (the sharded twin of `db.executor.delta_probe_index`)."""
    cached = stable._delta_index_cache.get((column, s))
    fresh = not (cached is not None and cached[0] == stable.version)
    didx = stable.delta_index(ks, column, s)
    if didx is not None and fresh:
        stats.delta_build_compares += didx.build_compares
    return didx


def sharded_index_leaf_mask(ks: KeySet, stable: ShardedTable, idx, leaf,
                            stats: ShardedExecStats) -> List[np.ndarray]:
    """One indexed leaf over base ∪ delta, per shard, as
    [shard_scan_width] union-slot masks.  The base `ShardedIndex`
    fan-out search answers the base block; every shard with a pending
    delta run adds its own binary search (≤ 2·ceil(log2 d_s) compares)
    whose delta-local hits shift past the base block."""
    W = stable.shard_scan_width
    N0 = stable.n_padded_per_shard
    before = idx.search_compares
    if isinstance(leaf, P.Range):
        masks = idx.shard_masks_range(ks, leaf.lo, leaf.hi, W, eps=leaf.eps)
    else:
        masks = idx.shard_masks_eq(ks, leaf.value, W, eps=leaf.eps)
    stats.index_compares += idx.search_compares - before
    for s in range(stable.num_shards):
        didx = shard_delta_probe_index(ks, stable, leaf.column, s, stats)
        if didx is None:
            continue
        before = didx.search_compares
        if isinstance(leaf, P.Range):
            drows = didx.search_range(ks, leaf.lo, leaf.hi, eps=leaf.eps)
        else:
            drows = didx.point_lookup(ks, leaf.value, eps=leaf.eps)
        stats.index_compares += didx.search_compares - before
        masks[s][N0 + np.asarray(drows, np.int64)] = True
    return masks


def sharded_filter_masks(ks: KeySet, stable: ShardedTable,
                         plan: P.CompiledPlan, *,
                         indexes: Optional[Dict[str, object]] = None,
                         engine: str = "jnp",
                         lane_budget: Optional[int] = None,
                         stats: Optional[ShardedExecStats] = None,
                         ) -> List[List[np.ndarray]]:
    """Per-leaf, per-shard union-slot masks (width `shard_scan_width`):
    indexed leaves via the fan-out search + per-delta-run probes, the
    rest via one shard-parallel fused scan covering base AND delta."""
    stats = stats if stats is not None else ShardedExecStats()
    indexes = indexes or {}
    S, W = stable.num_shards, stable.shard_scan_width
    leaf_masks: List[Optional[List[np.ndarray]]] = [None] * plan.num_leaves
    scan_atoms: List[P.Atom] = []
    scan_slices: List[Tuple[int, int, int]] = []
    for i, leaf in enumerate(plan.leaves):
        idx = indexes.get(leaf.column)
        if idx is not None:
            if not hasattr(idx, "shard_masks_range"):
                raise TypeError(
                    f"index for column {leaf.column!r} is {type(idx).__name__}"
                    " — a ShardedTable needs ShardedIndex instances "
                    "(db.ShardedIndex.build), not single-table SortedIndex")
            leaf_masks[i] = sharded_index_leaf_mask(ks, stable, idx, leaf,
                                                    stats)
            stats.indexed_leaves += 1
        else:
            atoms = plan.scan_atoms(i)
            scan_slices.append((i, len(scan_atoms), len(atoms)))
            scan_atoms.extend(atoms)
            stats.scan_leaves += 1
    if scan_atoms:
        vals = sharded_fused_eval(ks, stable, scan_atoms, engine=engine,
                                  lane_budget=lane_budget)
        stats.eval_calls += 1
        stats.scan_compares += len(scan_atoms) * S * W
        stats.per_shard_scan_compares += len(scan_atoms) * W
        for leaf_i, start, count in scan_slices:
            leaf_masks[leaf_i] = [
                X.scan_leaf_mask(ks, scan_atoms, vals[s], start, count)
                for s in range(S)]
    return leaf_masks  # type: ignore[return-value]


def combine_shard_masks(stable: ShardedTable, plan: P.CompiledPlan,
                        leaf_masks: List[List[np.ndarray]]) -> np.ndarray:
    """Fold the boolean tree per shard over union slots, then lift to a
    global row mask over the full id space (`n_total`); pads and
    tombstones drop out via `shard_slot_valid`."""
    W = stable.shard_scan_width
    mask = np.zeros(stable.n_total, bool)
    for s in range(stable.num_shards):
        per_leaf = [lm[s] for lm in leaf_masks]
        m = X.combine_tree(plan.tree, per_leaf, W)
        m &= stable.shard_slot_valid(s)
        gids = stable.shard_slot_gids(s)
        mask[gids[m]] = True
    return mask


# ---------------------------------------------------------------------------
# order / top-k via per-shard networks + cross-shard merges
# ---------------------------------------------------------------------------

def _shard_candidates(ks: KeySet, stable: ShardedTable, column: str,
                      row_ids: np.ndarray, *, block: int,
                      pad_value: int) -> Tuple[Ciphertext, np.ndarray, int]:
    """Matched rows grouped by owning shard, padded to `block` per shard
    and flattened for the merge networks.  Returns (ct, ids, num_blocks).
    `gather_global` resolves base slots and pending delta rows alike."""
    s_idx = stable.shard_of(row_ids)
    num_blocks = C.next_pow2(stable.num_shards)
    per_shard = []
    for s in range(stable.num_shards):
        sel = s_idx == s
        per_shard.append((stable.gather_global(column, row_ids[sel]),
                          row_ids[sel]))
    ct, ids = M.pad_shard_blocks(ks, per_shard, block=block,
                                 pad_value=pad_value,
                                 num_blocks=num_blocks)
    return ct, ids, num_blocks


def order_rows_sharded(ks: KeySet, stable: ShardedTable, query: P.Query,
                       row_ids: np.ndarray,
                       stats: ShardedExecStats) -> np.ndarray:
    """TopK / OrderBy / Limit over globally-matched row ids, resolved
    per shard with cross-shard merge stages."""
    n_sel = int(row_ids.shape[0])
    cmp = X.jitted_comparator(ks)
    if query.top_k is not None and n_sel:
        k = min(query.top_k.k, n_sel)
        kp = C.next_pow2(k)
        with obs.span("shard.order", kind="topk", rows=n_sel, k=k):
            counts = np.bincount(stable.shard_of(row_ids),
                                 minlength=stable.num_shards)
            block = max(C.next_pow2(int(counts.max())), kp)
            ct, ids, nb = _shard_candidates(
                ks, stable, query.top_k.column, row_ids, block=block,
                pad_value=-(ks.params.max_operand // 2))
            top, n_shard, n_merge = M.sharded_topk(ks, cmp, ct, ids,
                                                   num_blocks=nb, k=k)
            if np.any(top < 0):
                # a real row tied the sentinel and coin-flipped out —
                # rare; re-resolve through the tie-robust sort path
                # (id-stripped), exactly core encrypted_topk's fallback
                sub = stable.gather_global(query.top_k.column, row_ids)
                _, sel = C._topk_via_sort(ks, sub, k, cmp, None)
                top = row_ids[np.asarray(sel)]
        stats.per_shard_order_compares += n_shard
        stats.merge_compares += n_merge
        stats.order_compares += n_shard + n_merge
        obs.count("eval.lanes", n_shard + n_merge)
        row_ids = np.asarray(top)
    elif query.order_by is not None and n_sel:
        with obs.span("shard.order", kind="sort", rows=n_sel):
            counts = np.bincount(stable.shard_of(row_ids),
                                 minlength=stable.num_shards)
            block = C.next_pow2(int(counts.max()))
            ct, ids, nb = _shard_candidates(
                ks, stable, query.order_by.column, row_ids, block=block,
                pad_value=ks.params.max_operand // 2)
            ordered, n_shard, n_merge = M.sharded_sort(ks, cmp, ct, ids,
                                                       num_blocks=nb)
        stats.per_shard_order_compares += n_shard
        stats.merge_compares += n_merge
        stats.order_compares += n_shard + n_merge
        obs.count("eval.lanes", n_shard + n_merge)
        row_ids = ordered[::-1] if query.order_by.descending else ordered
    limit = query.limit_count
    if limit is not None:
        row_ids = row_ids[:limit]
    return row_ids


def execute_sharded(ks: KeySet, stable: ShardedTable, query, *,
                    indexes: Optional[Dict[str, object]] = None,
                    engine: str = "jnp",
                    lane_budget: Optional[int] = None) -> X.QueryResult:
    """Run a Query (or bare predicate / precompiled plan) against a
    ShardedTable.  Same result contract as `db.execute` (`lane_budget`
    caps the fused scan's per-launch eval lanes, None = shared policy)."""
    if isinstance(query, (P.Query, P.Predicate)):
        plan = P.compile_plan(query)
    elif isinstance(query, P.CompiledPlan):
        plan = query
    else:
        raise TypeError(f"cannot execute {query!r}")
    stats = ShardedExecStats(shards=stable.num_shards,
                             mesh_devices=stable.spec.mesh_devices)
    with obs.span("shard.execute", shards=stable.num_shards,
                  leaves=plan.num_leaves):
        leaf_masks = sharded_filter_masks(ks, stable, plan, indexes=indexes,
                                          engine=engine,
                                          lane_budget=lane_budget,
                                          stats=stats)
        mask = combine_shard_masks(stable, plan, leaf_masks)
        row_ids = np.nonzero(mask)[0]
        row_ids = order_rows_sharded(ks, stable, plan.query, row_ids, stats)
        columns = {c: stable.gather_global(c, row_ids)
                   for c in plan.query.select}
    if obs.is_enabled() and stable.n_rows:
        obs.observe("pad.waste",
                    stable.num_shards * stable.n_padded_per_shard
                    / stable.n_rows)
        obs.absorb_exec_stats(stats)
    return X.QueryResult(row_ids=row_ids, mask=mask, columns=columns,
                         stats=stats)
