"""Cross-shard encrypted merge networks (sort / top-k over shard blocks).

A sharded `OrderBy`/`TopK` never gathers all rows to one sort: each
shard first resolves its own candidates with a LOCAL bitonic network
(all shards riding the same batched Eval stages — the flattened
`[S·M, ...]` stack tiles block-local compare-exchanges across shards),
then a log₂S-depth cross-shard merge combines the per-shard results:

  * top-k:  per-shard partial bitonic top-k down to one descending
    kp-block per shard, then the max-merge TOURNAMENT continues across
    shard boundaries — merge overhead is (S-1)·(kp + kp/2·log₂kp)
    compares on k-sized blocks, independent of n.
  * sort:   per-shard full bitonic sort, then log₂S pairwise sorted-run
    merges (the half-cleaner + bitonic-merge network: each round is
    L/2·(1+log₂L) compares per pair on runs of length L) — O(n log n·
    log S) merge compares versus the O(n log² n) of re-sorting.

Everything runs on the `core.compare` compare-exchange machinery
(`_compare_swap` / `_bitonic_pairs` / `_block_pairs`), so stage
semantics — including FAE tie coin-flips and id-based (never value-
based) sentinel stripping — are definitionally identical to the
single-device `encrypted_sort` / `encrypted_topk`.

All functions take a FLATTENED `[S·M]` ciphertext whose blocks are the
shards' padded candidate lists plus an `ids` array carrying global row
ids (-1 on sentinel pads); compare counts come back split into the
per-shard phase and the cross-shard merge phase so benchmarks and stats
can attribute them.  Shard counts that are not powers of two are padded
with all-sentinel blocks by the caller (`pad_shard_blocks`).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compare as C
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet


def _obs_stage(site: str, glo) -> None:
    """Launch accounting for one compare-exchange stage (one batched
    Eval over `glo.shape[0]` lanes); no-op unless obs is enabled."""
    if not obs.is_enabled():
        return
    obs.jit_launch(site, (int(glo.shape[0]),))
    obs.count("eval.launches")
    obs.count("eval.lanes", int(glo.shape[0]))


def shard_block_sort(ks: KeySet, cmp: Callable, c0, c1, ids, *,
                     block: int, descending: bool = False) -> Tuple[
                         jax.Array, jax.Array, jax.Array, int]:
    """Sort each contiguous `block`-sized run independently — every stage
    of the tiled bitonic network is ONE batched Eval across all runs."""
    n = c0.shape[0]
    assert n % block == 0
    compares = 0
    with obs.span("merge.block_sort", rows=int(n), block=int(block)):
        for lo, hi, asc in C._bitonic_pairs(block):
            flags = ~asc if descending else asc
            glo, ghi, gasc = C._block_pairs(n // block, block, lo, hi, flags)
            _obs_stage("merge.block_sort", glo)
            c0, c1, ids = C._compare_swap(ks, cmp, c0, c1, ids,
                                          glo, ghi, gasc)
            compares += int(glo.shape[0])
    return c0, c1, ids, compares


def merge_sorted_runs(ks: KeySet, cmp: Callable, c0, c1, ids, *,
                      run: int) -> Tuple[jax.Array, jax.Array, jax.Array,
                                         int]:
    """Merge equal-length ascending runs pairwise until ONE ascending run
    remains (log₂(n/run) rounds, every stage one batched Eval).

    Round structure per pair of runs (a, b) of length L: the half-cleaner
    compare-exchanges a[i] against b[L-1-i] (after which max(a') <=
    min(b') and both halves are bitonic), then each half bitonic-merges
    in log₂L strides — L·(1+log₂L) compares per pair-merge.
    """
    n = c0.shape[0]
    assert n % run == 0 and n // run == C.next_pow2(n // run)
    compares = 0
    while run < n:
        with obs.span("merge.round", run=int(run), rows=int(n)):
            pairs = n // (2 * run)
            i = np.arange(run)
            # half-cleaner: a[i] vs b[run-1-i], smaller stays in a
            glo, ghi, gasc = C._block_pairs(pairs, 2 * run,
                                            i, 2 * run - 1 - i,
                                            np.ones(run, bool))
            _obs_stage("merge.round", glo)
            c0, c1, ids = C._compare_swap(ks, cmp, c0, c1, ids,
                                          glo, ghi, gasc)
            compares += int(glo.shape[0])
            stride = run // 2
            while stride >= 1:
                within = np.arange(run)
                p = within[(within & stride) == 0]
                glo, ghi, gasc = C._block_pairs(2 * pairs, run,
                                                p, p + stride,
                                                np.ones(p.shape[0], bool))
                _obs_stage("merge.round", glo)
                c0, c1, ids = C._compare_swap(ks, cmp, c0, c1, ids,
                                              glo, ghi, gasc)
                compares += int(glo.shape[0])
                stride //= 2
            run *= 2
    return c0, c1, ids, compares


def topk_tournament(ks: KeySet, cmp: Callable, c0, c1, ids, *, kp: int,
                    stop_blocks: int = 1) -> Tuple[
                        jax.Array, jax.Array, jax.Array, int]:
    """`encrypted_topk`'s max-merge tournament over descending kp-blocks,
    run until `stop_blocks` blocks survive.

    With stop_blocks=S it realizes the per-shard phase (blocks pair only
    within their shard: shard regions are contiguous with a power-of-two
    block count, and compaction keeps them contiguous); continuing with
    stop_blocks=1 is the cross-shard merge phase.
    """
    n_live = c0.shape[0]
    assert n_live % kp == 0
    compares = 0
    while n_live > stop_blocks * kp:
        with obs.span("merge.topk_round", live=int(n_live), kp=int(kp)):
            blocks = n_live // kp
            j = jnp.arange(blocks // 2)
            i = jnp.arange(kp)
            lo_idx = ((2 * j * kp)[:, None] + i[None, :]).ravel()
            hi_idx = (((2 * j + 1) * kp)[:, None]
                      + (kp - 1 - i)[None, :]).ravel()
            keep_larger = jnp.zeros(lo_idx.shape[0], bool)
            _obs_stage("merge.topk_round", lo_idx)
            c0, c1, ids = C._compare_swap(ks, cmp, c0, c1, ids,
                                          lo_idx, hi_idx, keep_larger)
            compares += int(lo_idx.shape[0])
            c0, c1, ids = c0[lo_idx], c1[lo_idx], ids[lo_idx]
            n_live //= 2
            stride = kp // 2
            while stride >= 1:
                within = jnp.arange(kp)
                p = within[(within & stride) == 0]
                glo, ghi, gasc = C._block_pairs(n_live // kp, kp,
                                                p, p + stride,
                                                jnp.zeros(p.shape[0], bool))
                _obs_stage("merge.topk_round", glo)
                c0, c1, ids = C._compare_swap(ks, cmp, c0, c1, ids,
                                              glo, ghi, gasc)
                compares += int(glo.shape[0])
                stride //= 2
    return c0, c1, ids, compares


# ---------------------------------------------------------------------------
# shard-level entry points
# ---------------------------------------------------------------------------

def pad_shard_blocks(ks: KeySet, per_shard: list, *, block: int,
                     pad_value: int, num_blocks: int) -> Tuple[Ciphertext,
                                                               np.ndarray]:
    """Stack per-shard (Ciphertext, global-id array) candidate lists into
    one flattened `[num_blocks·block]` column.

    Each shard's list pads to `block` rows with encrypted `pad_value`
    sentinels (same public-key sentinel construction as `encrypted_sort`
    padding); missing shards (num_blocks = next_pow2(S) > S) become
    all-sentinel blocks.  Pad slots carry id -1 — stripping is by id,
    never by value, exactly the core networks' tie-robust contract.
    """
    from repro.core import encrypt as E
    pad_key = jax.random.PRNGKey(0x5A4D)
    c0s, c1s, ids = [], [], []
    for s in range(num_blocks):
        ct, gids = (per_shard[s] if s < len(per_shard)
                    else (None, np.zeros(0, np.int64)))
        m = int(np.asarray(gids).shape[0])
        assert m <= block
        parts0 = [ct.c0] if m else []
        parts1 = [ct.c1] if m else []
        if m < block:
            pad = E.encrypt(ks, jnp.full((block - m,), pad_value, jnp.int64),
                            jax.random.fold_in(pad_key, s))
            parts0.append(pad.c0)
            parts1.append(pad.c1)
        c0s.append(jnp.concatenate(parts0) if len(parts0) > 1 else parts0[0])
        c1s.append(jnp.concatenate(parts1) if len(parts1) > 1 else parts1[0])
        ids.append(np.concatenate([np.asarray(gids, np.int64),
                                   np.full(block - m, -1, np.int64)]))
    return (Ciphertext(jnp.concatenate(c0s), jnp.concatenate(c1s)),
            np.concatenate(ids))


def sharded_topk(ks: KeySet, cmp: Callable, ct: Ciphertext,
                 ids: np.ndarray, *, num_blocks: int,
                 k: int) -> Tuple[np.ndarray, int, int]:
    """Global descending top-k over per-shard candidate blocks.

    ct/ids: flattened `[num_blocks·M]` stack from `pad_shard_blocks`
    (M a power-of-two multiple of kp = next_pow2(k)).  Returns
    (top-k global ids — may contain -1 if a sentinel coin-flipped its
    way in, caller re-resolves via the tie-robust sort path —,
    per-shard-phase compares, cross-shard merge compares).
    """
    n = ct.c0.shape[0]
    M = n // num_blocks
    kp = C.next_pow2(k)
    assert M % kp == 0 and M == C.next_pow2(M)
    c0, c1 = ct.c0, ct.c1
    gid = jnp.asarray(ids)
    # per-shard phase: descending kp-block sorts, then tournament down to
    # ONE block per shard — every stage batched across all shards
    c0, c1, gid, n_sort = shard_block_sort(ks, cmp, c0, c1, gid,
                                           block=kp, descending=True)
    c0, c1, gid, n_tour = topk_tournament(ks, cmp, c0, c1, gid, kp=kp,
                                          stop_blocks=num_blocks)
    # cross-shard merge: the same tournament, now pairing across shards
    c0, c1, gid, n_merge = topk_tournament(ks, cmp, c0, c1, gid, kp=kp,
                                           stop_blocks=1)
    return np.asarray(gid[:k]), n_sort + n_tour, n_merge


def sharded_sort(ks: KeySet, cmp: Callable, ct: Ciphertext,
                 ids: np.ndarray, *, num_blocks: int) -> Tuple[
                     np.ndarray, int, int]:
    """Globally ascending row ids via per-shard sorts + log-depth merge.

    ct/ids: flattened `[num_blocks·M]` stack from `pad_shard_blocks`
    with ascending sentinels (+max_operand//2).  Returns (real row ids
    ascending by value — sentinels stripped BY ID —, per-shard-phase
    compares, cross-shard merge compares).
    """
    n = ct.c0.shape[0]
    M = n // num_blocks
    c0, c1 = ct.c0, ct.c1
    gid = jnp.asarray(ids)
    c0, c1, gid, n_sort = shard_block_sort(ks, cmp, c0, c1, gid, block=M)
    c0, c1, gid, n_merge = merge_sorted_runs(ks, cmp, c0, c1, gid, run=M)
    gid = np.asarray(gid)
    return gid[gid >= 0], n_sort, n_merge
