"""`ShardSpec`: how a sharded table maps logical shards onto devices.

The shard count is a LOGICAL choice (how the rows partition, how many
merge lanes the cross-shard networks get) and is deliberately decoupled
from the physical device count: the same 4-shard table runs 4-way on a
TPU slice, 2-way on a 2-device host, and on a single CPU device — query
answers are identical in all three placements (the shard-invariance
contract tests/test_db_shard.py asserts).

Placement reuses the launch/parallel machinery: `launch.mesh.
make_shard_mesh` builds the 1-D device mesh and `parallel.sharding.
shard_leading` pins `[S, ...]` ciphertext stacks to it.  When the shard
count divides the mesh axis the fused filter launches run under
`shard_map` (`kernels.ops.shard_eval_values`); otherwise execution falls
back to one fused launch on the default device with no semantic change.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass(frozen=True, eq=False)
class ShardSpec:
    """S logical shards + an optional 1-D device mesh to place them on."""
    num_shards: int
    mesh: Optional[Any] = None          # jax.sharding.Mesh with `axis`
    axis: str = "shard"

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1: {self.num_shards}")

    @classmethod
    def create(cls, num_shards: int, *, use_mesh: bool = True,
               axis: str = "shard") -> "ShardSpec":
        """Spec over the local devices (the common entry point).

        `use_mesh=False` keeps everything on the default device — useful
        for differential testing of the placement itself.
        """
        mesh = None
        if use_mesh:
            from repro.launch.mesh import make_shard_mesh
            mesh = make_shard_mesh(num_shards, axis=axis)
        return cls(num_shards=num_shards, mesh=mesh, axis=axis)

    # -- placement geometry -------------------------------------------------

    @property
    def mesh_devices(self) -> int:
        """Devices on the shard axis (1 when meshless)."""
        return int(self.mesh.shape[self.axis]) if self.mesh is not None else 1

    @property
    def placeable(self) -> bool:
        """Can a [S, ...] stack split evenly over the mesh axis?"""
        return (self.mesh is not None
                and self.num_shards % self.mesh_devices == 0)

    @property
    def shard_map_ok(self) -> bool:
        """Run fused launches under shard_map (needs >1 device AND even
        placement; a 1-device mesh gains nothing over plain jit)."""
        return self.placeable and self.mesh_devices > 1

    def place(self, tree):
        """Pin every [S, ...] array leaf's leading dim to the mesh.  A
        no-op when the spec has no usable mesh, so callers never branch."""
        if not self.placeable or self.mesh_devices == 1:
            return tree
        from repro.parallel.sharding import shard_leading
        return shard_leading(self.mesh, tree, self.axis)

    def __repr__(self) -> str:
        return (f"ShardSpec(shards={self.num_shards}, "
                f"devices={self.mesh_devices}, axis={self.axis!r})")
