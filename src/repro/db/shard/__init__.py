"""repro.db.shard — mesh-sharded encrypted tables for the query engine.

Partitions ciphertext rows across logical shards placed on a 1-D device
mesh (`ShardSpec`, decoupled from physical devices), runs fused filter
stages shard-parallel under `shard_map`, resolves OrderBy/TopK with
per-shard bitonic networks + log-depth cross-shard merge networks, and
fans lookups out over per-shard sorted indexes in one lane-batched
launch.  Invariance contract: decrypted query answers are independent
of the shard count and the placement.

    ShardSpec          — logical shard count + optional device mesh
    ShardedTable       — [S, N_sp, ...] stacked encrypted columns
    ShardedIndex       — per-shard SortedIndexes, fan-out binary search
    execute_sharded    — the sharded plan executor (db.execute dispatches
                         here automatically for ShardedTable arguments)
    execute_join_sharded — cross-shard joins on the [S_l, S_r] pair grid
                         (db.execute_join dispatches here automatically)
    ShardedQueryServer — K queries x S shards in one vectorized pass
"""
from repro.db.shard.executor import (  # noqa: F401
    ShardedExecStats,
    execute_sharded,
    sharded_fused_eval,
)
from repro.db.shard.index import ShardedIndex  # noqa: F401
from repro.db.shard.join import (  # noqa: F401
    execute_join_sharded,
    sharded_pair_eval,
)
from repro.db.shard.serve import (  # noqa: F401
    ShardedBatchStats,
    ShardedQueryServer,
)
from repro.db.shard.spec import ShardSpec  # noqa: F401
from repro.db.shard.table import ShardedTable  # noqa: F401
