"""`ShardedQueryServer`: K client queries × S shards in one pass.

The `db.query_serve.QueryServer` queue/batch pattern lifted onto a
`ShardedTable`: a drained batch of K queries routes to ALL shards in a
single vectorized sweep —

  * every scan atom of every query joins ONE `[S, ΣA_i, N_sp]`
    shard-parallel raw-eval launch (`shard_map` on a usable mesh);
  * every index-eligible leaf joins ONE fan-out binary search per
    indexed column — the `[S, 2K]` probe grid resolves all queries'
    boundary lanes against all shards' indexes together, each step one
    batched Eval;
  * per-query combine / merge-order stages then run on each query's
    global mask (cross-shard top-k and order-by via the merge networks).

So K clients querying an S-shard table still cost one fused filter
launch + one lane-batched search per indexed column per batch — the
shard dim rides inside the launches instead of multiplying them.

MUTATIONS interleave exactly as on the single-table server
(`query_serve.QueryServer`): same-kind runs drain in submit order,
query batches answer over base ∪ delta (the shard-parallel scan widens
by the delta block; the fan-out searches add one per-delta-run search
per column per shard holding pending rows), and `compact()` /
`compact_threshold` retire deltas cooperatively between batches through
the per-shard merge networks.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.ckks import eps_to_tau
from repro.core.keys import KeySet
from repro.db import executor as X
from repro.db import plan as P
from repro.db.index import _stack_cts
from repro.db.query_serve import MutationResult, _QueuedMutation
from repro.db.shard import executor as SX
from repro.db.shard.index import ShardedIndex
from repro.db.shard.table import ShardedTable


@dataclasses.dataclass
class ShardedBatchStats:
    """Shared-launch accounting for one drained batch across all shards
    (the fused shard-parallel Eval and the fan-out searches count ONCE
    here; per-query shares live on each result's own stats)."""
    queries: int = 0
    shards: int = 0
    eval_calls: int = 0
    scan_compares: int = 0
    per_shard_scan_compares: int = 0
    index_compares: int = 0
    delta_build_compares: int = 0
    merge_compares: int = 0
    wall_s: float = 0.0


class ShardedQueryServer:
    """Queue + batch executor over one sharded encrypted table."""

    def __init__(self, ks: KeySet, stable: ShardedTable, *,
                 indexes: Optional[Dict[str, ShardedIndex]] = None,
                 batch: int = 4, engine: str = "jnp",
                 compact_threshold: Optional[int] = None,
                 lane_budget: Optional[int] = None):
        self.ks = ks
        self.stable = stable
        self.indexes = indexes or {}
        self.batch = int(batch)
        self.engine = engine
        self.compact_threshold = compact_threshold
        # per-launch eval-lane cap for the shard-parallel fused scans
        # (None = the kernels.ops policy default)
        self.lane_budget = lane_budget
        self._queue: List[Tuple[int, P.Query]] = []
        self._next_id = 0
        self.batch_log: List[ShardedBatchStats] = []
        self.compaction_log: list = []
        self._tenants: Dict[int, str] = {}     # request id -> tenant label

    # -- queue -------------------------------------------------------------

    def _enqueue(self, item, tenant: Optional[str]) -> int:
        """Assign the next request id, remember its tenant, enqueue."""
        qid = self._next_id
        self._next_id += 1
        if tenant is not None:
            self._tenants[qid] = tenant
        self._queue.append((qid, item))
        return qid

    def clear_queue(self) -> int:
        """Drop every queued, not-yet-drained request; returns how many
        were dropped.  The fault-recovery reset: after `run()` raises,
        the queue may hold a partially-consumed drain — callers that
        retry (e.g. `ServeLoop`) clear it before re-submitting."""
        dropped = len(self._queue)
        self._queue = []
        return dropped

    @contextlib.contextmanager
    def batch_size(self, n: int):
        """Temporarily set the drain batch size (restored on exit, even
        if the drain raises) — how `ServeLoop` runs a drafted batch as
        ONE shared launch without clobbering the configured size."""
        old, self.batch = self.batch, max(1, int(n))
        try:
            yield self
        finally:
            self.batch = old

    def _bill_tenant(self, qid: int, stats) -> None:
        """Per-tenant served-query + compare-lane attribution (counted
        only when the obs layer is enabled)."""
        if not obs.is_enabled():
            return
        tenant = self._tenants.get(qid, "default")
        obs.count("server.queries", 1, tenant=tenant)
        obs.count("server.compares", stats.filter_compares, tenant=tenant)

    def submit(self, query, *, tenant: Optional[str] = None) -> int:
        """Enqueue a Query (or bare predicate); returns a request id.
        `tenant` labels the request for per-tenant metrics attribution."""
        if isinstance(query, P.Predicate):
            query = P.Query(where=query)
        return self._enqueue(query, tenant)

    def submit_insert(self, data, key, *,
                      tenant: Optional[str] = None) -> int:
        """Enqueue an insert (routed to the least-loaded shards' delta
        runs); resolves to a `MutationResult` with the new global ids."""
        return self._enqueue(_QueuedMutation("insert", data=data, key=key),
                             tenant)

    def submit_delete(self, rows, *, tenant: Optional[str] = None) -> int:
        """Enqueue a tombstone of global row ids; resolves to a
        `MutationResult` with the newly-dead count."""
        return self._enqueue(_QueuedMutation(
            "delete", rows=np.asarray(rows, np.int64)), tenant)

    def submit_update(self, rows, data, key, *,
                      tenant: Optional[str] = None) -> int:
        """Enqueue an update (tombstone + re-insert); resolves to a
        `MutationResult` with the replacement global ids."""
        return self._enqueue(_QueuedMutation(
            "update", rows=np.asarray(rows, np.int64), data=data, key=key),
            tenant)

    def run(self) -> Dict[int, X.QueryResult]:
        """Drain the queue in submit order: maximal same-kind runs —
        query runs in shared-launch batches, mutation runs sequentially
        (reads observe exactly the writes submitted before them), with
        `compact_threshold` optionally triggering a cooperative
        compaction after a mutation run."""
        results: Dict[int, X.QueryResult] = {}
        while self._queue:
            is_mut = isinstance(self._queue[0][1], _QueuedMutation)
            n = 1
            while (n < len(self._queue) and isinstance(
                    self._queue[n][1], _QueuedMutation) == is_mut):
                n += 1
            chunk, self._queue = self._queue[:n], self._queue[n:]
            if is_mut:
                for qid, m in chunk:
                    results[qid] = self._apply_mutation(m)
                if (self.compact_threshold is not None
                        and self.stable.n_delta >= self.compact_threshold):
                    self.compact()
            else:
                for i in range(0, len(chunk), self.batch):
                    results.update(self._run_batch(chunk[i:i + self.batch]))
        return results

    # -- mutations ---------------------------------------------------------

    def _apply_mutation(self, m: _QueuedMutation) -> MutationResult:
        stable = self.stable
        with obs.span("server.mutation", kind=m.kind):
            deleted = 0
            if m.rows is not None:
                deleted = stable.delete(m.rows)
            row_ids = np.zeros(0, np.int64)
            if m.data is not None:
                row_ids = stable.insert(self.ks, m.data, m.key)
        return MutationResult(m.kind, row_ids, deleted=deleted)

    def compact(self):
        """Retire all shards' pending delta runs between batches: per
        shard, fold delta onto base and merge the (base run, delta run)
        pair of every served `ShardedIndex` through the log-depth merge
        network.  Returns the `CompactionStats` (also appended to
        `compaction_log`)."""
        from repro.db.delta import compact as _compact
        stats = _compact(self.ks, self.stable, self.indexes)
        self.compaction_log.append(stats)
        return stats

    # -- batch execution ---------------------------------------------------

    def _run_batch(self, chunk: List[Tuple[int, P.Query]],
                   ) -> Dict[int, X.QueryResult]:
        with obs.span("server.shard_batch", size=len(chunk),
                      shards=self.stable.num_shards) as bsp:
            return self._run_batch_traced(chunk, bsp)

    def _run_batch_traced(self, chunk: List[Tuple[int, P.Query]], bsp,
                          ) -> Dict[int, X.QueryResult]:
        t0 = time.perf_counter()
        ks, stable = self.ks, self.stable
        S, N = stable.num_shards, stable.n_padded_per_shard
        W = stable.shard_scan_width   # base block ∪ pending delta block
        plans = [(qid, P.compile_plan(q)) for qid, q in chunk]
        bstats = ShardedBatchStats(queries=len(chunk), shards=S)

        # partition leaves into fan-out index lanes vs scan atoms
        scan_atoms: List[P.Atom] = []
        scan_ref: List[Tuple[int, int, int, int]] = []
        lane_cts: Dict[str, list] = {}
        lane_strict: Dict[str, list] = {}
        lane_taus: Dict[str, list] = {}
        lane_ref: Dict[str, list] = {}
        for pi, (_, plan) in enumerate(plans):
            for li, leaf in enumerate(plan.leaves):
                idx = self.indexes.get(leaf.column)
                if idx is not None:
                    lo, hi = ((leaf.lo, leaf.hi)
                              if isinstance(leaf, P.Range)
                              else (leaf.value, leaf.value))
                    tau = (ks.params.tau if leaf.eps is None
                           else eps_to_tau(ks.params, leaf.eps))
                    lane_cts.setdefault(leaf.column, []).extend([lo, hi])
                    lane_strict.setdefault(leaf.column, []).extend(
                        [False, True])
                    lane_taus.setdefault(leaf.column, []).extend([tau, tau])
                    lane_ref.setdefault(leaf.column, []).append((pi, li))
                else:
                    atoms = plan.scan_atoms(li)
                    scan_ref.append((pi, li, len(scan_atoms), len(atoms)))
                    scan_atoms.extend(atoms)

        leaf_masks: List[List[Optional[List[np.ndarray]]]] = [
            [None] * plan.num_leaves for _, plan in plans]
        qstats = [SX.ShardedExecStats(shards=S,
                                      mesh_devices=stable.spec.mesh_devices)
                  for _ in plans]

        # ONE fan-out search per indexed column: all queries' boundary
        # lanes against all shards' indexes together ([S, 2K] probe
        # grid); every shard holding a pending delta run adds ONE more
        # lane-batched search against its own per-run index
        for column, cts in lane_cts.items():
            idx = self.indexes[column]
            lanes = _stack_cts(cts)
            strict = np.asarray(lane_strict[column])
            taus = np.asarray(lane_taus[column], np.int64)
            before = idx.search_compares
            pos = idx.search(ks, lanes, strict, taus)
            bstats.index_compares += idx.search_compares - before
            base_counts = idx.last_probe_counts.copy()
            dsearch = {}
            for s in range(S):
                didx = SX.shard_delta_probe_index(ks, stable, column, s,
                                                  bstats)
                if didx is None:
                    continue
                before = didx.search_compares
                dsearch[s] = (didx, didx.search(ks, lanes, strict, taus),
                              didx.last_probe_counts.copy())
                bstats.index_compares += didx.search_compares - before
            for j, (pi, li) in enumerate(lane_ref[column]):
                masks = idx.lane_masks(pos, j, W)
                # per-query share of the shared launches: this query's
                # two boundary lanes, base fan-out AND every delta-run
                # search (sums across queries reconcile with bstats)
                qstats[pi].index_compares += int(
                    base_counts[2 * j] + base_counts[2 * j + 1])
                for s, (didx, dpos, dcounts) in dsearch.items():
                    dl, dr = int(dpos[2 * j]), int(dpos[2 * j + 1])
                    masks[s][N + np.asarray(didx.perm[dl:dr],
                                            np.int64)] = True
                    qstats[pi].index_compares += int(
                        dcounts[2 * j] + dcounts[2 * j + 1])
                leaf_masks[pi][li] = masks
                qstats[pi].indexed_leaves += 1

        # ONE shard-parallel fused Eval for every scan atom in the batch
        # (over the union scan width — base blocks AND delta runs)
        if scan_atoms:
            vals = SX.sharded_fused_eval(ks, stable, scan_atoms,
                                         engine=self.engine,
                                         lane_budget=self.lane_budget)
            bstats.eval_calls += 1
            bstats.scan_compares += len(scan_atoms) * S * W
            bstats.per_shard_scan_compares += len(scan_atoms) * W
            for pi, li, start, count in scan_ref:
                leaf_masks[pi][li] = [
                    X.scan_leaf_mask(ks, scan_atoms, vals[s], start, count)
                    for s in range(S)]
                qstats[pi].scan_leaves += 1
                qstats[pi].scan_compares += count * S * W
                qstats[pi].per_shard_scan_compares += count * W
                qstats[pi].eval_calls = 1

        # per-query combine + merge-order/limit/project
        results: Dict[int, X.QueryResult] = {}
        for pi, (qid, plan) in enumerate(plans):
            stats = qstats[pi]
            mask = SX.combine_shard_masks(stable, plan, leaf_masks[pi])
            row_ids = np.nonzero(mask)[0]
            row_ids = SX.order_rows_sharded(ks, stable, plan.query,
                                            row_ids, stats)
            columns = {c: stable.gather_global(c, row_ids)
                       for c in plan.query.select}
            bstats.merge_compares += stats.merge_compares
            results[qid] = X.QueryResult(row_ids=row_ids, mask=mask,
                                         columns=columns, stats=stats)
            self._bill_tenant(qid, stats)
        bstats.wall_s = time.perf_counter() - t0
        bsp.set(queries=bstats.queries, eval_calls=bstats.eval_calls)
        obs.absorb_batch_stats(bstats, shards=str(S))
        self.batch_log.append(bstats)
        return results
