"""Cross-shard encrypted joins: the [S_l, S_r] shard-pair grid.

Both single-table strategies lift onto sharded layouts without new
comparison machinery:

  * NESTED-LOOP.  The uniform power-of-two block layout means every
    (left shard, right shard) pair is a static [N_l, N_r] sub-grid, so
    the whole join is ONE `[S_l, S_r, N_l, N_r]` broadcast raw-eval
    launch.  On a usable shard mesh it runs under `shard_map`
    (`kernels.ops.shard_eval_values` — the left shard dim places on the
    mesh, the right table broadcasts to every device; HADES eval stays
    row-local, so no collectives); otherwise the same grid evaluates as
    tiled launches on one device.  Decode thresholds apply host-side
    per the join's τ/ε — byte-identical to the unsharded grid because
    `from_table`-sharded tables carry the SAME ciphertext rows.

  * SORT-MERGE.  Each side contributes its per-shard ascending runs
    (reused from a `ShardedIndex`, or built in one batched per-shard
    network).  All S_l + S_r runs pad to one common block and the
    log-depth cross-shard merge network (`merge.merge_sorted_runs`)
    combines them into a single run — the same network that powers
    sharded OrderBy — then the shared adjacency/class/verify back half
    (`db.join.merge_runs_to_pairs`) emits pairs.  Total compares stay
    O((n_l+n_r)·log(n_l+n_r)·log S) versus the full product.

Invariance contract: `JoinResult.pairs` is byte-identical to the
unsharded plan for every (S_l, S_r) — asserted for S ∈ {1, 2, 3, 4} in
tests/test_db_join.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db import executor as X
from repro.db import join as J
from repro.db import plan as P
from repro.db.shard import executor as SX
from repro.db.shard.index import ShardedIndex
from repro.db.shard.table import ShardedTable


def _as_sharded(ks: KeySet, table) -> ShardedTable:
    """Normalize a join side to a ShardedTable.  Plain `Table`s wrap as
    one meshless shard via `from_table`, which REUSES the ciphertext
    rows — so mixed Table×ShardedTable joins stay byte-identical to
    their unsharded reference."""
    if isinstance(table, ShardedTable):
        return table
    from repro.db.shard.spec import ShardSpec
    return ShardedTable.from_table(ks, table,
                                   spec=ShardSpec.create(1, use_mesh=False))


def sharded_pair_eval(ks: KeySet, left: ShardedTable, right: ShardedTable,
                      lcol: str, rcol: str, *, engine: str = "jnp",
                      block_pairs: Optional[int] = None,
                      stats: Optional[J.JoinStats] = None) -> np.ndarray:
    """RAW eval values over the full shard-pair grid:
    [S_l, S_r, N_l, N_r] int64.

    On a usable mesh the grid runs under `shard_map`: the left stack
    reshapes to [S_l, 1, N_l, 1, K, n] (shard dim on the mesh axis) and
    the right stack replicates as [S_r, 1, N_r, K, n], broadcasting to
    each device's [S_r, N_l, N_r] slab.  The right rows tile into
    power-of-two chunks so each device's slab stays within
    `block_pairs` eval lanes — the same memory cap the single-table
    tiles enforce, now per shard (`block_pairs=None` resolves through
    the shared lane-budget policy, see `db.join.DEFAULT_BLOCK_PAIRS`).
    Meshless, the grid flattens to a [S_l·N_l, S_r·N_r] pair matrix and
    reuses the tiled single-table launches.  Either way, thresholds are
    NOT applied here (the `fused_eval` raw-value contract)."""
    block_pairs = J._resolve_block_pairs(block_pairs)
    lct, rct = left.columns[lcol], right.columns[rcol]
    S_l, N_l = lct.c0.shape[:2]
    S_r, N_r = rct.c0.shape[:2]
    spec = left.spec
    if spec.shard_map_ok:
        from repro.kernels import ops as KO
        a = Ciphertext(lct.c0[:, None, :, None], lct.c1[:, None, :, None])
        t_r = J._grid_tile(block_pairs, N_r, S_r * N_l)   # pow2, divides N_r
        chunks = []
        for lo in range(0, N_r, t_r):
            b = Ciphertext(rct.c0[:, None, lo:lo + t_r],
                           rct.c1[:, None, lo:lo + t_r])
            chunks.append(np.asarray(KO.shard_eval_values(
                ks, a, b, mesh=spec.mesh, axis_name=spec.axis,
                use_kernel=X._use_kernel(engine))))
            if stats is not None:
                stats.eval_calls += 1
        if stats is not None:
            stats.pair_compares += S_l * S_r * N_l * N_r
        return np.concatenate(chunks, axis=3)
    flat = lambda ct: Ciphertext(  # noqa: E731
        ct.c0.reshape((-1,) + ct.c0.shape[2:]),
        ct.c1.reshape((-1,) + ct.c1.shape[2:]))
    vals = J.pair_eval_values(ks, flat(lct), flat(rct), engine=engine,
                              block_pairs=block_pairs, stats=stats)
    return vals.reshape(S_l, N_l, S_r, N_r).transpose(0, 2, 1, 3)


def _shard_masks(stable: ShardedTable, gmask: np.ndarray) -> List[np.ndarray]:
    """Global [n_rows] row mask -> per-shard [N_sp] padded masks (pad
    slots False).  Reads the slot->id map, so compacted tables — whose
    shard ownership is no longer contiguous in id space — slice
    correctly."""
    out = []
    for s in range(stable.num_shards):
        m = np.zeros(stable.n_padded_per_shard, bool)
        gids = stable.global_ids(s)
        sel = gids >= 0
        m[sel] = gmask[gids[sel]]
        out.append(m)
    return out


def pairs_from_shard_grid(vals: np.ndarray, tau: int, left: ShardedTable,
                          right: ShardedTable, left_mask: np.ndarray,
                          right_mask: np.ndarray) -> np.ndarray:
    """Raw [S_l, S_r, N_l, N_r] grid -> [P, 2] GLOBAL matched row ids in
    canonical lexicographic order (strategy/placement independent)."""
    lmasks = _shard_masks(left, left_mask)
    rmasks = _shard_masks(right, right_mask)
    chunks = []
    for sl in range(left.num_shards):
        for sr in range(right.num_shards):
            sub = np.abs(vals[sl, sr]) < tau
            sub &= lmasks[sl][:, None] & rmasks[sr][None, :]
            idx = np.argwhere(sub)
            if idx.size:
                idx[:, 0] = left.global_ids(sl)[idx[:, 0]]
                idx[:, 1] = right.global_ids(sr)[idx[:, 1]]
                chunks.append(idx)
    if not chunks:
        return np.zeros((0, 2), dtype=np.int64)
    pairs = np.concatenate(chunks)
    return pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]


def _side_mask_sharded(ks: KeySet, stable: ShardedTable,
                       plan: Optional[P.CompiledPlan], *,
                       indexes: Optional[Dict[str, ShardedIndex]],
                       engine: str,
                       stats: SX.ShardedExecStats) -> np.ndarray:
    """One join side -> its GLOBAL [n_rows] row mask, through the sharded
    filter / merge-order machinery (mirrors `db.join._side_mask`,
    including its contract for mutated sides: a pending delta run is
    refused — compact first — while tombstoned rows just drop out of
    the mask)."""
    if stable.has_delta:
        raise ValueError(
            f"sharded table {stable.name!r} has {stable.n_delta} "
            "uncompacted delta rows — joins address base slots; run "
            "repro.db.delta.compact first")
    if plan is None:
        return stable.alive.copy()
    leaf_masks = SX.sharded_filter_masks(ks, stable, plan, indexes=indexes,
                                         engine=engine, stats=stats)
    mask = SX.combine_shard_masks(stable, plan, leaf_masks)
    q = plan.query
    if q.top_k is not None or q.order_by is not None or q.limit is not None:
        row_ids = SX.order_rows_sharded(ks, stable, q, np.nonzero(mask)[0],
                                        stats)
        mask = np.zeros(stable.n_rows, bool)
        mask[row_ids] = True
    return mask


def _shard_runs(ks: KeySet, stable: ShardedTable, column: str,
                index: Optional[ShardedIndex], id_base: int,
                stats: J.JoinStats) -> List[Tuple[Ciphertext, np.ndarray]]:
    """One side's per-shard ascending runs with GLOBAL combined-key ids
    (shard-local perm + shard offset + the side's `id_base`).  Reuses the
    side's ShardedIndex, building one (cost attributed) when absent."""
    if index is None:
        index = ShardedIndex.build(ks, stable, column)
        stats.build_compares += index.build_compares
    runs = []
    for s, ix in enumerate(index.shards):
        ct, perm = ix.sorted_run()
        # per-shard perms are LOCAL slots; the slot->id map lifts them to
        # global ids (contiguous-offset arithmetic breaks after compaction)
        runs.append((ct, id_base + stable.global_ids(s)[perm]))
    return runs


def execute_join_sharded(ks: KeySet, left, right, join: P.Join, *,
                         strategy: str = "auto",
                         left_indexes: Optional[Dict[str, object]] = None,
                         right_indexes: Optional[Dict[str, object]] = None,
                         engine: str = "jnp",
                         block_pairs: Optional[int] = None,
                         ) -> J.JoinResult:
    """Run a `Join` where either side is a `ShardedTable`.

    Same result contract as `db.join.execute_join` (which dispatches
    here automatically): canonical `pairs`, per-side masks, projected
    ciphertexts — byte-identical to the unsharded plan for every shard
    count when the sharded tables share ciphertext rows with the
    reference (`from_table`).
    """
    left = _as_sharded(ks, left)
    right = _as_sharded(ks, right)
    cj = P.compile_join(join)
    lcol, rcol = cj.on_columns
    left_indexes = dict(left_indexes or {})
    right_indexes = dict(right_indexes or {})
    stats = J.JoinStats(shards=(left.num_shards, right.num_shards))
    stats.left = SX.ShardedExecStats(shards=left.num_shards,
                                     mesh_devices=left.spec.mesh_devices)
    stats.right = SX.ShardedExecStats(shards=right.num_shards,
                                      mesh_devices=right.spec.mesh_devices)
    stats.strategy = J.resolve_strategy(strategy, lcol in left_indexes,
                                        rcol in right_indexes)
    lmask = _side_mask_sharded(ks, left, cj.left_plan, indexes=left_indexes,
                               engine=engine, stats=stats.left)
    rmask = _side_mask_sharded(ks, right, cj.right_plan,
                               indexes=right_indexes, engine=engine,
                               stats=stats.right)
    tau = J.join_tau(ks, join)
    if stats.strategy == "nested":
        vals = sharded_pair_eval(ks, left, right, lcol, rcol, engine=engine,
                                 block_pairs=block_pairs, stats=stats)
        pairs = pairs_from_shard_grid(vals, tau, left, right, lmask, rmask)
    else:
        n_left = left.n_rows
        runs = (_shard_runs(ks, left, lcol, left_indexes.get(lcol), 0, stats)
                + _shard_runs(ks, right, rcol, right_indexes.get(rcol),
                              n_left, stats))
        pairs = J.merge_runs_to_pairs(
            ks, runs, n_left, tau, verify=J.needs_verify(ks, join),
            gather_left=lambda rows: left.gather_global(lcol, rows),
            gather_right=lambda rows: right.gather_global(rcol, rows),
            left_mask=lmask, right_mask=rmask, stats=stats)
    columns = J._project(cj, left.gather_global, right.gather_global, pairs)
    return J.JoinResult(pairs=pairs, left_mask=lmask, right_mask=rmask,
                        columns=columns, stats=stats)
