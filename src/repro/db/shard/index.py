"""`ShardedIndex`: one HADES sorted index per shard, probed fan-out.

Build is batched across shards: every shard's valid rows pad to one
common block and ONE tiled bitonic network sorts all shards together
(each stage a single batched Eval — `merge.shard_block_sort`), then the
per-shard `SortedIndex` objects are carved out by id-stripping.

Lookups broadcast the client's encrypted trapdoor to every shard and
binary-search ALL shards' indexes in one lane-batched launch: a probe
step evaluates the `[S, B]` grid of (shard, lane) probes in one Eval,
so a range query over S shards still costs only ~log₂(max shard size)
launches.  Boundary lanes then combine per shard into local row masks
(the executor lifts them to the global mask).  Per-lane decode
thresholds ride exactly as in `SortedIndex.search` — ε-band lanes work
unchanged.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compare as C
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db.index import SortedIndex, _stack_cts, eps_lane_taus
from repro.db.shard import merge as M
from repro.db.shard.table import ShardedTable
from repro.db.table import rows_to_mask


class ShardedIndex:
    """Per-shard SortedIndexes + stacked sorted rows for fan-out probes."""

    def __init__(self, column: str, shards: List[SortedIndex], *,
                 build_compares: int = 0):
        self.column = column
        self.shards = shards
        self.counts = np.asarray([ix.n_rows for ix in shards], np.int64)
        self.build_compares = build_compares
        self.search_compares = 0
        # per-lane probe totals (summed over shards) from the LAST
        # `search` call — the per-query attribution the batched servers
        # bill from (the scalar above is only the cumulative total)
        self.last_probe_counts = np.zeros(0, np.int64)
        n_max = int(self.counts.max())
        c0s, c1s = [], []
        for ix in shards:
            c0, c1 = ix.sorted_ct.c0, ix.sorted_ct.c1
            pad = n_max - c0.shape[0]
            if pad:   # never probed (hi is clamped to the shard's count)
                c0 = jnp.concatenate([c0, jnp.zeros((pad,) + c0.shape[1:],
                                                    c0.dtype)])
                c1 = jnp.concatenate([c1, jnp.zeros((pad,) + c1.shape[1:],
                                                    c1.dtype)])
            c0s.append(c0)
            c1s.append(c1)
        self._sorted = Ciphertext(jnp.stack(c0s), jnp.stack(c1s))  # [S,Nm,..]
        self._cmp: Optional[Callable] = None

    # -- construction ------------------------------------------------------

    @classmethod
    def build(cls, ks: KeySet, stable: ShardedTable,
              column: str) -> "ShardedIndex":
        """Sort every shard's column in ONE batched per-shard network."""
        S = stable.num_shards
        block = C.next_pow2(int(stable.shard_rows.max()))
        per_shard = []
        for s in range(S):
            m = int(stable.shard_rows[s])
            per_shard.append((stable.gather(column, s, np.arange(m)),
                              np.arange(m, dtype=np.int64)))
        ct, ids = M.pad_shard_blocks(ks, per_shard, block=block,
                                     pad_value=ks.params.max_operand // 2,
                                     num_blocks=S)
        from repro.db.executor import jitted_comparator
        c0, c1, gid, compares = M.shard_block_sort(
            ks, jitted_comparator(ks), ct.c0, ct.c1, jnp.asarray(ids),
            block=block)
        gid = np.asarray(gid)
        shards = []
        for s in range(S):
            sl = slice(s * block, (s + 1) * block)
            keep = np.nonzero(gid[sl] >= 0)[0] + s * block
            shards.append(SortedIndex(
                column, Ciphertext(c0[keep], c1[keep]), gid[keep],
                # each shard rode a block-row network (the common padded
                # block, not its own row count) — attribute that share so
                # per-shard counts sum to the batched total
                build_compares=C.bitonic_compare_count(block)))
        return cls(column, shards, build_compares=compares)

    # -- fan-out search ----------------------------------------------------

    @property
    def num_shards(self) -> int:
        """Number of per-shard SortedIndexes (= the table's shard count)."""
        return len(self.shards)

    def _eval(self, ks: KeySet) -> Callable:
        if self._cmp is None:
            self._cmp = jax.jit(lambda a, b: C.eval_value(ks, a, b))
        return self._cmp

    def search(self, ks: KeySet, values: Ciphertext, strict: np.ndarray,
               taus: Optional[np.ndarray] = None) -> np.ndarray:
        """Fan-out boundary search: B lanes against ALL S shards.

        values: trapdoor ciphertexts with leading batch dim B — sent
        ONCE by the client, broadcast to every shard server-side.
        Returns [S, B] sorted positions; every binary-search step is ONE
        batched Eval over the S·B live probes.  strict/taus semantics
        match `SortedIndex.search` lane for lane.
        """
        strict = np.asarray(strict, bool)
        B = values.c0.shape[0]
        assert strict.shape == (B,)
        if taus is None:
            taus = np.full(B, ks.params.tau, dtype=np.int64)
        taus = np.asarray(taus, np.int64)
        assert taus.shape == (B,)
        S = self.num_shards
        ev = self._eval(ks)
        lo = np.zeros((S, B), np.int64)
        hi = np.broadcast_to(self.counts[:, None], (S, B)).copy()
        s_idx = np.arange(S)[:, None]
        lane_probes = np.zeros(B, np.int64)
        with obs.span("shard.index.search", column=self.column,
                      shards=S, lanes=B) as sp:
            while np.any(lo < hi):
                active = lo < hi
                mid = (lo + hi) // 2
                probe = np.where(active, mid, 0)
                rows = Ciphertext(self._sorted.c0[s_idx, probe],
                                  self._sorted.c1[s_idx, probe])  # [S,B,...]
                obs.jit_launch("shard.index.probe", rows.c0, values.c0)
                obs.count("eval.launches")
                obs.count("eval.lanes", S * B)
                v = np.asarray(ev(rows, values))               # [S, B] raw
                c = np.where(np.abs(v) < taus[None, :], 0, np.sign(v))
                lane_probes += active.sum(axis=0)
                go_left = np.where(strict[None, :], c > 0, c >= 0)
                hi = np.where(active & go_left, mid, hi)
                lo = np.where(active & ~go_left, mid + 1, lo)
            sp.set(probes=int(lane_probes.sum()))
        obs.count("index.probes", int(lane_probes.sum()))
        self.search_compares += int(lane_probes.sum())
        self.last_probe_counts = lane_probes
        return lo

    # -- leaf resolution (executor plumbing) -------------------------------

    def _eps_taus(self, ks: KeySet,
                  eps: Optional[float]) -> Optional[np.ndarray]:
        return eps_lane_taus(ks, eps)

    def lane_masks(self, pos: np.ndarray, lane: int,
                   n_padded: int) -> List[np.ndarray]:
        """Boundary lane pair (2·lane, 2·lane+1) -> per-shard local row
        masks (shared by executor and ShardedQueryServer)."""
        out = []
        for s in range(self.num_shards):
            l, r = int(pos[s, 2 * lane]), int(pos[s, 2 * lane + 1])
            out.append(rows_to_mask(self.shards[s].perm[l:r], n_padded))
        return out

    def shard_masks_range(self, ks: KeySet, ct_lo: Ciphertext,
                          ct_hi: Ciphertext, n_padded: int, *,
                          eps: Optional[float] = None) -> List[np.ndarray]:
        """lo <= value <= hi as per-shard local row masks — one 2-lane
        fan-out search (`eps` makes the bounds ε-inclusive)."""
        bounds = _stack_cts([ct_lo, ct_hi])
        pos = self.search(ks, bounds, np.array([False, True]),
                          self._eps_taus(ks, eps))
        return self.lane_masks(pos, 0, n_padded)

    def shard_masks_eq(self, ks: KeySet, ct_value: Ciphertext,
                       n_padded: int, *,
                       eps: Optional[float] = None) -> List[np.ndarray]:
        """value == v (ε-band with `eps`) as per-shard local row masks —
        one 2-lane fan-out search."""
        bounds = _stack_cts([ct_value, ct_value])
        pos = self.search(ks, bounds, np.array([False, True]),
                          self._eps_taus(ks, eps))
        return self.lane_masks(pos, 0, n_padded)

    def __repr__(self) -> str:
        return (f"ShardedIndex({self.column!r}, shards={self.num_shards}, "
                f"rows={self.counts.tolist()}, "
                f"build_compares={self.build_compares})")
