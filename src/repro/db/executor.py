"""Plan executor: fused batched filtering + encrypted order/top-k stages.

Execution model (one XLA program per stage):

  1. FILTER.  Every scan leaf of the compiled plan contributes 1 (Eq) or
     2 (Range) comparison atoms.  ALL atoms across the whole predicate
     tree are stacked into a single [A, N] batched `eval_value` call —
     a 5-leaf plan over 34k rows is still ONE fused Eval (the same fused
     kernel path `kernels/cmp_eval.py` lowers on TPU).  The launch
     returns RAW eval values; each atom's decode threshold (the profile
     τ, or the predicate's ε-tolerance via `ckks.eps_to_tau`) is applied
     host-side, so mixed-ε plans share one launch and one jit cache
     entry.  Leaves whose column has a `SortedIndex` skip the scan
     entirely and resolve with O(log n) binary-search compares.
  2. COMBINE.  Atom outcomes -> leaf masks -> boolean tree (host-side
     numpy; the comparison outcomes are exactly what the HADES trapdoor
     reveals to the server).
  3. ORDER / TOPK.  The surviving rows' order column runs through
     `encrypted_sort` / `encrypted_topk` (sentinel padding handles the
     arbitrary match count).
  4. LIMIT + PROJECT.  Slice row ids; gather selected ciphertext columns.

Two-table plans (`plan.Join`) execute in `db/join.py`, which reuses
this module's stage helpers for the per-side filters and adds the
pair-matching strategies (tiled nested-loop grid / sort-merge) on top —
`fused_eval`'s raw-value + host-side-threshold contract is exactly what
lets the join grid share programs across ε's and queries.

Engines: "jnp" evaluates via core/compare (reference path, CPU),
"kernel" routes the fused stage through kernels/ops.compare (Pallas
`cmp_eval`, compiled on TPU), "auto" picks kernel iff on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import compare as C
from repro.core.ckks import eps_to_tau
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet
from repro.db import plan as P
from repro.db.index import SortedIndex
from repro.db.table import Table, rows_to_mask


@dataclasses.dataclass
class ExecStats:
    """What the engine actually did — benchmarks and tests assert on this."""
    eval_calls: int = 0            # batched Eval launches in the filter stage
    scan_compares: int = 0         # comparisons inside fused linear scans
    index_compares: int = 0        # binary-search probe comparisons (the
    #                                base index AND any delta-run index)
    scan_leaves: int = 0
    indexed_leaves: int = 0
    order_compares: int = 0        # sort / top-k network comparisons
    delta_build_compares: int = 0  # lazy per-delta-run index builds

    @property
    def filter_compares(self) -> int:
        """Total filter-stage compare lanes (fused scans + index probes)."""
        return self.scan_compares + self.index_compares


@dataclasses.dataclass
class QueryResult:
    """One executed plan's answer: matched/ordered row ids, the filter
    mask, still-encrypted projected columns, and the engine stats."""
    row_ids: np.ndarray                      # selected (ordered) row ids
    mask: np.ndarray                         # [n_total] global filter mask
    columns: Dict[str, Ciphertext]           # projected ciphertexts
    stats: ExecStats

    def __len__(self) -> int:
        return int(self.row_ids.shape[0])


def _use_kernel(engine: str) -> bool:
    if engine == "auto":
        return jax.default_backend() == "tpu"
    if engine in ("jnp", "kernel"):
        return engine == "kernel"
    raise ValueError(f"unknown engine {engine!r} (jnp|kernel|auto)")


def _jitted(ks: KeySet, name: str, fn):
    """Per-KeySet jit cache (stashed on the keyset so lifetimes match).

    Jitting the compare plane matters on CPU too: the fused XLA program
    keeps the NTT pipeline in registers/cache instead of materializing
    every eager intermediate (measured ~5-15x on scan-sized batches).
    """
    cache = getattr(ks, "_db_jit_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(ks, "_db_jit_cache", cache)
    if name not in cache:
        cache[name] = jax.jit(fn)
    return cache[name]


def jitted_eval(ks: KeySet):
    """Jitted raw eval values (no threshold) closed over the keyset —
    the fused scan and the index search both decode from this, applying
    their own per-atom / per-lane τ on the host."""
    return _jitted(ks, "eval", lambda a, b: C.eval_value(ks, a, b))


def jitted_dedup_eval(ks: KeySet, axis: int = 0):
    """Jitted raw eval over a deduped column stack: gathers the unique
    columns back to per-atom order (`jnp.take` by `sel` on `axis`)
    INSIDE the program, then evaluates against the [A, 1] bounds.

    The gather living inside the XLA program is the point — the host
    hands over U unique columns however many atoms alias them, and the
    per-atom copies only ever exist as fused intermediates bounded by
    the tile size, never as a materialized A·N stack.

    In paper mode the dedup goes further: `eval_value` is LINEAR in the
    ciphertext pair (ctΔ then `scale·c0 + cek⊛c1` — NTT, pointwise, and
    scalar ops are all exact mod-q linear maps), so the expensive
    column-side transform runs ONCE per unique column and the per-atom
    work collapses to a gather + coefficient-0 subtract.  A same-column
    batch of A atoms costs ~1 column transform instead of A — bit-
    identical raw values, this is pure factoring.  Gadget mode keeps
    the joint form: `gadget_keymul` digit-decomposes its operand, which
    is not linear, so splitting it would change the noise."""
    from repro.core import ring as R

    def g0(ct0, ct1):
        # coefficient-0 eval part of one ciphertext: [..., K]
        rng = ks.ring
        scaled = R.scalar_mul(rng, ct0, ks.params.scale)
        keyed = R.negacyclic_mul(rng, ct1, ks.cek)
        return R.add(rng, scaled, keyed)[..., :, 0]

    if ks.params.mode == "paper":
        def fn(uc0, uc1, sel, b0, b1):
            g_col = jnp.take(g0(uc0, uc1), sel, axis=axis)
            diff = (g_col - g0(b0, b1)) % ks.ring.q_arr[:, 0]
            return R.crt_centered(ks.params, diff)
    else:
        def fn(uc0, uc1, sel, b0, b1):
            col = Ciphertext(jnp.take(uc0, sel, axis=axis),
                             jnp.take(uc1, sel, axis=axis))
            return C.eval_value(ks, col, Ciphertext(b0, b1))
    return _jitted(ks, f"dedup_eval_ax{axis}", fn)


def dedup_atom_columns(table, atoms: List[P.Atom],
                       stack) -> Tuple[Ciphertext, np.ndarray]:
    """Stack each DISTINCT scan column once + the [A] per-atom gather.

    `stack(column)` returns the column's scan ciphertext (`scan_column`
    on a Table, `scan_stack` on a ShardedTable); the returned `sel`
    maps atom i to its row in the unique stack, first-seen order — K
    range atoms over one column contribute ONE stacked copy."""
    order: Dict[str, int] = {}
    for a in atoms:
        order.setdefault(a.column, len(order))
    cols = [stack(c) for c in order]
    axis = 0 if cols[0].c0.ndim == 3 else 1     # after the shard dim
    uniq = Ciphertext(jnp.stack([c.c0 for c in cols], axis=axis),
                      jnp.stack([c.c1 for c in cols], axis=axis))
    sel = np.asarray([order[a.column] for a in atoms], np.int64)
    return uniq, sel


def stack_atom_bounds(atoms: List[P.Atom]) -> Ciphertext:
    """The [A, 1] per-atom trapdoor bounds stack every fused scan
    broadcasts against its column tiles."""
    return Ciphertext(jnp.stack([a.value.c0 for a in atoms])[:, None],
                      jnp.stack([a.value.c1 for a in atoms])[:, None])


def atom_tau(ks: KeySet, atom: P.Atom) -> int:
    """The decode threshold atom resolves to (profile τ or ε-derived)."""
    if atom.eps is None:
        return ks.params.tau
    return eps_to_tau(ks.params, atom.eps)


def jitted_comparator(ks: KeySet):
    """Jitted Alg. 4 trapdoor comparator in `encrypted_sort` signature."""
    fae = _jitted(ks, "cmp_fae", lambda a, b: C.compare_fae(ks, a, b))
    return lambda _ks, a, b: fae(a, b)


def fused_eval(ks: KeySet, table: Table, atoms: List[P.Atom], *,
               engine: str = "jnp",
               lane_budget: Optional[int] = None) -> np.ndarray:
    """RAW eval values for all atoms' fused scan: [A, N] int64
    (N = `table.scan_width`: a pending delta run's slots ride the SAME
    program as the base block — base ∪ delta costs one pass, not two).

    Duplicate-free and working-set bounded: the host stacks each
    DISTINCT column ONCE ([U, N] bytes moved, not [A, N] — K range
    queries over one column used to ship K full copies) and the
    per-atom gather + [A, 1] bounds broadcast happen INSIDE the jitted
    program.  Rows tile into power-of-two chunks of T with A·T lanes
    within the lane budget (`kernels.ops.lane_tile`; explicit
    `lane_budget` > `set_lane_budget` > `REPRO_LANE_BUDGET` > default),
    so peak intermediates stay off the bandwidth cliff however many
    atoms a batch fuses — each tile is one launch, same shapes across
    queries, at most one extra ragged-tail shape when N is not a
    multiple of T.

    Thresholds are deliberately NOT applied here: each atom decodes its
    own τ (profile default or ε-derived) host-side in `scan_leaf_mask`,
    so a plan mixing exact and ε-band predicates still shares launches.
    """
    from repro.kernels import ops as KO
    with obs.span("executor.fused_eval", atoms=len(atoms),
                  rows=table.scan_width):
        A, W = len(atoms), table.scan_width
        uniq, sel = dedup_atom_columns(table, atoms, table.scan_column)
        bounds = stack_atom_bounds(atoms)
        T = KO.lane_tile(W, A, lane_budget)
        # host<->device traffic is the deduped reality: U unique column
        # stacks + A bounds, counted once however many tiles launch
        obs.count("bytes.moved", 2 * (uniq.c0.nbytes + bounds.c0.nbytes))
        use_kernel = _use_kernel(engine)
        sel_j = jnp.asarray(sel)
        out = np.empty((A, W), dtype=np.int64)
        for lo in range(0, W, T):
            t = min(T, W - lo)
            with obs.span("executor.eval_tile", offset=lo, rows=t) as tsp:
                tile = Ciphertext(uniq.c0[:, lo:lo + t],
                                  uniq.c1[:, lo:lo + t])
                obs.jit_launch("executor.fused_eval", tile.c0, bounds.c0)
                obs.count("eval.launches")
                obs.count("eval.tiles")
                obs.count("eval.lanes", A * t)
                if use_kernel:
                    col = Ciphertext(jnp.take(tile.c0, sel_j, axis=0),
                                     jnp.take(tile.c1, sel_j, axis=0))
                    vals = tsp.sync(KO.broadcast_eval_values(ks, col,
                                                             bounds))
                else:
                    vals = tsp.sync(jitted_dedup_eval(ks)(
                        tile.c0, tile.c1, sel_j, bounds.c0, bounds.c1))
                out[:, lo:lo + t] = np.asarray(vals)
        return out


def fused_compare(ks: KeySet, table: Table, atoms: List[P.Atom], *,
                  engine: str = "jnp",
                  lane_budget: Optional[int] = None) -> np.ndarray:
    """Three-way outcomes (profile τ) for all atoms' fused scan.

    Compatibility wrapper over `fused_eval` for callers that want the
    -1/0/+1 view; the executor itself consumes the raw values.
    """
    v = fused_eval(ks, table, atoms, engine=engine, lane_budget=lane_budget)
    tau = ks.params.tau
    return np.where(np.abs(v) < tau, 0, np.sign(v)).astype(np.int32)


def _atom_mask(op: str, vals: np.ndarray, tau: int) -> np.ndarray:
    """Raw eval row -> bool mask under this atom's decode threshold.

    vals ≈ scale·Δ_enc·(column - value) + noise, so with the three-way
    decode c = (0 if |vals| < τ else sign):  >= is c >= 0, <= is c <= 0,
    == is c == 0 — written directly on the raw values.
    """
    if op == ">=":
        return vals > -tau
    if op == "<=":
        return vals < tau
    if op == "==":
        return np.abs(vals) < tau
    raise ValueError(f"unknown atom op {op!r}")


def scan_leaf_mask(ks: KeySet, atoms: List[P.Atom], vals: np.ndarray,
                   start: int, count: int) -> np.ndarray:
    """AND the fused-scan raw eval values of one leaf's atoms into its
    row mask, each atom under its own τ (single implementation for
    executor and QueryServer)."""
    a = atoms[start]
    m = _atom_mask(a.op, vals[start], atom_tau(ks, a))
    for j in range(1, count):
        a = atoms[start + j]
        m = m & _atom_mask(a.op, vals[start + j], atom_tau(ks, a))
    return m


def combine_tree(tree: Optional[tuple], leaf_masks: List[np.ndarray],
                 n_padded: int) -> np.ndarray:
    """Fold the compiled boolean tree over per-leaf row masks."""
    if tree is None:
        return np.ones(n_padded, bool)
    kind = tree[0]
    if kind == "leaf":
        return leaf_masks[tree[1]]
    if kind == "and":
        out = np.ones(n_padded, bool)
        for t in tree[1]:
            out &= combine_tree(t, leaf_masks, n_padded)
        return out
    if kind == "or":
        out = np.zeros(n_padded, bool)
        for t in tree[1]:
            out |= combine_tree(t, leaf_masks, n_padded)
        return out
    if kind == "not":
        return ~combine_tree(tree[1], leaf_masks, n_padded)
    raise ValueError(f"bad tree node {tree!r}")


def delta_probe_index(ks: KeySet, table: Table, column: str,
                      stats: ExecStats):
    """The per-delta-run `SortedIndex` for an indexed union probe, with
    lazy-build compares attributed to `stats` exactly once per delta
    state (shared by executor and QueryServer).  None without a delta."""
    if table.n_delta == 0:
        return table.delta_index(ks, column)   # fast path: None, no span
    cached = table._delta_index_cache.get(column)
    fresh = not (cached is not None and cached[0] == table.version)
    with obs.span("delta.index_build", column=column, fresh=fresh):
        didx = table.delta_index(ks, column)
    if didx is not None and fresh:
        stats.delta_build_compares += didx.build_compares
        obs.count("eval.lanes", didx.build_compares)
    return didx


def index_leaf_mask(ks: KeySet, table: Table, idx: SortedIndex,
                    leaf, stats: ExecStats) -> np.ndarray:
    """Resolve one indexed leaf over base ∪ delta as a
    [table.scan_width] slot mask.

    The base `SortedIndex` answers with ~2·log2(n_base) probe compares;
    a pending delta run adds one per-run binary search — at most
    2·ceil(log2 |delta|) extra compares — against its own (lazily built,
    cached) sorted run.  Base row ids ARE base slot ids; delta-local
    hits shift past the base block."""
    before = idx.search_compares
    if isinstance(leaf, P.Range):
        rows = idx.search_range(ks, leaf.lo, leaf.hi, eps=leaf.eps)
    else:
        rows = idx.point_lookup(ks, leaf.value, eps=leaf.eps)
    stats.index_compares += idx.search_compares - before
    slots = [np.asarray(rows, np.int64)]
    didx = delta_probe_index(ks, table, leaf.column, stats)
    if didx is not None:
        before = didx.search_compares
        if isinstance(leaf, P.Range):
            drows = didx.search_range(ks, leaf.lo, leaf.hi, eps=leaf.eps)
        else:
            drows = didx.point_lookup(ks, leaf.value, eps=leaf.eps)
        stats.index_compares += didx.search_compares - before
        slots.append(table.n_padded + np.asarray(drows, np.int64))
    return rows_to_mask(np.concatenate(slots), table.scan_width)


def filter_masks(ks: KeySet, table: Table, plan: P.CompiledPlan, *,
                 indexes: Optional[Dict[str, SortedIndex]] = None,
                 engine: str = "jnp",
                 lane_budget: Optional[int] = None,
                 stats: Optional[ExecStats] = None) -> List[np.ndarray]:
    """Per-leaf row masks over the union slot space (`table.scan_width`):
    indexed leaves via binary search (base index + per-delta-run
    search), the rest via one fused scan covering base AND delta."""
    stats = stats if stats is not None else ExecStats()
    indexes = indexes or {}
    W = table.scan_width
    leaf_masks: List[Optional[np.ndarray]] = [None] * plan.num_leaves
    scan_atoms: List[P.Atom] = []
    scan_slices: List[Tuple[int, int, int]] = []   # (leaf, start, count)
    for i, leaf in enumerate(plan.leaves):
        idx = indexes.get(leaf.column)
        if idx is not None:
            leaf_masks[i] = index_leaf_mask(ks, table, idx, leaf, stats)
            stats.indexed_leaves += 1
        else:
            atoms = plan.scan_atoms(i)
            scan_slices.append((i, len(scan_atoms), len(atoms)))
            scan_atoms.extend(atoms)
            stats.scan_leaves += 1
    if scan_atoms:
        vals = fused_eval(ks, table, scan_atoms, engine=engine,
                          lane_budget=lane_budget)
        stats.eval_calls += 1
        stats.scan_compares += len(scan_atoms) * W
        for leaf_i, start, count in scan_slices:
            leaf_masks[leaf_i] = scan_leaf_mask(ks, scan_atoms, vals,
                                                start, count)
    return leaf_masks  # type: ignore[return-value]


def order_rows(ks: KeySet, table: Table, query: P.Query,
               row_ids: np.ndarray, stats: ExecStats) -> np.ndarray:
    """Apply TopK / OrderBy / Limit to the filtered row ids."""
    n_sel = int(row_ids.shape[0])
    if query.top_k is not None and n_sel:
        k = min(query.top_k.k, n_sel)
        with obs.span("executor.order", kind="topk", rows=n_sel, k=k):
            sub = table.gather(query.top_k.column, row_ids)
            _, sel = C.encrypted_topk(ks, sub, k, jitted_comparator(ks))
        row_ids = row_ids[np.asarray(sel)]
        stats.order_compares += _topk_compares(n_sel, k)
        obs.count("eval.lanes", _topk_compares(n_sel, k))
    elif query.order_by is not None and n_sel:
        with obs.span("executor.order", kind="sort", rows=n_sel):
            sub = table.gather(query.order_by.column, row_ids)
            _, perm = C.encrypted_sort(ks, sub, jitted_comparator(ks))
        row_ids = row_ids[np.asarray(perm)]
        if query.order_by.descending:
            row_ids = row_ids[::-1]
        stats.order_compares += _sort_compares(n_sel)
        obs.count("eval.lanes", _sort_compares(n_sel))
    limit = query.limit_count
    if limit is not None:
        row_ids = row_ids[:limit]
    return row_ids


def _sort_compares(n: int) -> int:
    return C.bitonic_compare_count(n)


def _topk_compares(n: int, k: int) -> int:
    n_pad = C.next_pow2(n)
    kp = C.next_pow2(k)
    if kp >= n_pad:
        return _sort_compares(n_pad)
    total = sum(range(1, kp.bit_length())) * (n_pad // 2)  # block sorts
    live = n_pad
    while live > kp:
        total += live // 2                                  # max-merge
        live //= 2
        total += (kp.bit_length() - 1) * (live // 2)        # re-merge
    return total


def execute(ks: KeySet, table, query, *,
            indexes: Optional[Dict[str, SortedIndex]] = None,
            engine: str = "jnp",
            lane_budget: Optional[int] = None) -> QueryResult:
    """Run a Query (or bare predicate / precompiled plan) against a table.

    Accepts a `Table` or a `ShardedTable` — sharded tables dispatch to
    the shard-parallel executor (`db.shard.execute_sharded`; their
    `indexes` must then be `ShardedIndex` instances), so call sites stay
    placement-agnostic.  `lane_budget` caps the fused scan's per-launch
    eval lanes (None = the shared `kernels.ops` policy default)."""
    import sys
    # sys.modules guard keeps non-shard users import-free: a ShardedTable
    # argument implies repro.db.shard.table is already loaded
    shard_mod = sys.modules.get("repro.db.shard.table")
    if shard_mod is not None and isinstance(table, shard_mod.ShardedTable):
        from repro.db.shard.executor import execute_sharded
        return execute_sharded(ks, table, query, indexes=indexes,
                               engine=engine, lane_budget=lane_budget)
    if isinstance(query, (P.Query, P.Predicate)):
        plan = P.compile_plan(query)
    elif isinstance(query, P.CompiledPlan):
        plan = query
    else:
        raise TypeError(f"cannot execute {query!r}")
    stats = ExecStats()
    with obs.span("executor.execute", leaves=plan.num_leaves):
        leaf_masks = filter_masks(ks, table, plan, indexes=indexes,
                                  engine=engine, lane_budget=lane_budget,
                                  stats=stats)
        slot_mask = combine_tree(plan.tree, leaf_masks, table.scan_width)
        slot_mask &= table.slot_valid      # pads AND tombstones excluded
        row_ids = table.slot_global_ids[np.nonzero(slot_mask)[0]]
        mask = rows_to_mask(row_ids, table.n_total)  # [n_total] global mask
        row_ids = order_rows(ks, table, plan.query, row_ids, stats)
        columns = {c: table.gather(c, row_ids) for c in plan.query.select}
    if obs.is_enabled() and table.n_rows:
        obs.observe("pad.waste", table.n_padded / table.n_rows)
        obs.absorb_exec_stats(stats)
    return QueryResult(row_ids=row_ids, mask=mask,
                       columns=columns, stats=stats)
