"""repro.db — the encrypted query engine over HADES comparisons.

The paper's "database perspective" realized as a subsystem: encrypted
column-store tables, HADES-sorted indexes with O(log n) encrypted binary
search, a logical-plan IR whose executor fuses every comparison of a
plan stage into one batched Eval, and a batched multi-query server.

    Table        — named Ciphertext columns, rows padded to powers of two
    SortedIndex  — built once via encrypted_sort; binary-search lookups
    Range/Eq/And/Or/Not + OrderBy/TopK/Limit/Query — the plan IR
    Join         — two-table equi-join node (ε-band capable)
    compile_plan / execute — lower + run a plan (indexes optional)
    execute_join — batched nested-loop or sort-merge join execution
    QueryServer  — K client queries against one table in one fused pass
    ServeLoop    — always-on multi-tenant loop: admission control,
                   deadline-aware two-class scheduling, pow2 bucketing
    compact      — fold a table's pending delta run into base + indexes

Write path: `Table.insert/update/delete` land rows in a pow2-padded
delta run (deletes are host-side tombstones); every read answers over
base ∪ delta, and `compact` retires the run through the log-depth merge
network without re-encrypting a single base row.

Sharded variants (repro.db.shard): ShardSpec / ShardedTable /
ShardedIndex / ShardedQueryServer partition rows across a device mesh
with cross-shard merge stages; `execute` dispatches automatically.

The comparison primitives themselves (range_query, encrypted_sort,
encrypted_topk) live in core/compare.py and are re-exported here — the
engine is a consumer of those ops, existing callers keep working.
"""
from repro.core.ckks import (  # noqa: F401
    eps_to_tau,
    equality_tolerance,
)
from repro.core.compare import (  # noqa: F401
    encrypted_sort,
    encrypted_topk,
    range_query,
)
from repro.db.executor import (  # noqa: F401
    ExecStats,
    QueryResult,
    execute,
    fused_compare,
    fused_eval,
)
from repro.db.index import SortedIndex  # noqa: F401
from repro.db.join import (  # noqa: F401
    JoinResult,
    JoinStats,
    execute_join,
)
from repro.db.plan import (  # noqa: F401
    And,
    Atom,
    CompiledJoin,
    CompiledPlan,
    Eq,
    Join,
    Limit,
    Not,
    Or,
    OrderBy,
    Query,
    Range,
    TopK,
    compile_join,
    compile_plan,
)
from repro.db.delta import (  # noqa: F401
    CompactionStats,
    compact,
    merge_index_runs,
)
from repro.db.table import Table  # noqa: F401


_SHARD_EXPORTS = ("ShardSpec", "ShardedTable", "ShardedIndex",
                  "ShardedQueryServer", "ShardedExecStats",
                  "execute_sharded", "execute_join_sharded")

_SERVE_EXPORTS = ("QueryServer", "MutationResult")

_LOOP_EXPORTS = ("ServeLoop", "AdmissionPolicy", "Response", "LoopStats")


def __getattr__(name):
    # lazy: keeps `python -m repro.db.query_serve` free of the runpy
    # double-import warning while preserving `db.QueryServer`; the shard
    # subsystem loads on first use for the same reason
    if name in _SERVE_EXPORTS:
        from repro.db import query_serve as _qs
        return getattr(_qs, name)
    if name in _LOOP_EXPORTS:
        from repro.db import serve_loop as _sl
        return getattr(_sl, name)
    if name in _SHARD_EXPORTS:
        from repro.db import shard as _shard
        return getattr(_shard, name)
    raise AttributeError(name)
