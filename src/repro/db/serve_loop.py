"""Always-on multi-tenant serving loop with admission control.

`QueryServer`/`ShardedQueryServer` batch K queries per synchronous
`run()` call; millions of users arrive as a *continuous stream* over
many tables.  `ServeLoop` lifts the `launch/serve.py` queue/batch
pattern into an always-on front door over any number of registered
servers —

  * **request queue**: clients `submit()` (tenant, table, plan,
    deadline) from any thread and receive a ticket; the loop forms
    batches and resolves each ticket to a `Response`;
  * **admission control**: per-tenant queue-depth caps, a total queue
    cap, and optional per-table tenant ACLs — an over-budget or
    unauthorized submission gets an *explicit* REJECTED response
    instead of unbounded queuing (`AdmissionPolicy`);
  * **per-tenant KeySets**: each registered server carries its own
    `KeySet`, so registering one table per tenant (with a `tenants=`
    ACL) gives every tenant its own keys while all tenants share one
    loop, one scheduler, and one jit cache;
  * **two-class deadline-aware scheduling**: requests classify as
    `point` (every filter leaf rides a `SortedIndex`, no order/top-k
    stage) or `bulk` (full scans, joins, sorts); every pump drafts the
    point batch *first* so an indexed lookup never waits behind a
    34k-row scan, and bulk still gets a draft slot each pump, so
    nothing starves.  Requests whose deadline already passed at
    batch-formation time are SHED (never executed); requests completed
    past deadline are answered with `deadline_missed=True`;
  * **pow2 shape bucketing**: drafted batch sizes round down to a
    power of two, so the underlying fused launches cycle through a
    small closed set of shapes and the jit cache stays hot (the engine
    already pads rows/lanes to pow2 for the same reason; per-launch
    working set stays bounded by the PR 9 `lane_budget` policy the
    servers carry);
  * **fair-share drafting**: within a class, tenants are drained
    round-robin (per-tenant FIFO preserved) and capped at
    `AdmissionPolicy.fair_share` slots per batch when contended, so
    one chatty tenant cannot monopolize a batch;
  * **write ordering**: mutations are admission-order *barriers* per
    table — a query drafts only after every mutation admitted before
    it (on its table) has applied, and a mutation applies only after
    every earlier-admitted query finished, so every query sees exactly
    the writes admitted before it (the two-class reordering happens
    strictly *between* barriers);
  * **fault isolation**: if a drafted batch raises mid-drain, the loop
    retries its requests one by one — the poisoned request alone
    resolves FAILED (with the error string), everyone else's answer is
    recovered, and the loop keeps serving.  (The engine raises before
    per-tenant billing, so obs counters stay reconciled; recovery goes
    through the servers' public `clear_queue()` / `batch_size()` API.)
  * **bounded retention**: only the most recent `max_responses`
    terminal responses (and batch shapes) are retained — older ones
    evict oldest-first, and clients `forget(ticket)` results as they
    consume them — so the always-on stream never grows loop memory
    without bound.

Observability (all no-ops unless `obs.tracing()` is active):
`serve.queue_depth` histogram (depth at every admit and pump),
`serve.queue_wait_s` histogram per class, `serve.rejected` /
`serve.shed` / `serve.deadline_miss` / `serve.failed` per-tenant
counters, and `serve.pump` / `serve.batch` spans around every drain.

The loop is deterministic when driven synchronously: `pump()` runs one
scheduling round, `run_until_idle()` pumps until the queue drains —
both on an injectable `clock` (deadline tests fake time the same way
`launch/elastic.FleetMonitor` does).  `start()`/`stop()` wrap `pump`
in a daemon thread for the always-on mode, optionally heartbeating a
`FleetMonitor` so the elastic scaffolding sees the loop as a live
host.

Usage:
  PYTHONPATH=src python -m repro.db.serve_loop --requests 32 --rows 1024
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.db import plan as P

# scheduling classes
POINT = "point"
BULK = "bulk"
WRITE = "write"

# terminal + pending response states
PENDING = "PENDING"
OK = "OK"
FAILED = "FAILED"
REJECTED = "REJECTED"
SHED = "SHED"


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Queue-depth budgets enforced at submit + draft time.

    `tenant_queue_cap` bounds one tenant's pending requests across all
    tables; `total_queue_cap` bounds the whole loop; `fair_share` caps
    how many of one tenant's requests a single batch drafts when other
    tenants are waiting in the same class."""
    tenant_queue_cap: int = 64
    total_queue_cap: int = 4096
    fair_share: int = 4


@dataclasses.dataclass
class Response:
    """Terminal record for one ticket: status, result, and timing.

    `status` is one of OK / FAILED / REJECTED / SHED (or PENDING while
    queued).  `result` holds the engine's native result object
    (`QueryResult`, `JoinResult`, or `MutationResult`) on OK.  All
    timestamps are on the loop's clock."""
    ticket: int
    tenant: str
    table: str
    klass: str
    status: str = PENDING
    result: object = None
    error: str = ""
    deadline: Optional[float] = None
    deadline_missed: bool = False
    submit_t: float = 0.0
    start_t: Optional[float] = None
    done_t: Optional[float] = None

    @property
    def done(self) -> bool:
        """True once the ticket reached a terminal status."""
        return self.status != PENDING

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Seconds spent queued before batch formation (None if never
        drafted — rejected/shed requests have no start time)."""
        if self.start_t is None:
            return None
        return max(0.0, self.start_t - self.submit_t)

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-terminal seconds (None while PENDING)."""
        if self.done_t is None:
            return None
        return max(0.0, self.done_t - self.submit_t)


@dataclasses.dataclass
class LoopStats:
    """Loop-level totals — the reconciliation targets for the
    per-tenant obs counters (`sum over tenants == these`)."""
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    shed: int = 0
    served: int = 0
    failed: int = 0
    deadline_miss: int = 0
    batches: int = 0
    pumps: int = 0


@dataclasses.dataclass(eq=False)
class _Pending:
    """One admitted, not-yet-drafted request."""
    ticket: int
    tenant: str
    klass: str
    kind: str                    # "query"|"join"|"insert"|"delete"|"update"
    payload: dict
    deadline: Optional[float]
    seq: int


class _Registration:
    """One served table: its server, its tenant ACL, its admit-order
    pending list (mutations act as barriers within it)."""

    def __init__(self, name: str, server, tenants=None):
        self.name = name
        self.server = server
        self.tenants = None if tenants is None else frozenset(tenants)
        self.pending: List[_Pending] = []


class ServeLoop:
    """Always-on admission-controlled front door over query servers.

    Register any mix of `QueryServer` / `ShardedQueryServer` instances
    (each with its own KeySet — one per tenant if desired), then feed a
    continuous request stream through `submit*`; drive with `pump()` /
    `run_until_idle()` synchronously or `start()` a daemon thread.
    See the module docstring for scheduling/admission semantics."""

    def __init__(self, *, policy: Optional[AdmissionPolicy] = None,
                 batch: int = 8, pow2_buckets: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 monitor=None, monitor_host: int = 0,
                 max_responses: int = 65536):
        self.policy = policy or AdmissionPolicy()
        self.batch = int(batch)
        self.pow2_buckets = bool(pow2_buckets)
        self.clock = clock
        # optional launch/elastic.FleetMonitor: each pump heartbeats
        # `monitor_host` with the pump's wall time, so the elastic
        # scaffolding's dead-host/straggler logic watches the loop
        self.monitor = monitor
        self.monitor_host = monitor_host
        # retention bound for the always-on mode: only the most recent
        # `max_responses` TERMINAL responses (and batch shapes) are
        # kept — unread older terminals are evicted oldest-first, so a
        # continuous stream cannot grow loop memory without bound.
        # PENDING responses are never evicted (callers also `forget()`
        # terminals they have consumed to release results eagerly)
        self.max_responses = int(max_responses)
        self.stats = LoopStats()
        self.batch_shapes: List[Tuple[str, str, int]] = []  # (table, klass, size)
        self._regs: Dict[str, _Registration] = {}
        self._responses: Dict[int, Response] = {}
        self._terminal: "collections.deque[int]" = collections.deque()
        self._next_ticket = 0
        self._next_seq = 0
        self._lock = threading.Lock()        # queue + response state
        self._pump_lock = threading.Lock()   # one scheduling round at a time
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- registration ------------------------------------------------------

    def register(self, name: str, server, *, tenants=None) -> None:
        """Serve `server` (a QueryServer or ShardedQueryServer, carrying
        its own KeySet) under table name `name`.  `tenants` restricts
        who may submit to it (None = open) — registering one table per
        tenant with an ACL gives per-tenant keys behind one loop."""
        with self._lock:
            self._regs[name] = _Registration(name, server, tenants)

    def tables(self) -> List[str]:
        """Registered table names, in registration order."""
        return list(self._regs)

    # -- classification ----------------------------------------------------

    def _classify(self, reg: _Registration, query) -> str:
        """`point` iff every filter leaf rides one of the server's
        sorted indexes and there is no order/top-k stage; else `bulk`.
        (Select-all is a full scan; sorts pay bitonic networks.)"""
        plan = P.compile_plan(query)
        q = plan.query
        if q.order_by is not None or q.top_k is not None:
            return BULK
        if not plan.leaves:
            return BULK
        indexes = reg.server.indexes
        if all(leaf.column in indexes for leaf in plan.leaves):
            return POINT
        return BULK

    # -- admission ---------------------------------------------------------

    def _admit_error(self, reg: _Registration, tenant: str) -> str:
        """Reason to reject, or '' to admit (caller holds the lock)."""
        if reg.tenants is not None and tenant not in reg.tenants:
            return f"tenant {tenant!r} not authorized for table {reg.name!r}"
        total = sum(len(r.pending) for r in self._regs.values())
        if total >= self.policy.total_queue_cap:
            return f"loop queue full ({total} pending)"
        depth = sum(1 for r in self._regs.values()
                    for p in r.pending if p.tenant == tenant)
        if depth >= self.policy.tenant_queue_cap:
            return f"tenant {tenant!r} queue full ({depth} pending)"
        return ""

    def _admit(self, tenant: str, table: str, klass: str, kind: str,
               payload: dict, deadline: Optional[float], *,
               reject: str = "") -> int:
        """Create the ticket; enqueue or immediately REJECT.  A
        non-empty `reject` reason rejects unconditionally — the request
        is never enqueued, so no pump can race a draft against the
        rejection."""
        with self._lock:
            reg = self._regs.get(table)
            if reg is None:
                raise KeyError(f"no table {table!r} registered")
            now = self.clock()
            ticket = self._next_ticket
            self._next_ticket += 1
            self.stats.submitted += 1
            resp = Response(ticket=ticket, tenant=tenant, table=table,
                            klass=klass, deadline=deadline, submit_t=now)
            self._responses[ticket] = resp
            reason = reject or self._admit_error(reg, tenant)
            if reason:
                resp.status = REJECTED
                resp.error = reason
                resp.done_t = now
                self.stats.rejected += 1
                self._retire(ticket)
                obs.count("serve.rejected", 1, tenant=tenant)
                return ticket
            seq = self._next_seq
            self._next_seq += 1
            reg.pending.append(_Pending(ticket, tenant, klass, kind,
                                        payload, deadline, seq))
            self.stats.admitted += 1
            obs.observe("serve.queue_depth",
                        sum(len(r.pending) for r in self._regs.values()))
            return ticket

    def _retire(self, ticket: int) -> None:
        """Record a newly-terminal ticket; evict the oldest retained
        terminals (and batch shapes) past `max_responses` (caller holds
        the lock)."""
        self._terminal.append(ticket)
        while len(self._terminal) > self.max_responses:
            self._responses.pop(self._terminal.popleft(), None)
        if len(self.batch_shapes) > self.max_responses:
            del self.batch_shapes[:-self.max_responses]

    # -- submission --------------------------------------------------------

    def submit(self, tenant: str, table: str, query, *,
               deadline: Optional[float] = None,
               klass: Optional[str] = None) -> int:
        """Submit a Query (or bare predicate) for `tenant` against
        `table`; returns a ticket.  `deadline` (loop-clock seconds) is
        shed-or-flag advisory; `klass` overrides auto classification
        ("point"/"bulk"; anything else raises ValueError — an unknown
        class would pend forever, no pump drafts it)."""
        if klass is not None and klass not in (POINT, BULK):
            raise ValueError(
                f"klass must be {POINT!r} or {BULK!r}, got {klass!r}")
        reg = self._regs.get(table)
        if reg is None:
            raise KeyError(f"no table {table!r} registered")
        if isinstance(query, P.Predicate):
            query = P.Query(where=query)
        klass = klass or self._classify(reg, query)
        return self._admit(tenant, table, klass, "query",
                           {"query": query}, deadline)

    def submit_join(self, tenant: str, table: str, join: P.Join, right, *,
                    right_indexes=None, strategy: str = "auto",
                    deadline: Optional[float] = None) -> int:
        """Submit a Join (left side = `table`'s server) — always bulk
        class.  REJECTED with an explanatory error if the server has no
        join support (the sharded server does not, yet)."""
        if not hasattr(self._require(table).server, "submit_join"):
            # rejected inside _admit, atomically: the request is never
            # enqueued, so a concurrent pump cannot draft (and fail) it
            # before the rejection lands
            return self._admit(
                tenant, table, BULK, "join", {}, deadline,
                reject=(f"table {table!r}'s server does not "
                        "support joins"))
        P.compile_join(join)      # validate shape at submit time
        return self._admit(tenant, table, BULK, "join",
                           {"join": join, "right": right,
                            "right_indexes": right_indexes,
                            "strategy": strategy}, deadline)

    def submit_insert(self, tenant: str, table: str, data, key, *,
                      deadline: Optional[float] = None) -> int:
        """Submit an insert — write class, an ordering barrier: queries
        admitted after it (on this table) see the new rows.  Writes are
        never shed (shedding one would break read-your-admitted-writes
        for every later query)."""
        return self._admit(tenant, table, WRITE, "insert",
                           {"data": data, "key": key}, deadline)

    def submit_delete(self, tenant: str, table: str, rows, *,
                      deadline: Optional[float] = None) -> int:
        """Submit a tombstone of global row ids — write class/barrier."""
        return self._admit(tenant, table, WRITE, "delete",
                           {"rows": np.asarray(rows, np.int64)}, deadline)

    def submit_update(self, tenant: str, table: str, rows, data, key, *,
                      deadline: Optional[float] = None) -> int:
        """Submit an update (tombstone + replacement insert) — write
        class/barrier."""
        return self._admit(tenant, table, WRITE, "update",
                           {"rows": np.asarray(rows, np.int64),
                            "data": data, "key": key}, deadline)

    # -- results -----------------------------------------------------------

    def response(self, ticket: int) -> Response:
        """The Response for `ticket` (PENDING until a pump resolves it).
        KeyError once the terminal response has been `forget()`-acked or
        evicted past the `max_responses` retention bound."""
        with self._lock:
            return self._responses[ticket]

    def responses(self) -> Dict[int, Response]:
        """Snapshot of every RETAINED ticket's Response (terminals past
        the `max_responses` bound are evicted oldest-first)."""
        with self._lock:
            return dict(self._responses)

    def forget(self, ticket: int) -> Optional[Response]:
        """Ack-and-release one TERMINAL response (returns it, or None if
        unknown/already released) — continuous-stream clients forget
        tickets as they consume them so results are not pinned until
        the retention bound evicts them.  PENDING tickets are refused
        (ValueError): their result has nowhere else to land."""
        with self._lock:
            resp = self._responses.get(ticket)
            if resp is None:
                return None
            if resp.status == PENDING:
                raise ValueError(f"ticket {ticket} is still PENDING")
            return self._responses.pop(ticket)

    def queue_depth(self, tenant: Optional[str] = None) -> int:
        """Pending (admitted, not yet drafted) request count, optionally
        for one tenant."""
        with self._lock:
            return sum(1 for r in self._regs.values() for p in r.pending
                       if tenant is None or p.tenant == tenant)

    def _require(self, table: str) -> _Registration:
        reg = self._regs.get(table)
        if reg is None:
            raise KeyError(f"no table {table!r} registered")
        return reg

    # -- scheduling --------------------------------------------------------

    def pump(self) -> int:
        """Run ONE scheduling round: first apply every table's head run
        of writes (the admission-order barriers), then draft + run one
        POINT batch per table — across ALL tables, so no table's point
        lookups wait behind another table's scan — then one BULK batch
        per table.  Returns the number of requests resolved this
        round."""
        with self._pump_lock:
            t0 = time.perf_counter()
            done = 0
            with obs.span("serve.pump"):
                regs = [self._regs[n] for n in list(self._regs)]
                for reg in regs:
                    done += self._apply_head_writes(reg)
                for klass in (POINT, BULK):
                    for reg in regs:
                        done += self._draft_and_run(reg, klass)
                with self._lock:
                    depth = sum(len(r.pending)
                                for r in self._regs.values())
                obs.observe("serve.queue_depth", depth)
            self.stats.pumps += 1
            if self.monitor is not None:
                self.monitor.heartbeat(self.monitor_host,
                                       step_time=time.perf_counter() - t0)
            return done

    def _apply_head_writes(self, reg: _Registration) -> int:
        """Apply the maximal run of writes at the head of `reg`'s admit
        order (they are barriers: nothing admitted before them is still
        pending)."""
        with self._lock:
            writes: List[_Pending] = []
            while reg.pending and reg.pending[0].klass == WRITE:
                writes.append(reg.pending.pop(0))
        done = 0
        for p in writes:
            done += self._run_write(reg, p)
        return done

    def _draft_and_run(self, reg: _Registration, klass: str) -> int:
        """Draft + run one `klass` batch from the admit-order window
        before `reg`'s next write barrier; shed expired requests at
        formation time."""
        done = 0
        with self._lock:
            window: List[_Pending] = []
            for p in reg.pending:
                if p.klass == WRITE:
                    break
                window.append(p)
            shed = [p for p in window
                    if p.deadline is not None
                    and self.clock() > p.deadline]
            cands = [p for p in window
                     if p.klass == klass and p not in shed]
            drafted = self._draft(cands)
            lift = {p.ticket for p in drafted} | {p.ticket for p in shed}
            reg.pending = [p for p in reg.pending
                           if p.ticket not in lift]
        for p in shed:
            self._finish(p, SHED,
                         error="deadline passed before batch formation")
            done += 1
        if drafted:
            done += self._run_batch(reg, drafted, klass)
        return done

    def _draft(self, cands: List[_Pending]) -> List[_Pending]:
        """Fair-share round-robin draft, pow2-bucketed.

        Tenants are visited in order of their head request's (deadline,
        admit seq); each visit takes the tenant's next request (FIFO),
        capped at `fair_share` per tenant when contended.  The drafted
        size then rounds DOWN to a power of two so batch shapes cycle
        through a small closed set and the jit cache stays hot."""
        if not cands:
            return []
        by_tenant: Dict[str, List[_Pending]] = {}
        for p in cands:
            by_tenant.setdefault(p.tenant, []).append(p)
        inf = float("inf")
        order = sorted(by_tenant, key=lambda t: (
            inf if by_tenant[t][0].deadline is None
            else by_tenant[t][0].deadline, by_tenant[t][0].seq))
        fair = (self.policy.fair_share if len(order) > 1
                else self.batch)
        out: List[_Pending] = []
        taken = dict.fromkeys(order, 0)
        progress = True
        while len(out) < self.batch and progress:
            progress = False
            for t in order:
                if len(out) >= self.batch:
                    break
                if by_tenant[t] and taken[t] < fair:
                    out.append(by_tenant[t].pop(0))
                    taken[t] += 1
                    progress = True
        if self.pow2_buckets and len(out) > 1:
            out = out[:1 << (len(out).bit_length() - 1)]
        return out

    # -- execution ---------------------------------------------------------

    def _submit_one(self, server, p: _Pending) -> int:
        """Forward one drafted request to its underlying server."""
        pl = p.payload
        if p.kind == "query":
            return server.submit(pl["query"], tenant=p.tenant)
        if p.kind == "join":
            return server.submit_join(
                pl["join"], pl["right"],
                right_indexes=pl["right_indexes"],
                strategy=pl["strategy"], tenant=p.tenant)
        if p.kind == "insert":
            return server.submit_insert(pl["data"], pl["key"],
                                        tenant=p.tenant)
        if p.kind == "delete":
            return server.submit_delete(pl["rows"], tenant=p.tenant)
        if p.kind == "update":
            return server.submit_update(pl["rows"], pl["data"], pl["key"],
                                        tenant=p.tenant)
        raise ValueError(f"unknown request kind {p.kind!r}")

    def _run_write(self, reg: _Registration, p: _Pending) -> int:
        """Apply one mutation (isolated: a failing write resolves FAILED
        without poisoning the loop)."""
        server = reg.server
        with obs.span("serve.batch", table=reg.name, klass=WRITE, size=1):
            self._mark_start([p], WRITE)
            try:
                qid = self._submit_one(server, p)
                res = server.run()
                self._finish(p, OK, result=res[qid])
            except Exception as e:          # noqa: BLE001 — isolate faults
                server.clear_queue()
                self._finish(p, FAILED, error=f"{type(e).__name__}: {e}")
        self.stats.batches += 1
        self.batch_shapes.append((reg.name, WRITE, 1))
        return 1

    def _mark_start(self, drafted: List[_Pending], klass: str) -> None:
        """Stamp batch-formation time + queue-wait histograms."""
        now = self.clock()
        with self._lock:
            for p in drafted:
                resp = self._responses[p.ticket]
                resp.start_t = now
                obs.observe("serve.queue_wait_s",
                            max(0.0, now - resp.submit_t), klass=klass)

    def _run_batch(self, reg: _Registration, drafted: List[_Pending],
                   klass: str) -> int:
        """Run one drafted read batch through the server as ONE shared-
        launch drain; on failure, retry requests individually so only
        the poisoned one resolves FAILED."""
        server = reg.server
        size = len(drafted)
        self.batch_shapes.append((reg.name, klass, size))
        self.stats.batches += 1
        with obs.span("serve.batch", table=reg.name, klass=klass,
                      size=size):
            self._mark_start(drafted, klass)
            try:
                with server.batch_size(size):
                    qids = {p.ticket: self._submit_one(server, p)
                            for p in drafted}
                    res = server.run()
                for p in drafted:
                    self._finish(p, OK, result=res[qids[p.ticket]])
            except Exception:               # noqa: BLE001 — isolate faults
                server.clear_queue()        # drop the failed drain's leftovers
                for p in drafted:
                    try:
                        with server.batch_size(1):
                            qid = self._submit_one(server, p)
                            res = server.run()
                        self._finish(p, OK, result=res[qid])
                    except Exception as e:  # noqa: BLE001
                        server.clear_queue()
                        self._finish(p, FAILED,
                                     error=f"{type(e).__name__}: {e}")
        return size

    def _finish(self, p: _Pending, status: str, *, result=None,
                error: str = "") -> None:
        """Resolve one ticket to a terminal status + bill loop stats."""
        with self._lock:
            resp = self._responses[p.ticket]
            resp.status = status
            resp.result = result
            resp.error = error
            resp.done_t = self.clock()
            self._retire(p.ticket)
            if status == OK:
                self.stats.served += 1
                if (p.deadline is not None
                        and resp.done_t > p.deadline):
                    resp.deadline_missed = True
                    self.stats.deadline_miss += 1
                    obs.count("serve.deadline_miss", 1, tenant=p.tenant)
            elif status == FAILED:
                self.stats.failed += 1
                obs.count("serve.failed", 1, tenant=p.tenant)
            elif status == SHED:
                self.stats.shed += 1
                obs.count("serve.shed", 1, tenant=p.tenant)

    # -- drive modes -------------------------------------------------------

    def run_until_idle(self, max_pumps: int = 100_000) -> Dict[int, Response]:
        """Pump until every admitted request has a terminal response;
        returns the response snapshot.  `max_pumps` guards against a
        runaway loop (it should never bind: every pump with pending
        work resolves at least one request)."""
        pumps = 0
        while self.queue_depth() > 0:
            if pumps >= max_pumps:
                raise RuntimeError("run_until_idle: max_pumps exceeded")
            self.pump()
            pumps += 1
        return self.responses()

    def start(self, interval_s: float = 0.005) -> None:
        """Start the always-on daemon thread: pump whenever work is
        queued, idle-wait `interval_s` between empty rounds."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _forever():
            while not self._stop.is_set():
                if self.pump() == 0:
                    self._stop.wait(interval_s)

        self._thread = threading.Thread(target=_forever, daemon=True,
                                        name="repro-serve-loop")
        self._thread.start()

    def stop(self, timeout_s: float = 10.0) -> None:
        """Stop the daemon thread (waits for the in-flight pump)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=timeout_s)
        self._thread = None


# ---------------------------------------------------------------------------
# CLI demo: a short mixed-traffic run against one table
# ---------------------------------------------------------------------------

def main(argv=None) -> dict:
    """CLI demo: admit a stream of random point + range queries through
    the loop and print latency/shed stats (see module docstring)."""
    import jax
    import jax.numpy as jnp

    from repro.core import encrypt as E
    from repro.core.keys import keygen
    from repro.core.params import make_params
    from repro.db.index import SortedIndex
    from repro.db.query_serve import QueryServer
    from repro.db.table import Table

    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1024)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    vals = rng.integers(0, params.max_operand // 2,
                        args.rows).astype(np.int64)
    table = Table.from_arrays(ks, "demo", {"value": vals},
                              jax.random.PRNGKey(args.seed + 1))
    indexes = {"value": SortedIndex.build(ks, table, "value")}
    server = QueryServer(ks, table, indexes=indexes, batch=args.batch)

    loop = ServeLoop(batch=args.batch)
    loop.register("demo", server)
    t0 = time.perf_counter()
    for i in range(args.requests):
        v = int(rng.choice(vals))
        ct = E.encrypt(ks, jnp.asarray(v),
                       jax.random.PRNGKey(int(rng.integers(1 << 30))))
        loop.submit("tenant%d" % (i % 4), "demo", P.Eq("value", ct))
    res = loop.run_until_idle()
    wall = time.perf_counter() - t0
    lat = sorted(r.latency_s for r in res.values() if r.status == OK)
    out = {
        "requests": args.requests,
        "served": loop.stats.served,
        "rejected": loop.stats.rejected,
        "shed": loop.stats.shed,
        "batches": loop.stats.batches,
        "p50_ms": round(1e3 * lat[len(lat) // 2], 3) if lat else None,
        "p99_ms": round(1e3 * lat[min(len(lat) - 1,
                                      int(0.99 * len(lat)))], 3)
        if lat else None,
        "qps": round(args.requests / wall, 2),
    }
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
