"""Distribution: sharding rules and collective helpers."""
