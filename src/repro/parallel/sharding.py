"""Name-based sharding rules (t5x-style): param-tree paths -> PartitionSpec.

Strategy (DESIGN.md §5):
  * TP: attention heads / FFN hidden / experts / vocab on the `model` axis.
  * FSDP/ZeRO-3: the contracting (d_model/ff-in) dim of every large matrix on
    the `data` axis — params AND Adam moments are fully sharded, which is
    what lets 34B-param train cells fit 16 GiB/chip (XLA all-gathers weights
    per layer and reduce-scatters grads).
  * `pod` composes with `data` for the batch; params are not sharded over
    `pod` (weight all-gathers stay intra-pod; only grad reduction crosses).
  * Scanned stacks carry a leading group axis -> rules key on trailing dims.

Small / state-like leaves (norm scales, biases, RG-LRU gates, routers)
replicate — sharding them buys nothing and costs collectives.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# (regex on "/"-joined path, spec for the LAST ndim dims of the leaf)
_PARAM_RULES = [
    # embeddings: vocab on model; d replicated (gather stays cheap)
    (r"(^|/)unembed$",             P(None, "model")),
    (r"(^|/)embed$",               P("model", None)),
    # attention (leading scan-group axis handled by padding below)
    (r"attn/w(q|k|v)$",            P("data", "model")),
    (r"attn/wo$",                  P("model", "data")),
    (r"cross/w(q|k|v)$",           P("data", "model")),
    (r"cross/wo$",                 P("model", "data")),
    # MLA
    (r"attn/wq_down$",             P("data", None)),
    (r"attn/wq_up$",               P(None, "model")),
    (r"attn/wkv_down$",            P("data", None)),
    (r"attn/w(k|v)_up$",           P(None, "model")),
    # dense FFN
    (r"ffn/w(i|g)$",               P("data", "model")),
    (r"ffn/wo$",                   P("model", "data")),
    (r"shared/w(i|g)$",            P("data", "model")),
    (r"shared/wo$",                P("model", "data")),
    # MoE: experts on model (EP), contracting dim on data (FSDP)
    (r"moe/experts_w(i|g)$",       P("model", "data", None)),
    (r"moe/experts_wo$",           P("model", None, "data")),
    (r"moe/router$",               P("data", None)),
    # RG-LRU
    (r"rec/w_(gate|in)$",          P("data", "model")),
    (r"rec/w_out$",                P("model", "data")),
    (r"rec/conv_k$",               P(None, "model")),
    (r"rec/(lam|gate_a|gate_x|bias_a|bias_x)$", P("model")),
    # xLSTM (small models: replicate weights, shard batch only)
    (r"cell/.*$",                  None),
    # norms / everything else: replicate
    (r".*$",                       None),
]


def _spec_for(path: str, ndim: int) -> P:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            if spec is None:
                return P()
            pad = ndim - len(spec)
            assert pad >= 0, f"{path}: rule {spec} too long for ndim {ndim}"
            return P(*([None] * pad + list(spec)))
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_specs(params: PyTree) -> PyTree:
    """PartitionSpec tree matching `params`."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _spec_for(_path_str(path), x.ndim), params)


def param_shardings(mesh: Mesh, params: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params),
                        is_leaf=lambda s: isinstance(s, P))


def batch_axes(mesh: Mesh):
    """The composite batch axis — ('pod','data') by default; small-model
    cells override via constrain.set_batch_axes (DP-over-model layout)."""
    from repro.parallel.constrain import get_batch_axes
    return get_batch_axes(mesh)


def choose_layout(mesh: Mesh, param_count: int, global_batch: int,
                  small_model_threshold: int = 1_000_000_000):
    """Pick batch axes for a cell.  Models small enough to replicate
    (params + f32 Adam moments < ~10 GiB/chip) re-purpose the model axis
    for DP when the batch divides — a 360M model on 256 chips wants DP=256,
    not TP=16 (§Perf iteration A2).  Returns (batch_axes, replicate_params).
    """
    names = mesh.axis_names
    if param_count <= small_model_threshold:
        candidates = [("pod", "data", "model"), ("data", "model"),
                      ("pod", "data"), ("data",)]
        for cand in candidates:
            axes = tuple(a for a in cand if a in names)
            if not axes or set(axes) != set(cand) & set(names):
                continue
            import math
            size = math.prod(mesh.shape[a] for a in axes)
            if global_batch % size == 0 and "model" in axes:
                return axes, True
    return tuple(a for a in ("pod", "data") if a in names), False


def replicated_param_specs(params: PyTree) -> PyTree:
    return jax.tree.map(lambda x: P(), params,
                        is_leaf=lambda x: hasattr(x, "shape"))


def data_specs(mesh: Mesh, batch: PyTree) -> PyTree:
    """Shard every batch leaf on its leading (batch) dim."""
    b = batch_axes(mesh)
    def spec(x):
        return P(*( (b,) + (None,) * (x.ndim - 1) ))
    return jax.tree.map(spec, batch)


def cache_specs(mesh: Mesh, cache: PyTree) -> PyTree:
    """Decode-cache sharding: leaves are [G, B, T, ...] — B on batch axes,
    T (dim 2, when it is the long context axis) on `model`.  State-like
    leaves [G, B, ...] shard B only.  `pos` scalar replicates."""
    b = batch_axes(mesh)

    def spec(path, x):
        name = _path_str(path)
        if name.endswith("pos"):
            return P()
        if x.ndim >= 4 and re.search(r"(k|v|ckv|krope|ck|cv)$", name):
            # [G, B, T, ...]: shard T on model ONLY for genuinely long axes;
            # ring buffers (W = window) and encoder K/V stay local.
            t = x.shape[2]
            t_spec = "model" if t >= 8192 else None
            return P(*( (None, b, t_spec) + (None,) * (x.ndim - 3) ))
        if x.ndim >= 2:
            return P(*( (None, b) + (None,) * (x.ndim - 2) ))
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def to_shardings(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def leading_sharding(mesh: Mesh, ndim: int,
                     axis: str = "shard") -> NamedSharding:
    """NamedSharding splitting an array's LEADING dim over `axis` (the
    repro.db sharded-table layout: ciphertext stacks are [S, ...])."""
    return NamedSharding(mesh, P(axis, *([None] * (ndim - 1))))


def shard_leading(mesh: Mesh, tree: PyTree, axis: str = "shard") -> PyTree:
    """device_put every array leaf with its leading dim split over `axis`.

    Used by `db.shard.ShardSpec.place` to pin a sharded table's column
    stacks to the mesh at ingest, so every later jitted eval launch runs
    shard-parallel without resharding traffic."""
    return jax.tree.map(
        lambda x: jax.device_put(x, leading_sharding(mesh, x.ndim, axis)),
        tree)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        out = 1
        for a in entry:
            out *= mesh.shape[a]
        return out
    return mesh.shape[entry]


def sanitize_specs(mesh: Mesh, specs: PyTree, shapes: PyTree,
                   allow_move: bool = True) -> PyTree:
    """pjit in_shardings demand exact divisibility (unlike constraints).
    Drop axes that don't divide their dim; if a dropped axis can move to a
    sibling dim that divides and is unsharded, move it there (e.g.
    minicpm3's vocab 73448 %16 != 0 -> shard d_model instead).
    allow_move=False disables the move (fallback for cells where the moved
    layout trips XLA partitioner bugs — launch/dryrun.py retries with it)."""

    def fix(spec, shape):
        if not isinstance(spec, P):
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        dropped = []
        for i, (e, dim) in enumerate(zip(entries, shape)):
            if e is not None and dim % _axis_size(mesh, e) != 0:
                dropped.append(e)
                entries[i] = None
        if allow_move:
            for e in dropped:
                for i, (cur, dim) in enumerate(zip(entries, shape)):
                    if cur is None and dim % _axis_size(mesh, e) == 0 \
                            and dim >= _axis_size(mesh, e) \
                            and e not in entries:
                        entries[i] = e
                        break
        return P(*entries)

    spec_flat, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda s: isinstance(s, P))
    shape_flat = treedef.flatten_up_to(shapes)
    fixed = [fix(s, x.shape) for s, x in zip(spec_flat, shape_flat)]
    return jax.tree_util.tree_unflatten(treedef, fixed)
