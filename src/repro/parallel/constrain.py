"""Activation sharding constraints (MaxText-style anchors).

GSPMD propagation left to its own devices can resolve the FSDP weight
sharding against batch-sharded activations by REPLICATING THE BATCH
(observed: 19x per-device FLOP blow-up on the 16x16 mesh).  Pinning the
activation layout at block boundaries forces the intended resolution:
all-gather weights (cheap, overlappable), keep activations batch-sharded.

`shard(x, *dims)` is a no-op outside a mesh context, so model code runs
unchanged in single-device tests.  "batch" expands to ("pod","data") on
multi-pod meshes.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


# Per-cell layout override (§Perf iteration A2): small models re-purpose
# the `model` axis for data parallelism — set by launch/dryrun.py (and any
# caller that knows the arch scale) before tracing.
_BATCH_AXES_OVERRIDE = {"axes": None}


def set_batch_axes(axes):
    """axes: tuple of mesh axis names to use as the batch dim, or None for
    the default (pod, data)."""
    _BATCH_AXES_OVERRIDE["axes"] = axes


def get_batch_axes(mesh):
    if _BATCH_AXES_OVERRIDE["axes"] is not None:
        return tuple(a for a in _BATCH_AXES_OVERRIDE["axes"]
                     if a in mesh.axis_names)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard(x: jax.Array, *dims: str | None) -> jax.Array:
    """Constrain x: dims are per-axis entries; "batch" -> pod+data axes,
    "model" -> model axis, None -> unsharded."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    batch = get_batch_axes(mesh) or None
    model_taken = batch is not None and "model" in batch

    def axis_size(a):
        if a is None:
            return 1
        if isinstance(a, tuple):
            import math
            return math.prod(mesh.shape[x] for x in a)
        return mesh.shape[a]

    def resolve(d, size):
        if d == "batch":
            a = batch
        elif d == "model":
            # if the model axis is carrying batch (small-model DP layout),
            # tensor dims must not claim it
            a = "model" if ("model" in names and not model_taken) else None
        else:
            a = d
        if a is None:
            return None
        # GSPMD pads uneven shards: acceptable when size >= axis (waste
        # <= 1 shard, e.g. 56 heads on 16 -> 4/dev with slack), but
        # catastrophic when size < axis (kv=1 on 16 idles 15/16) — drop.
        return a if size >= axis_size(a) else None

    spec = P(*[resolve(d, s) for d, s in zip(dims, x.shape)])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
