"""Serving driver: batched prefill + decode over a request queue.

CPU-scale demo of the serving path the decode_32k/long_500k dry-run cells
lower.  Requests are grouped into fixed-size batches (static shapes =>
one compiled program); each batch runs prefill once then decodes greedily.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
      --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import serve as SV
from repro.models import transformer as T


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    T_max = args.prompt_len + args.gen

    prefill = jax.jit(lambda p, b: SV.prefill(cfg, p, b, T_max=T_max))
    decode = jax.jit(lambda p, c, t: SV.decode_step(cfg, p, c, t))

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.requests, args.prompt_len))
    outputs: List[np.ndarray] = []
    t0 = time.time()
    toks_generated = 0
    for i in range(0, args.requests, args.batch):
        chunk = prompts[i:i + args.batch]
        if len(chunk) < args.batch:            # pad the tail batch
            pad = args.batch - len(chunk)
            chunk = np.concatenate([chunk, chunk[:1].repeat(pad, 0)])
        batch = {"tokens": jnp.asarray(chunk, jnp.int32)}
        if cfg.frontend == "patches":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), cfg.dtype)
        if cfg.frontend == "frames":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        logits, cache = prefill(params, batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        gen = [tok]
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            gen.append(tok)
            toks_generated += args.batch
        outputs.append(np.stack([np.asarray(g) for g in gen], 1))
    dt = time.time() - t0
    result = {"requests": args.requests,
              "tokens_generated": int(args.gen * args.requests),
              "wall_s": round(dt, 3),
              "tok_per_s": round(args.gen * args.requests / dt, 2)}
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
