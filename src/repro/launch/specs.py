"""Input specs for every (architecture x shape) dry-run cell.

ShapeDtypeStruct stand-ins only — no device allocation.  The shape set per
the assignment:

    train_4k     seq=4096    gb=256   lowers train_step
    prefill_32k  seq=32768   gb=32    lowers prefill
    decode_32k   seq=32768   gb=128   lowers serve_step (1 token, full cache)
    long_500k    seq=524288  gb=1     lowers serve_step (sub-quadratic only)

Skips (DESIGN.md §4.1): long_500k is only legal for configs whose serve
state is O(1) in context (`cfg.sub_quadratic`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import serve as SV
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import train_lib as TL

PyTree = Any

SHAPES = {
    "train_4k":    dict(seq_len=4096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288, global_batch=1,   kind="decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention KV cache at 524k tokens is neither "
                       "sub-quadratic nor HBM-feasible; skipped per the "
                       "assignment rule (runs only for ssm/hybrid)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, seq: int, gb: int) -> Dict[str, Any]:
    b: Dict[str, Any] = {"tokens": _sds((gb, seq), jnp.int32)}
    if cfg.frontend == "patches":
        b["patches"] = _sds((gb, cfg.num_patches, cfg.d_model), cfg.dtype)
    if cfg.frontend == "frames":
        b["frames"] = _sds((gb, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return b


def train_state_specs(cfg: ModelConfig, tcfg: TL.TrainConfig) -> PyTree:
    return jax.eval_shape(
        lambda: TL.init_state(cfg, tcfg, jax.random.PRNGKey(0)))


def cache_specs_abstract(cfg: ModelConfig, gb: int, seq: int) -> PyTree:
    return jax.eval_shape(lambda: SV.init_cache(cfg, gb, seq))


def input_specs(cfg: ModelConfig, shape: str,
                tcfg: Optional[TL.TrainConfig] = None) -> Dict[str, Any]:
    """-> {"kind", "args": tuple of ShapeDtypeStruct pytrees}."""
    meta = SHAPES[shape]
    seq, gb, kind = meta["seq_len"], meta["global_batch"], meta["kind"]
    if kind == "train":
        tcfg = tcfg or TL.TrainConfig()
        return {"kind": "train",
                "args": (train_state_specs(cfg, tcfg),
                         batch_specs(cfg, seq, gb))}
    if kind == "prefill":
        params = jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        return {"kind": "prefill",
                "args": (params, batch_specs(cfg, seq, gb))}
    # decode: one token against a cache of length seq
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    cache = cache_specs_abstract(cfg, gb, seq)
    token = _sds((gb,), jnp.int32)
    return {"kind": "decode", "args": (params, cache, token)}
