"""Elastic scaling + straggler mitigation (the fleet-level control loop).

On a real fleet this daemon would:
  1. heartbeat every host; mark a host dead after `dead_after` missed beats
     (node failure) or persistently slow steps (straggler);
  2. tear the mesh down to the surviving host set, re-run
     `make_production_mesh`-style construction over fewer devices;
  3. restore the latest checkpoint (mesh-agnostic by construction —
     train/checkpoint.py stores full arrays) and resume from the same data
     index (counter-based pipeline => no sample skew).

The container has one host, so the logic is expressed over *simulated*
device sets and validated in tests/test_fault_tolerance.py — the decision
logic (who is dead, what mesh shape to rebuild, which step to resume) is
the part that must be correct; the transport is deployment-specific.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float
    step_times: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ElasticConfig:
    beat_interval_s: float = 10.0
    dead_after: int = 3                 # missed beats
    straggler_factor: float = 3.0       # x median step time
    straggler_strikes: int = 5
    min_hosts: int = 1


class FleetMonitor:
    """Tracks heartbeats + step times, decides evictions and mesh shape."""

    def __init__(self, cfg: ElasticConfig, host_ids: List[int],
                 now: Optional[float] = None):
        now = time.time() if now is None else now
        self.cfg = cfg
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, now) for h in host_ids}
        self.strikes: Dict[int, int] = {h: 0 for h in host_ids}

    def heartbeat(self, host_id: int, step_time: Optional[float] = None,
                  now: Optional[float] = None) -> None:
        now = time.time() if now is None else now
        h = self.hosts[host_id]
        h.last_beat = now
        if step_time is not None:
            h.step_times.append(step_time)

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        limit = self.cfg.beat_interval_s * self.cfg.dead_after
        return [h.host_id for h in self.hosts.values()
                if now - h.last_beat > limit]

    def stragglers(self) -> List[int]:
        times = [h.step_times[-1] for h in self.hosts.values()
                 if h.step_times]
        if len(times) < 3:
            return []
        med = sorted(times)[len(times) // 2]
        out = []
        for h in self.hosts.values():
            if h.step_times and h.step_times[-1] > \
                    self.cfg.straggler_factor * med:
                self.strikes[h.host_id] += 1
                if self.strikes[h.host_id] >= self.cfg.straggler_strikes:
                    out.append(h.host_id)
            else:
                self.strikes[h.host_id] = 0
        return out

    def evict(self, host_ids: List[int]) -> None:
        for h in host_ids:
            self.hosts.pop(h, None)
            self.strikes.pop(h, None)

    def surviving(self) -> List[int]:
        return sorted(self.hosts)


def plan_mesh(num_devices: int, model_parallel: int = 16
              ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (data, model) mesh that fits the surviving device set.

    Keeps the model axis fixed (weight shards must stay complete) and
    shrinks the data axis — the standard elastic-downscale move.  Falls
    back to smaller model axes when fewer than `model_parallel` devices
    survive.
    """
    while model_parallel > 1 and num_devices < model_parallel:
        model_parallel //= 2
    data = max(1, num_devices // model_parallel)
    return (data, model_parallel), ("data", "model")


def resume_plan(ckpt_dir: str) -> Optional[dict]:
    """What an elastic restart does: newest complete step + batch index."""
    from repro.train import checkpoint as CKPT
    CKPT.clean_incomplete(ckpt_dir)
    step = CKPT.latest_step(ckpt_dir)
    if step is None:
        return None
    return {"restore_step": step, "next_batch_index": step}
