"""Training driver (end-to-end; CPU-scale by default, mesh-ready).

Fault tolerance in this driver (tested in tests/test_fault_tolerance.py):
  * atomic checkpoints every --ckpt-every steps (+ async writer)
  * --resume auto: restart from the latest complete checkpoint; the
    counter-based data pipeline replays the exact batch sequence
  * watchdog: per-step wall-time EMA; a step exceeding
    --straggler-factor x EMA is logged as a straggler event (on real
    fleets this signal feeds launch/elastic.py)
  * --fail-at-step N: crash injection for the restart tests

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --variant train_100m --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.parallel import sharding as SH
from repro.train import checkpoint as CKPT
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_lib as TL


def get_cfg(arch: str, variant: str | None) -> ModelConfig:
    if variant:
        import importlib
        mod = importlib.import_module(f"repro.configs.{configs.canon(arch)}")
        return getattr(mod, variant)()
    return configs.get_reduced(arch)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--variant", default=None,
                    help="config factory name, e.g. train_100m / reduced")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_cfg(args.arch, args.variant)
    tcfg = TL.TrainConfig(
        opt=OPT.OptimizerConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                                total_steps=args.steps),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads)
    dcfg = DATA.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)

    mesh = make_host_mesh()
    with mesh:
        state = TL.init_state(cfg, tcfg, jax.random.PRNGKey(args.seed))
        start_step = 0
        if args.resume == "auto" and args.ckpt_dir:
            CKPT.clean_incomplete(args.ckpt_dir)
            last = CKPT.latest_step(args.ckpt_dir)
            if last is not None:
                state = CKPT.restore(args.ckpt_dir, last, state)
                start_step = last
                print(f"[resume] restored step {last}")

        step_fn = jax.jit(TL.make_train_step(cfg, tcfg), donate_argnums=0)
        losses = []
        ema = None
        writer = None
        for i, batch in enumerate(DATA.batches(dcfg, start_index=start_step)):
            step = start_step + i
            if step >= args.steps:
                break
            if args.fail_at_step is not None and step == args.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            if ema is not None and dt > args.straggler_factor * ema and step > 3:
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"(ema {ema:.2f}s) — would trigger mitigation")
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            losses.append(loss)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if writer is not None:
                    writer.join()
                writer = CKPT.save(args.ckpt_dir, step + 1, state,
                                   async_=True)
        if writer is not None:
            writer.join()
        if args.ckpt_dir:
            CKPT.save(args.ckpt_dir, args.steps, state)
            CKPT.keep_last(args.ckpt_dir, 3)
    result = {"first_loss": losses[0] if losses else None,
              "last_loss": losses[-1] if losses else None,
              "steps_run": len(losses)}
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
