import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count at first init.  Everything below is ordinary.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.launch import roofline as RL        # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (               # noqa: E402
    SHAPES, cell_supported, input_specs)
from repro.models import serve as SV           # noqa: E402
from repro.models import transformer as T      # noqa: E402
from repro.models.config import ModelConfig    # noqa: E402
from repro.parallel import sharding as SH      # noqa: E402
from repro.train import train_lib as TL        # noqa: E402
from repro.train.optimizer import AdamState    # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * memory_analysis()   — proves the partitioned program fits HBM
  * cost_analysis()     — per-device FLOPs / bytes for §Roofline
  * collective bytes    — parsed from the post-SPMD HLO text

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k [--multi-pod] [--all] [--out artifacts/dryrun]
"""

PyTree = Any


# sanitize-move toggle: conservative (drop-to-replicated) by default —
# measured better (minicpm3 train: 13.3s -> 0.7s collectives, the moved
# embed layout forced per-step activation regathers); run_cell retries
# WITH moves if the conservative layout fails to compile.
_ALLOW_MOVE = {"v": False}


def _state_spec_tree(state_specs: TL.TrainState) -> TL.TrainState:
    p_spec = SH.param_specs(state_specs.params)
    return TL.TrainState(
        params=p_spec,
        opt=AdamState(step=P(), mu=p_spec, nu=p_spec),
        compressor=None)


def build_cell(cfg: ModelConfig, shape: str, mesh,
               tcfg: Optional[TL.TrainConfig] = None):
    """-> (step_fn, args_specs, in_shardings, out_shardings).

    Chooses the cell's layout first (§Perf iteration A2): small models
    replicate params and spread the batch over ALL axes (pure DP) —
    callers must trace/lower while this layout is set.
    """
    from repro.parallel.constrain import set_batch_axes
    meta = SHAPES[shape]
    axes, replicate = SH.choose_layout(mesh, cfg.param_count(),
                                       meta["global_batch"])
    set_batch_axes(axes if replicate else None)
    param_specs_fn = (SH.replicated_param_specs if replicate
                      else SH.param_specs)

    spec = input_specs(cfg, shape, tcfg)
    kind = spec["kind"]
    args = spec["args"]
    named = lambda sp, shapes: SH.to_shardings(
        mesh, SH.sanitize_specs(mesh, sp, shapes,
                                allow_move=_ALLOW_MOVE["v"]))

    if kind == "train":
        tcfg = tcfg or TL.TrainConfig()
        step = TL.make_train_step(cfg, tcfg)
        state_specs, batch_specs = args
        p_spec = param_specs_fn(state_specs.params)
        st_spec = TL.TrainState(
            params=p_spec, opt=AdamState(step=P(), mu=p_spec, nu=p_spec),
            compressor=None)
        st_sh = named(st_spec, state_specs)
        in_sh = (st_sh, named(SH.data_specs(mesh, batch_specs), batch_specs))
        out_sh = (st_sh,
                  SH.to_shardings(mesh, {"loss": P(), "lr": P(),
                                         "grad_norm": P()}))
        fn = step
    elif kind == "prefill":
        params_specs, batch_specs = args
        b = SH.batch_axes(mesh)
        gb, seq = batch_specs["tokens"].shape
        cache_shapes = jax.eval_shape(lambda: SV.init_cache(cfg, gb, seq))
        logits_shape = jax.ShapeDtypeStruct((gb, cfg.vocab_size), cfg.dtype)
        in_sh = (named(param_specs_fn(params_specs), params_specs),
                 named(SH.data_specs(mesh, batch_specs), batch_specs))
        out_sh = (named(P(b, "model"), logits_shape),
                  named(SH.cache_specs(mesh, cache_shapes), cache_shapes))
        fn = lambda params, batch: SV.prefill(cfg, params, batch)
    else:  # decode
        params_specs, cache_specs_, token_spec = args
        b = SH.batch_axes(mesh)
        gb = token_spec.shape[0]
        logits_shape = jax.ShapeDtypeStruct((gb, cfg.vocab_size), cfg.dtype)
        cache_sh = named(SH.cache_specs(mesh, cache_specs_), cache_specs_)
        in_sh = (named(param_specs_fn(params_specs), params_specs),
                 cache_sh,
                 named(P(b), token_spec))
        out_sh = (named(P(b, "model"), logits_shape), cache_sh)
        fn = lambda params, cache, token: SV.decode_step(
            cfg, params, cache, token)
    return fn, args, in_sh, out_sh


def _depth_variant(cfg: ModelConfig, groups: int) -> ModelConfig:
    """Depth-scaled UNROLLED variant for exact cost measurement.

    XLA's cost_analysis counts while-loop bodies ONCE (verified 8x
    undercount on a scan), so the scanned full-depth compile cannot give
    roofline FLOPs.  Costs are linear in depth: measure unrolled G=1 and
    G=2, extrapolate  total(G) = f1 + (G-1) * (f2 - f1).
    """
    repl = dict(num_layers=groups * cfg.group_size, scan_layers=False)
    if cfg.is_encoder_decoder:
        repl["encoder_layers"] = groups
    return dataclasses.replace(cfg, **repl)


def _compile_cell(cfg: ModelConfig, shape: str, mesh, tcfg=None):
    fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh, tcfg)
    lowered = jax.jit(fn, in_shardings=in_sh,
                      out_shardings=out_sh).lower(*args)
    return lowered.compile()


def _extrapolated_cost(cfg: ModelConfig, shape: str, mesh) -> Dict:
    """(flops, bytes, per-op collective bytes) at full depth, per device."""
    G = cfg.num_groups
    c1 = _compile_cell(_depth_variant(cfg, 1), shape, mesh)
    f1 = c1.cost_analysis()
    h1 = RL.collective_bytes(c1.as_text())
    if G == 1:
        f2, h2 = f1, h1
    else:
        c2 = _compile_cell(_depth_variant(cfg, 2), shape, mesh)
        f2 = c2.cost_analysis()
        h2 = RL.collective_bytes(c2.as_text())

    def lin(a, b):
        return a + (G - 1) * (b - a)

    flops = lin(float(f1.get("flops", 0.0)), float(f2.get("flops", 0.0)))
    byts = lin(float(f1.get("bytes accessed", 0.0)),
               float(f2.get("bytes accessed", 0.0)))
    ops = set(h1) | set(h2)
    coll = {op: int(lin(h1.get(op, 0), h2.get(op, 0))) for op in ops}
    return {"flops": flops, "bytes accessed": byts, "collectives": coll}


def _slstm_correction(cfg: ModelConfig, shape: str, mesh) -> float:
    """sLSTM's hidden-to-hidden recurrence is a genuine while loop over S
    (cannot unroll 32k steps); add its per-step matmul FLOPs analytically."""
    n_slstm = sum(k == "slstm" for k in cfg.pattern) * cfg.num_groups
    if not n_slstm:
        return 0.0
    meta = SHAPES[shape]
    S = meta["seq_len"] if meta["kind"] != "decode" else 1
    if S <= 1:
        return 0.0
    gb = meta["global_batch"]
    chips_batch = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_dev = max(1, gb // chips_batch)
    w = cfg.d_model
    hd = w // cfg.num_heads
    per_step = b_dev * cfg.num_heads * hd * 4 * hd * 2
    mult = 3.0 if meta["kind"] == "train" else 1.0
    return n_slstm * (S - 1) * per_step * mult


def _auto_microbatches(cfg: ModelConfig, shape: str, mesh) -> int:
    """Grad-accumulation factor so the scan residual (x carried per group,
    bf16) stays under ~2 GiB/device in the memory-fit compile.  Respects
    the cell's chosen layout (small models spread batch over model too,
    so their per-device batch is already tiny)."""
    import math
    meta = SHAPES[shape]
    if meta["kind"] != "train":
        return 1
    axes, _ = SH.choose_layout(mesh, cfg.param_count(),
                               meta["global_batch"])
    chips_batch = math.prod(mesh.shape[a] for a in axes)
    b_dev = max(1, meta["global_batch"] // chips_batch)
    carry = cfg.num_groups * b_dev * meta["seq_len"] * cfg.d_model * 2
    budget = 2 * 2**30
    mb = 1
    while carry / mb > budget and mb < b_dev:
        mb *= 2
    return min(mb, b_dev)


# ---------------------------------------------------------------------------
# the paper's own workload as a dry-run cell: batched HADES comparisons
# sharded over the mesh (DESIGN.md §2.1 — the compare plane scales on the
# batch axis; each ciphertext's ring stays chip-local).
# ---------------------------------------------------------------------------

HADES_SHAPES = {"cmp_64k": 65536, "cmp_256k": 262144, "cmp_1m": 1048576,
                # §Perf iteration C: int32 at-rest ciphertexts (residues are
                # < 2^31; widen to int64 in-register) — halves HBM traffic
                "cmp_256k_c32": 262144}


def run_hades_cell(shape: str, multi_pod: bool) -> Dict:
    import jax.numpy as jnp
    from repro.core import compare as HC
    from repro.core import ring as HR
    from repro.core.encrypt import Ciphertext
    from repro.core.keys import KeySet
    from repro.core.params import make_params

    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    params = make_params("paper-bfv", mode="gadget")
    ring = HR.make_ring(params)
    K, n = params.num_towers, params.n
    E = K * params.gadget_digits_per_tower
    B = HADES_SHAPES[shape]
    compact = shape.endswith("_c32")
    b_axes = SH.batch_axes(mesh)

    def fn(cek_ntt, a0, a1, b0, b1):
        if compact:
            a0, a1, b0, b1 = (t.astype(jnp.int64) for t in (a0, a1, b0, b1))
        ks = KeySet(params=params, ring=ring, sk=None, pk0=None, pk1=None,
                    cek=None, cek_gadget=None, cek_gadget_ntt=cek_ntt)
        return HC.compare(ks, Ciphertext(a0, a1), Ciphertext(b0, b1))

    ct_dt = jnp.int32 if compact else jnp.int64
    ct_sds = jax.ShapeDtypeStruct((B, K, n), ct_dt)
    cek_sds = jax.ShapeDtypeStruct((E, K, n), jnp.int64)
    ct_sh = NamedSharding(mesh, P(b_axes, None, None))
    rep = NamedSharding(mesh, P())
    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            fn, in_shardings=(rep, ct_sh, ct_sh, ct_sh, ct_sh),
            out_shardings=NamedSharding(mesh, P(b_axes))).lower(
                cek_sds, ct_sds, ct_sds, ct_sds, ct_sds)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = RL.collective_bytes(compiled.as_text())
    # "useful" op count: (E fwd NTTs + 1 inv NTT) x K towers of
    # (n/2 log n) butterflies (~2 int-ops each) + E*K*n pointwise MACs,
    # per comparison, per device.
    b_dev = B / (mesh.shape.get("pod", 1) * mesh.shape["data"])
    log_n = n.bit_length() - 1
    useful = b_dev * K * ((E + 1) * (n // 2) * log_n * 2 + E * n * 2)
    flops = float(cost.get("flops", 0.0)) + float(
        cost.get("transcendentals", 0.0))
    # fused-kernel HBM floor: 4 ct components in + CEK + residues out
    # (the Pallas cmp_eval kernel keeps the whole pipeline VMEM-resident)
    ct_bytes = 4 if compact else 8
    floor = (b_dev * 4 * K * n * ct_bytes + E * K * n * 8 + b_dev * K * 8)
    terms = {
        "compute_s": flops / 197e12,
        "memory_s": floor / chips_hbm(),
        "memory_upper_s": float(cost.get("bytes accessed", 0.0))
        / chips_hbm(),
        "collective_s": sum(coll.values()) / 50e9,
    }
    dominant = max(
        {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        key=lambda k: terms[k]).replace("_s", "")
    return {
        "arch": "hades-cmp", "shape": shape, "mesh": mesh_name,
        "status": "ok", "chips": chips, "microbatches": 1,
        "cost_compile_s": 0.0,
        "memfit_compile_s": round(time.time() - t0, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
                / 2**30, 3),
        },
        "cost": {"flops": flops,
                 "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": {
            **{k: terms[k] for k in terms},
            "dominant": dominant,
            "model_flops_per_dev": useful,
            "useful_ratio": round(useful / max(flops, 1.0), 4),
            "roofline_fraction": round(
                (useful / 197e12) / max(terms.values()), 6),
            "step_time_s": max(terms.values()),
        },
    }


def chips_hbm() -> float:
    return 819e9


def run_cell(arch: str, shape: str, multi_pod: bool,
             save_hlo: bool = False) -> Dict:
    if arch == "hades-cmp":
        return run_hades_cell(shape, multi_pod)
    cfg = configs.get_config(arch)
    ok, why = cell_supported(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skip", "reason": why}
    meta = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    from repro.parallel.constrain import set_batch_axes
    try:
        for attempt in range(2):
            try:
                with mesh:
                    # (1) memory-fit compile: full depth, scanned, auto mb
                    mb = _auto_microbatches(cfg, shape, mesh)
                    tcfg = TL.TrainConfig(microbatches=mb)
                    compiled = _compile_cell(cfg, shape, mesh, tcfg)
                    t_compile = time.time() - t0
                    mem = compiled.memory_analysis()
                    # (2) cost compiles: unrolled G=1/G=2, extrapolated
                    cost = _extrapolated_cost(cfg, shape, mesh)
                    cost["flops"] += _slstm_correction(cfg, shape, mesh)
                    t_lower = time.time() - t0 - t_compile
                break
            except Exception:
                if attempt == 1:
                    raise
                # retry with sanitize-moves enabled (some cells need the
                # vocab->d_model / batch->seq moved layouts to shard)
                _ALLOW_MOVE["v"] = True
    finally:
        set_batch_axes(None)
        _ALLOW_MOVE["v"] = False
    data_shards = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    terms = RL.make_terms(cfg, arch, shape, mesh_name, chips, meta["kind"],
                          meta["seq_len"], meta["global_batch"], cost,
                          hlo_text=None, data_shards=data_shards)
    terms.coll_by_op = cost["collectives"]
    terms.coll_bytes_per_dev = float(sum(cost["collectives"].values()))
    terms.__post_init__()
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "chips": chips,
        "microbatches": mb,
        "cost_compile_s": round(t_lower, 2),
        "memfit_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
                / 2**30, 3),
        },
        "cost": {"flops": float(cost.get("flops", 0.0)),
                 "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "collectives": terms.coll_by_op,
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "memory_upper_s": terms.memory_upper_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "model_flops_per_dev": terms.model_flops_per_dev,
            "useful_ratio": round(terms.useful_ratio, 4),
            "roofline_fraction": round(terms.roofline_fraction, 6),
            "step_time_s": terms.step_time_s,
        },
    }
    if save_hlo:
        rec["hlo_len"] = len(hlo_text)
    return rec


def all_cells():
    for arch in configs.ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + list(HADES_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{configs.canon(arch)}_{shape}_{'2x16x16' if mp else '16x16'}"
            try:
                rec = run_cell(arch, shape, mp)
            except Exception as e:  # a failing cell is a bug in the system
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "error", "error": repr(e),
                       "trace": traceback.format_exc()[-2000:]}
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"[ok]   {tag:55s} mem/dev={rec['memory']['peak_per_device_gib']:7.2f}GiB "
                      f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                      f"coll={r['collective_s']:.3e}s dom={r['dominant']}")
            elif rec["status"] == "skip":
                print(f"[skip] {tag:55s} {rec['reason'][:60]}")
            else:
                print(f"[FAIL] {tag:55s} {rec['error'][:120]}")
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
