"""Assemble EXPERIMENTS.md tables from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--out artifacts]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

MESHES = ("16x16", "2x16x16")
SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k",
               "cmp_64k", "cmp_256k", "cmp_256k_c32", "cmp_1m")


def load(art_dir: str):
    recs = {}
    mtimes = {}
    for f in glob.glob(os.path.join(art_dir, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        arch = r["arch"].replace("-", "_")
        if arch == "hades_cmp":
            arch = "hades-cmp"
        r["arch"] = arch
        key = (arch, r["shape"], r["mesh"])
        mt = os.path.getmtime(f)
        if key not in recs or mt > mtimes[key]:     # newest wins
            recs[key] = r
            mtimes[key] = mt
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(recs, mesh="16x16") -> str:
    lines = [
        "| arch | shape | mem GiB/dev | compute | memory | collective | "
        "dominant | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({a for a, _, _ in recs})
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | — | — | — | — | "
                             f"SKIP (sub-quadratic rule) | — | — |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            ro = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | "
                f"{r['memory']['peak_per_device_gib']:.2f} | "
                f"{fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} | "
                f"{fmt_s(ro['collective_s'])} | {ro['dominant']} | "
                f"{ro['useful_ratio']:.3f} | {ro['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | mem GiB/dev | HLO GFLOP/dev | "
        "coll MB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh) in sorted(recs):
        r = recs[(arch, shape, mesh)]
        if r["status"] == "ok":
            coll = sum(r["collectives"].values()) / 1e6
            lines.append(
                f"| {arch} | {shape} | {mesh} | ok | "
                f"{r['memory']['peak_per_device_gib']:.2f} | "
                f"{r['cost']['flops']/1e9:.0f} | {coll:.0f} | "
                f"{r.get('memfit_compile_s', 0):.0f} |")
        else:
            lines.append(f"| {arch} | {shape} | {mesh} | {r['status']} | "
                         f"— | — | — | — |")
    return "\n".join(lines)


def summary(recs) -> str:
    n_ok = sum(r["status"] == "ok" for r in recs.values())
    n_skip = sum(r["status"] == "skip" for r in recs.values())
    n_err = sum(r["status"] == "error" for r in recs.values())
    doms = defaultdict(int)
    for r in recs.values():
        if r["status"] == "ok" and r["mesh"] == "16x16":
            doms[r["roofline"]["dominant"]] += 1
    return (f"cells: {n_ok} ok, {n_skip} skip, {n_err} error; "
            f"single-pod dominant terms: {dict(doms)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts")
    args = ap.parse_args()
    recs = load(args.art)
    print(summary(recs))
    with open(os.path.join(args.out, "roofline_16x16.md"), "w") as f:
        f.write(roofline_table(recs, "16x16"))
    with open(os.path.join(args.out, "roofline_2x16x16.md"), "w") as f:
        f.write(roofline_table(recs, "2x16x16"))
    with open(os.path.join(args.out, "dryrun_table.md"), "w") as f:
        f.write(dryrun_table(recs))
    print("tables written to", args.out)


if __name__ == "__main__":
    main()
