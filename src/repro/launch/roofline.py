"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds-per-step for the
per-device partitioned program XLA actually emitted:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

plus MODEL_FLOPS = 6*N*D (dense train) / 6*N_active*D (MoE) / 2*N per token
(decode), and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs * chips).

collective_bytes comes from parsing the post-SPMD HLO text — cost_analysis
does not expose it (see the brief).  We sum RESULT-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(the dominant cost for ring algorithms is ~result bytes on the wire;
all-reduce counted 2x for its reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import ModelConfig

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")

_ARR_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARR_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind result bytes from post-SPMD HLO (per device)."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue                       # counted at -start
        op = m.group("op")
        b = _type_bytes(m.group("type"))
        if op == "all-reduce":
            b *= 2                         # RS + AG phases on the wire
        out[op] = out.get(op, 0) + b
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float          # HLO bytes-accessed: UNFUSED upper bound
    coll_bytes_per_dev: float
    coll_by_op: Dict[str, int]
    model_flops_per_dev: float
    mem_floor_bytes: float = 0.0  # analytic fused floor (see memory_floor)
    compute_s: float = 0.0
    memory_s: float = 0.0         # floor-based (TPU fuses elementwise)
    memory_upper_s: float = 0.0   # unfused bytes-accessed bound
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops_per_dev / PEAK_FLOPS_BF16
        self.memory_upper_s = self.bytes_per_dev / HBM_BW
        floor = self.mem_floor_bytes or self.bytes_per_dev
        self.memory_s = floor / HBM_BW
        self.collective_s = self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'
        (catches remat recompute / dispatch waste)."""
        return (self.model_flops_per_dev / self.flops_per_dev
                if self.flops_per_dev else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak FLOP/s at the roofline step time (MFU bound)."""
        t = self.step_time_s
        return (self.model_flops_per_dev / PEAK_FLOPS_BF16) / t if t else 0.0


def model_flops(cfg: ModelConfig, shape_kind: str, seq: int, gb: int,
                chips: int) -> float:
    """Analytic MODEL_FLOPS per device per step."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        total = 6.0 * n_active * (seq * gb)
    elif shape_kind == "prefill":
        total = 2.0 * n_active * (seq * gb)
    else:  # decode: one token per sequence (+ attention reads not counted)
        total = 2.0 * n_active * gb
    return total / chips


def memory_floor(cfg: ModelConfig, shape_kind: str, seq: int, gb: int,
                 chips: int, data_shards: int) -> float:
    """Analytic per-device HBM-traffic floor (perfect fusion).

    HLO 'bytes accessed' counts every unfused op's operands — a gross
    upper bound on CPU-lowered modules.  The floor below is what a
    well-fused TPU program must still move:

      train   : params fwd-read + bwd-read + grad-write + opt m/v rw (f32)
                + one activation write+read per layer boundary
      prefill : params read + activations once + cache write
      decode  : active params read + full cache/state read (per token)
    """
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    p_dev = p_total * 2 / chips                    # bf16, fully sharded
    toks_dev = seq * gb / max(data_shards, 1)
    act_rw = 2 * toks_dev * cfg.d_model * 2 * cfg.num_layers
    if shape_kind == "train":
        opt_rw = p_total * 4 * 4 / chips           # m,v f32 read+write
        grads = p_total * 4 / chips
        return 3 * p_dev + opt_rw + grads + act_rw
    if shape_kind == "prefill":
        kv_dev = _cache_bytes(cfg, seq, gb) / chips
        return p_dev + act_rw + kv_dev
    # decode
    kv_dev = _cache_bytes(cfg, seq, gb) / chips
    return p_active * 2 / chips + kv_dev


def _cache_bytes(cfg: ModelConfig, seq: int, gb: int) -> float:
    if cfg.attention == "mla":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    else:
        per_tok = 2 * cfg.num_kv_heads * cfg.hd
    n_attn = sum(k in ("attn",) for k in cfg.pattern) * cfg.num_groups
    n_local = sum(k == "local" for k in cfg.pattern) * cfg.num_groups
    n_state = sum(k in ("rglru", "mlstm", "slstm")
                  for k in cfg.pattern) * cfg.num_groups
    total = n_attn * gb * seq * per_tok * 2
    total += n_local * gb * min(seq, cfg.window or seq) * per_tok * 2
    total += n_state * gb * 4 * cfg.d_model * 4     # rough state bytes
    return float(total)


def make_terms(cfg: ModelConfig, arch: str, shape: str, mesh_name: str,
               chips: int, shape_kind: str, seq: int, gb: int,
               cost: Dict, hlo_text: Optional[str],
               data_shards: int = 16) -> RooflineTerms:
    coll = collective_bytes(hlo_text) if hlo_text else {}
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_dev=float(cost.get("flops", 0.0)),
        bytes_per_dev=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=float(sum(coll.values())),
        coll_by_op=coll,
        model_flops_per_dev=model_flops(cfg, shape_kind, seq, gb, chips),
        mem_floor_bytes=memory_floor(cfg, shape_kind, seq, gb, chips,
                                     data_shards),
    )
