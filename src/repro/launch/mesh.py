"""Production mesh construction (the dry-run contract).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init).

Geometry: TPU v5e-256 pods.  Single pod = (data=16, model=16); two pods =
(pod=2, data=16, model=16).  `pod` composes with `data` for the batch
dimension; weights are never sharded across pods (DESIGN.md §5).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes),
                         devices=devices[:need])


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the real local devices (tests / CPU training)."""
    n = jax.device_count()
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


# TPU v5e single-chip peaks (roofline constants; see brief)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link
