"""Production mesh construction (the dry-run contract).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init).

Geometry: TPU v5e-256 pods.  Single pod = (data=16, model=16); two pods =
(pod=2, data=16, model=16).  `pod` composes with `data` for the batch
dimension; weights are never sharded across pods (DESIGN.md §5).
"""
from __future__ import annotations

import math

import jax

try:  # jax >= 0.5 (explicit-sharding axis types)
    from jax.sharding import AxisType
except ImportError:  # the baked jax 0.4.x: every mesh axis is Auto already
    AxisType = None


def _mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: pass axis_types only when the
    installed jax knows about them (0.4.x predates AxisType)."""
    kw = {"devices": devices} if devices is not None else {}
    if AxisType is not None:
        kw["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)")
    return _mesh(shape, axes, devices[:need])


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the real local devices (tests / CPU training)."""
    n = jax.device_count()
    dp = n // model_parallel
    return _mesh((dp, model_parallel), ("data", "model"))


def make_shard_mesh(num_shards: int, *, axis: str = "shard"):
    """1-D device mesh for `repro.db.shard` tables.

    Shard count is LOGICAL (chosen by the table's `ShardSpec`); this
    picks d = the largest divisor of `num_shards` the host can supply,
    so a `[num_shards, ...]`-leading ciphertext stack always places
    evenly — 4 shards run 4-way on a v5e slice, 2-way on a 2-device
    host, and degrade to one device without any caller change.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    devices = jax.devices()
    d = 1
    for cand in range(min(num_shards, len(devices)), 0, -1):
        if num_shards % cand == 0:
            d = cand
            break
    return _mesh((d,), (axis,), devices[:d])


# TPU v5e single-chip peaks (roofline constants; see brief)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link
