"""Pallas negacyclic-NTT kernels (TPU target, validated interpret=True).

TPU adaptation of the paper's OpenFHE CPU hot spot (DESIGN.md §3):

* A whole ciphertext block stays **VMEM-resident across all log2(n) butterfly
  stages** — one HBM round-trip per polynomial instead of one per stage.
  n = 4096 coeffs x 8 B = 32 KiB/poly; a (8, n) block + twiddles is ~0.5 MiB,
  far under the ~16 MiB VMEM budget.
* **No bit-reversal gathers anywhere**: the forward transform is
  decimation-in-frequency (natural -> bit-reversed "br-eval" order) and the
  inverse is decimation-in-time (br-eval -> natural). Pointwise products are
  order-agnostic, so the convolution pipeline never permutes. Gathers are the
  one op class that maps badly onto the TPU vector unit; reshapes/rolls here
  are lane-local.
* Modular arithmetic: residues < 2^31 so a*b fits int64; `%` is exact in
  interpret mode. Production-TPU note: int64 lowers to 32-bit pairs on TPU —
  the drop-in fix is 16-bit limb decomposition with int32 MACs (jaxite-style),
  which changes only the in-kernel `_mulmod` below, not the schedule.

Layout: polys are [B, K, n] (batch, RNS towers, coeffs). Grid = (B/bb, K);
each program transforms a (bb, n) tile for one tower.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ring as R

DEFAULT_BLOCK_B = 8


def _fwd_stages(x, stage_w, q, n):
    """DIF butterflies: natural order in -> br-eval order out. x: [bb, n]."""
    stages = n.bit_length() - 1
    for s in reversed(range(stages)):
        h = 1 << s
        m = 2 * h
        w = stage_w[s, :h]                       # [h]
        xr = x.reshape(-1, n // m, m)
        u, v = xr[..., :h], xr[..., h:]
        x = jnp.concatenate(
            [(u + v) % q, ((u - v) * w) % q], axis=-1).reshape(-1, n)
    return x


def _inv_stages(x, stage_w_inv, q, n):
    """DIT butterflies: br-eval order in -> natural order out."""
    stages = n.bit_length() - 1
    for s in range(stages):
        h = 1 << s
        m = 2 * h
        w = stage_w_inv[s, :h]
        xr = x.reshape(-1, n // m, m)
        u, v = xr[..., :h], xr[..., h:]
        t = (v * w) % q
        x = jnp.concatenate([(u + t) % q, (u - t) % q], axis=-1).reshape(-1, n)
    return x


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _ntt_kernel(x_ref, psi_ref, w_ref, q_ref, o_ref, *, n):
    q = q_ref[0]
    x = x_ref[:, 0, :]                            # [bb, n]
    x = (x * psi_ref[0]) % q                      # negacyclic pre-twist
    o_ref[:, 0, :] = _fwd_stages(x, w_ref[0], q, n)


def _intt_kernel(x_ref, psi_inv_ref, w_ref, q_ref, o_ref, *, n):
    q = q_ref[0]
    x = _inv_stages(x_ref[:, 0, :], w_ref[0], q, n)
    o_ref[:, 0, :] = (x * psi_inv_ref[0]) % q     # post-twist (n^-1 folded)


def _mul_kernel(a_ref, b_ref, psi_ref, psi_inv_ref, wf_ref, wi_ref, q_ref,
                o_ref, *, n):
    """Fused negacyclic multiply: twist -> DIF -> pointwise -> DIT -> twist.

    One kernel, one HBM round trip for a and b; zero gathers.
    """
    q = q_ref[0]
    a = (a_ref[:, 0, :] * psi_ref[0]) % q
    b = (b_ref[:, 0, :] * psi_ref[0]) % q
    a = _fwd_stages(a, wf_ref[0], q, n)
    b = _fwd_stages(b, wf_ref[0], q, n)
    prod = (a * b) % q
    out = _inv_stages(prod, wi_ref[0], q, n)
    o_ref[:, 0, :] = (out * psi_inv_ref[0]) % q


# ---------------------------------------------------------------------------
# pallas_call wrappers (shape plumbing only; public API in ops.py)
# ---------------------------------------------------------------------------

def _specs(bb: int, n: int, stages: int):
    """Common BlockSpecs: x-like [B,K,n], tables [K,...], q [K]."""
    x_spec = pl.BlockSpec((bb, 1, n), lambda i, k: (i, k, 0))
    psi_spec = pl.BlockSpec((1, n), lambda i, k: (k, 0))
    w_spec = pl.BlockSpec((1, stages, n // 2), lambda i, k: (k, 0, 0))
    q_spec = pl.BlockSpec((1,), lambda i, k: (k,))
    return x_spec, psi_spec, w_spec, q_spec


@functools.partial(jax.jit, static_argnames=("block_b", "interpret", "fwd"))
def ntt_br(x: jax.Array, ring: R.Ring, *, fwd: bool = True,
           block_b: int = DEFAULT_BLOCK_B, interpret: bool = True):
    """Forward (natural->br-eval) or inverse (br-eval->natural) NTT.

    x: [B, K, n] int64.  B must be a multiple of block_b (ops.py pads).
    """
    Bb, K, n = x.shape
    stages = n.bit_length() - 1
    bb = min(block_b, Bb)
    grid = (Bb // bb, K)
    x_spec, psi_spec, w_spec, q_spec = _specs(bb, n, stages)
    qs = ring.q_arr[:, 0]
    if fwd:
        kern = functools.partial(_ntt_kernel, n=n)
        tables = (ring.psi_pow, ring.stage_w, qs)
    else:
        kern = functools.partial(_intt_kernel, n=n)
        tables = (ring.psi_inv_pow, ring.stage_w_inv, qs)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[x_spec, psi_spec, w_spec, q_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, *tables)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def negacyclic_mul(a: jax.Array, b: jax.Array, ring: R.Ring, *,
                   block_b: int = DEFAULT_BLOCK_B, interpret: bool = True):
    """Fused a ⊛ b over [B, K, n] batches."""
    Bb, K, n = a.shape
    stages = n.bit_length() - 1
    bb = min(block_b, Bb)
    grid = (Bb // bb, K)
    x_spec, psi_spec, w_spec, q_spec = _specs(bb, n, stages)
    qs = ring.q_arr[:, 0]
    return pl.pallas_call(
        functools.partial(_mul_kernel, n=n),
        grid=grid,
        in_specs=[x_spec, x_spec, psi_spec, psi_spec, w_spec, w_spec, q_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b, ring.psi_pow, ring.psi_inv_pow, ring.stage_w, ring.stage_w_inv,
      qs)
