"""Fused HADES Eval kernel (Alg. 2 / Alg. 4 hot path).

Computes, per batched ciphertext difference (d0, d1):

    paper mode :  coeff0 of [ d0*scale + d1 ⊛ cek ]         (mod q, per tower)
    gadget mode:  coeff0 of [ d0*scale + Σ_e digit_e ⊛ cek_e ]

entirely inside one kernel: pre-twist, DIF-NTT, MAC against the CEK held in
br-eval order, DIT-INTT, post-twist, emit coefficient 0 only.

Roofline motivation (EXPERIMENTS.md §Perf): the naive pipeline writes the
full n-coefficient eval polynomial back to HBM (2*K*n*8 B per compare) and
re-reads it to decode; the comparison *result* is one residue per tower.
Fusing decode into the kernel cuts output bytes by n x (4096x for the paper
profile), turning the compare plane from memory-bound to compute-bound.

Same legality notes as kernels/ntt.py (no gathers, int64 MACs,
interpret-mode validated; ref.py is the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ring as R
from repro.core.keys import KeySet
from repro.kernels.ntt import _fwd_stages, _inv_stages

DEFAULT_BLOCK_B = 8


def _eval_paper_kernel(d0_ref, d1_ref, cek_ref, psi_ref, psi_inv_ref,
                       wf_ref, wi_ref, q_ref, scale_ref, o_ref, *, n):
    q = q_ref[0]
    scale = scale_ref[0]
    d1 = (d1_ref[:, 0, :] * psi_ref[0]) % q
    d1 = _fwd_stages(d1, wf_ref[0], q, n)
    prod = (d1 * cek_ref[0]) % q                    # cek already br-eval
    out = _inv_stages(prod, wi_ref[0], q, n)
    out = (out * psi_inv_ref[0]) % q
    # eval = d0*scale + d1 ⊛ cek ; decode -> coefficient 0 per tower
    o_ref[:, 0] = (d0_ref[:, 0, 0] * scale + out[:, 0]) % q


def _eval_gadget_kernel(d0_ref, dig_ref, cek_ref, psi_ref, psi_inv_ref,
                        wf_ref, wi_ref, q_ref, scale_ref, o_ref, *, n, E):
    """dig_ref: [bb, E, 1, n] digit polys (already < B, RNS-lift = identity);
    cek_ref: [E, 1, n] gadget CEK rows for this tower, br-eval order."""
    q = q_ref[0]
    scale = scale_ref[0]
    acc = jnp.zeros((dig_ref.shape[0], n), jnp.int64)
    for e in range(E):
        d = (dig_ref[:, e, 0, :] * psi_ref[0]) % q
        d = _fwd_stages(d, wf_ref[0], q, n)
        acc = (acc + (d * cek_ref[e, 0]) % q) % q   # MAC in eval domain
    out = _inv_stages(acc, wi_ref[0], q, n)
    out = (out * psi_inv_ref[0]) % q
    o_ref[:, 0] = (d0_ref[:, 0, 0] * scale + out[:, 0]) % q


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def eval_coeff0_paper(d0: jax.Array, d1: jax.Array, cek_br: jax.Array,
                      ring: R.Ring, scale: int, *,
                      block_b: int = DEFAULT_BLOCK_B,
                      interpret: bool = True) -> jax.Array:
    """[B, K, n] diff components + br-eval cek [K, n] -> coeff0 [B, K]."""
    Bb, K, n = d0.shape
    stages = n.bit_length() - 1
    bb = min(block_b, Bb)
    grid = (Bb // bb, K)
    x_spec = pl.BlockSpec((bb, 1, n), lambda i, k: (i, k, 0))
    tab_spec = pl.BlockSpec((1, n), lambda i, k: (k, 0))
    w_spec = pl.BlockSpec((1, stages, n // 2), lambda i, k: (k, 0, 0))
    q_spec = pl.BlockSpec((1,), lambda i, k: (k,))
    o_spec = pl.BlockSpec((bb, 1), lambda i, k: (i, k))
    scale_arr = jnp.full((K,), scale, jnp.int64)
    return pl.pallas_call(
        functools.partial(_eval_paper_kernel, n=n),
        grid=grid,
        in_specs=[x_spec, x_spec, tab_spec, tab_spec, tab_spec, w_spec,
                  w_spec, q_spec, q_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((Bb, K), jnp.int64),
        interpret=interpret,
    )(d0, d1, cek_br, ring.psi_pow, ring.psi_inv_pow, ring.stage_w,
      ring.stage_w_inv, ring.q_arr[:, 0], scale_arr)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def eval_coeff0_gadget(d0: jax.Array, digits: jax.Array,
                       cek_gadget_br: jax.Array, ring: R.Ring, scale: int, *,
                       block_b: int = DEFAULT_BLOCK_B,
                       interpret: bool = True) -> jax.Array:
    """digits: [B, E, K, n] (E = K_src*D gadget rows, values < B_gadget);
    cek_gadget_br: [E, K, n] br-eval order.  Returns coeff0 [B, K]."""
    Bb, E, K, n = digits.shape
    stages = n.bit_length() - 1
    bb = min(block_b, Bb)
    grid = (Bb // bb, K)
    x_spec = pl.BlockSpec((bb, 1, n), lambda i, k: (i, k, 0))
    dig_spec = pl.BlockSpec((bb, E, 1, n), lambda i, k: (i, 0, k, 0))
    cek_spec = pl.BlockSpec((E, 1, n), lambda i, k: (0, k, 0))
    tab_spec = pl.BlockSpec((1, n), lambda i, k: (k, 0))
    w_spec = pl.BlockSpec((1, stages, n // 2), lambda i, k: (k, 0, 0))
    q_spec = pl.BlockSpec((1,), lambda i, k: (k,))
    o_spec = pl.BlockSpec((bb, 1), lambda i, k: (i, k))
    scale_arr = jnp.full((K,), scale, jnp.int64)
    return pl.pallas_call(
        functools.partial(_eval_gadget_kernel, n=n, E=E),
        grid=grid,
        in_specs=[x_spec, dig_spec, cek_spec, tab_spec, tab_spec, w_spec,
                  w_spec, q_spec, q_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((Bb, K), jnp.int64),
        interpret=interpret,
    )(d0, digits, cek_gadget_br, ring.psi_pow, ring.psi_inv_pow,
      ring.stage_w, ring.stage_w_inv, ring.q_arr[:, 0], scale_arr)


# ---------------------------------------------------------------------------
# br-eval-order CEK precompute helpers
# ---------------------------------------------------------------------------

def cek_to_br(ks: KeySet) -> jax.Array:
    """Paper-mode cek -> br-eval order [K, n] (DIF output order)."""
    ev = R.ntt(ks.ring, ks.cek)
    return jnp.take(ev, ks.ring.bitrev, axis=-1)


def cek_gadget_to_br(ks: KeySet) -> jax.Array:
    """Gadget CEK -> [E, K, n] br-eval order."""
    params = ks.params
    E = params.num_towers * params.gadget_digits_per_tower
    flat = ks.cek_gadget_ntt.reshape(E, params.num_towers, params.n)
    return jnp.take(flat, ks.ring.bitrev, axis=-1)
