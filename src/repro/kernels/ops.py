"""Public jit'd wrappers around the Pallas kernels.

Handles batch padding (grid blocks need B % block_b == 0), backend dispatch
(interpret=True on CPU, compiled on TPU), and exposes a kernel-backed
`compare` with the same contract as core.compare — used by integration tests
and the benchmark harness to demonstrate the fused-path speedup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ring as R
from repro.core.compare import ct_sub
from repro.core.encrypt import Ciphertext
from repro.core.gadget import digit_decompose
from repro.core.keys import KeySet
from repro.kernels import cmp_eval as CK
from repro.kernels import ntt as NK


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_batch(x: jax.Array, block_b: int):
    b = x.shape[0]
    pad = (-b) % block_b
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, b


def ntt(x: jax.Array, ring: R.Ring, *, block_b: int = NK.DEFAULT_BLOCK_B,
        interpret: bool | None = None) -> jax.Array:
    """Forward negacyclic NTT (br-eval order). x: [B, K, n]."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    xp, b = _pad_batch(x, block_b)
    return NK.ntt_br(xp, ring, fwd=True, block_b=block_b,
                     interpret=interpret)[:b]


def intt(x: jax.Array, ring: R.Ring, *, block_b: int = NK.DEFAULT_BLOCK_B,
         interpret: bool | None = None) -> jax.Array:
    interpret = (not _on_tpu()) if interpret is None else interpret
    xp, b = _pad_batch(x, block_b)
    return NK.ntt_br(xp, ring, fwd=False, block_b=block_b,
                     interpret=interpret)[:b]


def negacyclic_mul(a: jax.Array, b: jax.Array, ring: R.Ring, *,
                   block_b: int = NK.DEFAULT_BLOCK_B,
                   interpret: bool | None = None) -> jax.Array:
    interpret = (not _on_tpu()) if interpret is None else interpret
    ap, nb = _pad_batch(a, block_b)
    bp, _ = _pad_batch(b, block_b)
    return NK.negacyclic_mul(ap, bp, ring, block_b=block_b,
                             interpret=interpret)[:nb]


def eval_values(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext, *,
                block_b: int = NK.DEFAULT_BLOCK_B,
                interpret: bool | None = None) -> jax.Array:
    """Kernel-backed centered eval values (Alg. 2 lines 2-4, no threshold).

    Returning the raw value lets callers apply their own decode threshold
    — the db executor thresholds per-atom (ε-tolerant CKKS equality) on
    ONE fused launch instead of one launch per distinct ε.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    params, rng = ks.params, ks.ring
    d = ct_sub(rng, ct0, ct1)
    d0p, b = _pad_batch(d.c0, block_b)
    d1p, _ = _pad_batch(d.c1, block_b)
    if params.mode == "paper":
        cek_br = CK.cek_to_br(ks)
        coeff0 = CK.eval_coeff0_paper(d0p, d1p, cek_br, rng, params.scale,
                                      block_b=block_b, interpret=interpret)
    else:
        digits = digit_decompose(params, d1p)          # [B, K, D, n]
        Bb = digits.shape[0]
        E = params.num_towers * params.gadget_digits_per_tower
        # rows: (k_src, digit) pairs; broadcast digit value to all towers
        dig = digits.reshape(Bb, E, 1, params.n)
        dig = jnp.broadcast_to(dig, (Bb, E, params.num_towers, params.n))
        cek_br = CK.cek_gadget_to_br(ks)
        coeff0 = CK.eval_coeff0_gadget(d0p, dig, cek_br, rng, params.scale,
                                       block_b=block_b, interpret=interpret)
    return R.crt_centered(params, coeff0[:b])


def compare(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext, *,
            block_b: int = NK.DEFAULT_BLOCK_B,
            interpret: bool | None = None) -> jax.Array:
    """Kernel-backed Algorithm 2 (-1/0/+1). Batched over leading dim."""
    v = eval_values(ks, ct0, ct1, block_b=block_b, interpret=interpret)
    return jnp.where(jnp.abs(v) < ks.params.tau,
                     0, jnp.sign(v)).astype(jnp.int32)
