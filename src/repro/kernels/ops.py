"""Public jit'd wrappers around the Pallas kernels.

Handles batch padding (grid blocks need B % block_b == 0), backend dispatch
(interpret=True on CPU, compiled on TPU), and exposes a kernel-backed
`compare` with the same contract as core.compare — used by integration tests
and the benchmark harness to demonstrate the fused-path speedup.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import ring as R
from repro.core.compare import ct_sub
from repro.core.encrypt import Ciphertext
from repro.core.gadget import digit_decompose
from repro.core.keys import KeySet
from repro.kernels import cmp_eval as CK
from repro.kernels import ntt as NK


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# lane-budget policy: the one knob bounding every eval launch's working set
# ---------------------------------------------------------------------------

# Default ceiling on eval LANES per launch (one lane = one [K, n]
# polynomial compare).  Every NTT-stage intermediate scales with the
# lane count, so this is the working-set bound that keeps a launch on
# the fast side of the cache/bandwidth cliff: the measured hg38 serving
# regression (ROADMAP) showed [2, 65536]-lane launches ~2x faster per
# lane than one [16, 65536] program — 1 << 17 is that fast regime's
# size.  Scan tiles (`db.executor.fused_eval`) and join grid tiles
# (`db.join.pair_eval_values`, via its own DEFAULT_BLOCK_PAIRS default)
# both resolve through this policy, so one knob governs both.
DEFAULT_LANE_BUDGET = 1 << 17

_LANE_BUDGET_OVERRIDE: int | None = None


def set_lane_budget(budget: int | None) -> int | None:
    """Install a process-wide lane-budget override (None clears it).

    Returns the previous override so callers can restore it — the knob
    every entry point resolves through `resolve_lane_budget`, preferred
    over threading a parameter when tuning a whole serving process.
    """
    global _LANE_BUDGET_OVERRIDE
    prev = _LANE_BUDGET_OVERRIDE
    _LANE_BUDGET_OVERRIDE = None if budget is None else int(budget)
    return prev


def resolve_lane_budget(explicit: int | None = None, *,
                        default: int = DEFAULT_LANE_BUDGET) -> int:
    """The effective lane budget: explicit argument > `set_lane_budget`
    override > `REPRO_LANE_BUDGET` env var > `default` (callers with
    their own historical default — join's `DEFAULT_BLOCK_PAIRS` — pass
    it here so the shared overrides still win)."""
    if explicit is not None:
        return int(explicit)
    if _LANE_BUDGET_OVERRIDE is not None:
        return _LANE_BUDGET_OVERRIDE
    env = os.environ.get("REPRO_LANE_BUDGET")
    if env:
        return int(env)
    return default


def lane_tile(n_rows: int, lanes_per_row: int,
              lane_budget: int | None = None, *,
              default: int = DEFAULT_LANE_BUDGET) -> int:
    """Rows per tile: the largest power of two T with T·lanes_per_row
    within the lane budget, clamped to [1, n_rows].

    The same formula `db.join._grid_tile` has always used for pair
    grids, exposed for every tiled launch: power-of-two tiles keep the
    jit cache warm across queries (at most one extra compiled shape for
    a ragged tail when n_rows is not a multiple of T)."""
    b = resolve_lane_budget(lane_budget, default=default)
    t = max(1, b // max(1, lanes_per_row))
    t = 1 << (t.bit_length() - 1)
    return min(t, n_rows)


def _pad_batch(x: jax.Array, block_b: int):
    b = x.shape[0]
    pad = (-b) % block_b
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, b


def ntt(x: jax.Array, ring: R.Ring, *, block_b: int = NK.DEFAULT_BLOCK_B,
        interpret: bool | None = None) -> jax.Array:
    """Forward negacyclic NTT (br-eval order). x: [B, K, n]."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    xp, b = _pad_batch(x, block_b)
    return NK.ntt_br(xp, ring, fwd=True, block_b=block_b,
                     interpret=interpret)[:b]


def intt(x: jax.Array, ring: R.Ring, *, block_b: int = NK.DEFAULT_BLOCK_B,
         interpret: bool | None = None) -> jax.Array:
    interpret = (not _on_tpu()) if interpret is None else interpret
    xp, b = _pad_batch(x, block_b)
    return NK.ntt_br(xp, ring, fwd=False, block_b=block_b,
                     interpret=interpret)[:b]


def negacyclic_mul(a: jax.Array, b: jax.Array, ring: R.Ring, *,
                   block_b: int = NK.DEFAULT_BLOCK_B,
                   interpret: bool | None = None) -> jax.Array:
    interpret = (not _on_tpu()) if interpret is None else interpret
    ap, nb = _pad_batch(a, block_b)
    bp, _ = _pad_batch(b, block_b)
    return NK.negacyclic_mul(ap, bp, ring, block_b=block_b,
                             interpret=interpret)[:nb]


def eval_values(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext, *,
                block_b: int = NK.DEFAULT_BLOCK_B,
                interpret: bool | None = None) -> jax.Array:
    """Kernel-backed centered eval values (Alg. 2 lines 2-4, no threshold).

    Returning the raw value lets callers apply their own decode threshold
    — the db executor thresholds per-atom (ε-tolerant CKKS equality) on
    ONE fused launch instead of one launch per distinct ε.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    params, rng = ks.params, ks.ring
    d = ct_sub(rng, ct0, ct1)
    d0p, b = _pad_batch(d.c0, block_b)
    d1p, _ = _pad_batch(d.c1, block_b)
    if params.mode == "paper":
        cek_br = CK.cek_to_br(ks)
        coeff0 = CK.eval_coeff0_paper(d0p, d1p, cek_br, rng, params.scale,
                                      block_b=block_b, interpret=interpret)
    else:
        digits = digit_decompose(params, d1p)          # [B, K, D, n]
        Bb = digits.shape[0]
        E = params.num_towers * params.gadget_digits_per_tower
        # rows: (k_src, digit) pairs; broadcast digit value to all towers
        dig = digits.reshape(Bb, E, 1, params.n)
        dig = jnp.broadcast_to(dig, (Bb, E, params.num_towers, params.n))
        cek_br = CK.cek_gadget_to_br(ks)
        coeff0 = CK.eval_coeff0_gadget(d0p, dig, cek_br, rng, params.scale,
                                       block_b=block_b, interpret=interpret)
    return R.crt_centered(params, coeff0[:b])


def compare(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext, *,
            block_b: int = NK.DEFAULT_BLOCK_B,
            interpret: bool | None = None) -> jax.Array:
    """Kernel-backed Algorithm 2 (-1/0/+1). Batched over leading dim."""
    v = eval_values(ks, ct0, ct1, block_b=block_b, interpret=interpret)
    return jnp.where(jnp.abs(v) < ks.params.tau,
                     0, jnp.sign(v)).astype(jnp.int32)


def broadcast_eval_values(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext, *,
                          block_b: int = NK.DEFAULT_BLOCK_B,
                          interpret: bool | None = None) -> jax.Array:
    """Kernel-backed raw eval values over two-sided-broadcast batch dims.

    ct0 and ct1 carry mutually-broadcastable batch shapes — e.g. the
    join tile layout ct0 [T, 1, K, n] against ct1 [1, R, K, n], or a
    shard_map body's local [S_r, 1, N_r] bounds against [1, N_l, 1]
    rows.  The broadcast grid is materialized once, flattened through
    the fused `cmp_eval` kernel path exactly like the single-dim entry,
    and reshaped back — ONE kernel launch with the same block padding
    rules as a fused filter scan.  THE shared broadcast-flatten-eval
    implementation: `db.join`'s tiled grids and `shard_eval_values`'
    per-device body both route here rather than re-deriving the
    reshape.  (Distinct from `db.join.pair_eval_values`, which adds
    host-side tiling on top of launches like this one.)
    """
    batch = jnp.broadcast_shapes(ct0.c0.shape[:-2], ct1.c0.shape[:-2])
    full = batch + ct0.c0.shape[-2:]
    flat = lambda x: jnp.broadcast_to(x, full).reshape(  # noqa: E731
        (-1,) + full[-2:])
    v = eval_values(ks, Ciphertext(flat(ct0.c0), flat(ct0.c1)),
                    Ciphertext(flat(ct1.c0), flat(ct1.c1)),
                    block_b=block_b, interpret=interpret)
    return v.reshape(batch)


# ---------------------------------------------------------------------------
# shard-aware eval entry (repro.db.shard)
# ---------------------------------------------------------------------------

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map          # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map


def _ks_cache(ks: KeySet, name: str) -> dict:
    """Per-KeySet jit cache (lifetime tied to the keyset, same pattern as
    db/executor._jitted — duplicated here to keep kernels below db in the
    layering)."""
    cache = getattr(ks, name, None)
    if cache is None:
        cache = {}
        object.__setattr__(ks, name, cache)
    return cache


def shard_eval_values(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext, *,
                      mesh, axis_name: str = "shard",
                      use_kernel: bool = False,
                      sel: jax.Array | None = None,
                      block_b: int = NK.DEFAULT_BLOCK_B,
                      interpret: bool | None = None) -> jax.Array:
    """Shard-parallel raw eval values under `shard_map`.

    ct0 leads with the shard dim — [S, ...batch, K, n], S divisible by
    the mesh's `axis_name` size; ct1 is replicated to every device and
    broadcast against ct0's batch dims inside each shard.  The two
    batch shapes broadcast TWO-SIDED, which covers both launch layouts
    the sharded engine uses: the fused filter stage (ct0 [S, A, N_sp],
    ct1 [A, 1] trapdoor bounds) and the cross-shard join pair grid
    (ct0 [S_l, 1, N_l, 1], ct1 [S_r, 1, N_r] — every device evaluates
    its left blocks against ALL right shard blocks).  HADES eval is
    row-local, so the mapped program needs NO cross-shard collectives —
    each device runs the eval pipeline over its own rows and only the
    decoded masks are reduced host-side.  `use_kernel=True` routes the
    per-device compute through the Pallas `cmp_eval` path (flattening
    local batch dims the way the single-device kernel entry does).

    `sel` supports the deduped fused-scan layout: ct0 carries UNIQUE
    columns [S, U, ...] and `sel` is the [A] per-atom gather into that
    unique axis (axis 1), applied INSIDE the mapped program — host-side
    bytes moved stay U·N while the program still evaluates all A atom
    lanes against the replicated [A, 1] bounds.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    from repro.core import compare as C

    def local_eval(c00, c01, b0, b1, *sel_arg):
        if sel_arg:
            c00 = jnp.take(c00, sel_arg[0], axis=1)
            c01 = jnp.take(c01, sel_arg[0], axis=1)
        if not use_kernel:
            return C.eval_value(ks, Ciphertext(c00, c01),
                                Ciphertext(b0, b1))
        return broadcast_eval_values(ks, Ciphertext(c00, c01),
                                     Ciphertext(b0, b1),
                                     block_b=block_b, interpret=interpret)

    from jax.sharding import PartitionSpec as P
    nd0, nd1 = ct0.c0.ndim, ct1.c0.ndim
    cache = _ks_cache(ks, "_shard_eval_cache")
    key = (id(mesh), axis_name, use_kernel, interpret, block_b, nd0, nd1,
           sel is not None)
    if key not in cache:
        spec0 = P(axis_name, *([None] * (nd0 - 1)))
        rep = P(*([None] * nd1))
        in_specs = [spec0, spec0, rep, rep]
        if sel is not None:
            in_specs.append(P(None))         # gather indices: replicated
        out_spec = P(axis_name, *([None] * (nd0 - 3)))
        fn = _shard_map(local_eval, mesh=mesh,
                        in_specs=tuple(in_specs),
                        out_specs=out_spec, check_rep=False)
        cache[key] = jax.jit(fn)
    args = (ct0.c0, ct0.c1, ct1.c0, ct1.c1)
    if sel is not None:
        args += (jnp.asarray(sel),)
    return cache[key](*args)
