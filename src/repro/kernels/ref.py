"""Pure-jnp oracles for every Pallas kernel (tests assert exact equality —
modular integer arithmetic admits no tolerance)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gadget as G
from repro.core import ring as R
from repro.core.keys import KeySet


def ntt_br(x: jax.Array, ring: R.Ring, *, fwd: bool = True) -> jax.Array:
    """Oracle for kernels.ntt.ntt_br: DIF order == bitrev-permuted DIT NTT."""
    if fwd:
        return jnp.take(R.ntt(ring, x), ring.bitrev, axis=-1)
    return R.intt(ring, jnp.take(x, ring.bitrev, axis=-1))


def negacyclic_mul(a: jax.Array, b: jax.Array, ring: R.Ring) -> jax.Array:
    return R.negacyclic_mul(ring, a, b)


def eval_coeff0_paper(d0: jax.Array, d1: jax.Array, ks: KeySet,
                      scale: int) -> jax.Array:
    rng = ks.ring
    keyed = R.negacyclic_mul(rng, d1, ks.cek)
    ev = (d0 * jnp.int64(scale) + keyed) % rng.q_arr
    return ev[..., :, 0]


def eval_coeff0_gadget(d0: jax.Array, d1: jax.Array, ks: KeySet,
                       scale: int) -> jax.Array:
    rng = ks.ring
    keyed = G.gadget_keymul(ks, d1)
    ev = (d0 * jnp.int64(scale) + keyed) % rng.q_arr
    return ev[..., :, 0]
