"""Train-step factory: loss + grad + AdamW under pjit, with optional
microbatching (gradient accumulation) and gradient compression.

The same factory serves three callers:
  * launch/train.py        — the real training driver (CPU-scale runs)
  * launch/dryrun.py       — .lower()/.compile() against the 512-chip mesh
  * tests/test_training.py — convergence + checkpoint-resume tests
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.train import compress as GC
from repro.train import optimizer as OPT

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: OPT.AdamState
    # residuals live in the state only when compression is on (None is a
    # static pytree-leaf-free marker)
    compressor: Optional[GC.CompressorState]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OPT.OptimizerConfig = OPT.OptimizerConfig()
    microbatches: int = 1          # grad accumulation steps per update
    compress_grads: bool = False


def init_state(cfg: ModelConfig, tcfg: TrainConfig,
               key: jax.Array) -> TrainState:
    params = T.init_params(cfg, key)
    comp = GC.init_state(params) if tcfg.compress_grads else None
    return TrainState(params=params, opt=OPT.init_state(params),
                      compressor=comp)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    grad_fn = jax.value_and_grad(lambda p, b: T.loss_fn(cfg, p, b))

    def accumulate(params, batch):
        if tcfg.microbatches == 1:
            return grad_fn(params, batch)
        # split batch on the leading dim into microbatches, scan-accumulate
        mb = tcfg.microbatches

        def resh(x):
            b = x.shape[0]
            assert b % mb == 0, f"batch {b} % microbatches {mb} != 0"
            return x.reshape((mb, b // mb) + x.shape[1:])

        stacked = jax.tree.map(resh, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)

        def body(carry, micro):
            loss_acc, g_acc = carry
            loss, g = grad_fn(params, micro)
            g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                 g_acc, g)
            return (loss_acc + loss, g_acc), None

        (loss, grads), _ = jax.lax.scan(body, (0.0, zeros), stacked)
        inv = 1.0 / mb
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        loss, grads = accumulate(state.params, batch)
        comp_state = state.compressor
        if tcfg.compress_grads:
            vals, scales, comp_state = GC.compress(comp_state, grads)
            grads = GC.decompress(vals, scales)
        params, opt, metrics = OPT.apply_updates(
            tcfg.opt, state.params, grads, state.opt)
        metrics = {"loss": loss, **metrics}
        return TrainState(params=params, opt=opt,
                          compressor=comp_state), metrics

    return train_step
