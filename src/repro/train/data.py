"""Data pipeline: counter-based synthetic token stream + tokenized-file
loader.

Counter-based = stateless: batch `i` is a pure function of (seed, i), so
any worker can regenerate any batch after a failure or an elastic re-shard —
no data-loader state in checkpoints, no skew after restarts (DESIGN.md §5).

The synthetic stream is a Zipf-ish unigram mixture with Markov order-1
structure so losses move (pure uniform tokens give a flat loss surface).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None     # tokenized .npy (1-D int32) — optional


def _zipf_logits(vocab: int, key) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    base = -1.1 * jnp.log(ranks)
    jitter = 0.3 * jax.random.normal(key, (vocab,))
    return base + jitter


def synthetic_batch(cfg: DataConfig, index: int) -> Dict[str, jax.Array]:
    """Batch `index`, deterministically. tokens: [B, S] int32."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), index)
    k_tok, k_shift = jax.random.split(key)
    logits = _zipf_logits(cfg.vocab_size, jax.random.PRNGKey(cfg.seed + 1))
    toks = jax.random.categorical(
        k_tok, logits, shape=(cfg.global_batch, cfg.seq_len))
    # order-1 structure: every other token is a deterministic fn of the prev
    shifted = (toks[:, :-1] * 31 + 7) % cfg.vocab_size
    mask = (jnp.arange(cfg.seq_len - 1) % 2 == 1)
    toks = toks.at[:, 1:].set(jnp.where(mask, shifted, toks[:, 1:]))
    return {"tokens": toks.astype(jnp.int32)}


class FileDataset:
    """Fixed-stride windows over a tokenized 1-D array (memory-mapped)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.arr = np.load(cfg.path, mmap_mode="r")
        self.n_windows = (len(self.arr) - 1) // cfg.seq_len

    def batch(self, index: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed + index)
        starts = rng.integers(0, self.n_windows, size=cfg.global_batch)
        toks = np.stack([
            self.arr[s * cfg.seq_len:(s + 1) * cfg.seq_len]
            for s in starts]).astype(np.int32)
        return {"tokens": jnp.asarray(toks)}


def batches(cfg: DataConfig, start_index: int = 0
            ) -> Iterator[Dict[str, jax.Array]]:
    ds = FileDataset(cfg) if cfg.path else None
    i = start_index
    while True:
        yield (ds.batch(i) if ds else synthetic_batch(cfg, i))
        i += 1
