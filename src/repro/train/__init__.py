"""Training substrate: optimizer, data pipeline, checkpointing, fault
tolerance, gradient compression — all built in JAX (no optax/orbax)."""
