"""AdamW + cosine schedule + global-norm clipping, from scratch.

The optimizer state tree mirrors the param tree, so the FSDP sharding rules
(parallel/sharding.py) apply verbatim to the moments — fully-sharded
optimizer state (ZeRO-3) falls out for free.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree       # first moment (f32)
    nu: PyTree       # second moment (f32)


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio * peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(math.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_state(params: PyTree) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def apply_updates(cfg: OptimizerConfig, params: PyTree, grads: PyTree,
                  state: AdamState) -> Tuple[PyTree, AdamState, dict]:
    """One AdamW step (with clipping). Returns (params, state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * pf
        return (pf - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return (new_params, AdamState(step=step, mu=new_mu, nu=new_nu),
            {"lr": lr, "grad_norm": gnorm})
