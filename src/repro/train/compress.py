"""Gradient compression for the DP all-reduce: int8 error-feedback.

Per-leaf symmetric int8 quantization with a residual carried across steps
(error feedback keeps the compressor unbiased in the long run).  Applied
BEFORE the pjit boundary the gradients cross the `data`/`pod` axes on, so
the all-reduce moves 1 byte/grad instead of 4 — the knob benchmarked in
EXPERIMENTS.md §Perf for the collective-bound cells.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressorState(NamedTuple):
    residual: PyTree     # f32, same structure as grads


def init_state(grads_like: PyTree) -> CompressorState:
    return CompressorState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress(state: CompressorState, grads: PyTree
             ) -> Tuple[PyTree, PyTree, CompressorState]:
    """-> (int8 values, f32 scales, new state). Quantizes g + residual."""
    def q(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q8 = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q8.astype(jnp.float32) * scale
        return q8, scale, new_r

    out = jax.tree.map(q, grads, state.residual)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    vals = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    scales = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    resid = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return vals, scales, CompressorState(residual=resid)


def decompress(vals: PyTree, scales: PyTree) -> PyTree:
    return jax.tree.map(
        lambda v, s: v.astype(jnp.float32) * s, vals, scales)
