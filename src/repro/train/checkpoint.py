"""Checkpointing: atomic, mesh-agnostic, async-capable.

Layout (one directory per step):
    <dir>/step_000123.tmp/...   (write)
    <dir>/step_000123/          (atomic rename on completion)
        manifest.json           {step, leaf paths, shapes, dtypes}
        <leaf_id>.npy           one file per pytree leaf (unsharded)

Mesh-agnostic: leaves are gathered to host as full arrays and resharded on
restore against whatever mesh the restarted job brings up — restarting
512-chip training on 256 chips (elastic downscale) is just `restore()` with
the new shardings.  Atomicity: the rename is the commit point; a crash
mid-write leaves only a .tmp dir that `latest_step` ignores and `clean`
removes.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _leaf_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx",
                          getattr(p, "name", "?")))))
        names.append("__".join(parts))
    return names, [leaf for _, leaf in flat], treedef


def save(ckpt_dir: str, step: int, tree: PyTree,
         async_: bool = False) -> threading.Thread | None:
    """Write checkpoint for `step`. async_=True returns the writer thread
    (device->host transfer happens synchronously; disk IO in background)."""
    names, leaves, _ = _leaf_paths(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

    def write():
        tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for name, arr in zip(names, host_leaves):
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # commit point

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Load `step` into the structure of `like`, placing each leaf with the
    given shardings (or uncommitted host arrays if None)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    names, leaves, treedef = _leaf_paths(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for name, ref, shd in zip(names, leaves, shard_leaves):
        arr = np.load(os.path.join(path, name + ".npy"))
        assert arr.shape == tuple(ref.shape), \
            f"{name}: ckpt {arr.shape} != model {ref.shape}"
        if shd is not None:
            out.append(jax.device_put(arr.astype(ref.dtype), shd))
        else:
            out.append(jax.device_put(arr.astype(ref.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


def clean_incomplete(ckpt_dir: str) -> int:
    """Remove .tmp dirs left by crashes. Returns count removed."""
    if not os.path.isdir(ckpt_dir):
        return 0
    n = 0
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d))
            n += 1
    return n


def keep_last(ckpt_dir: str, k: int) -> None:
    """Retention policy: keep the newest k complete checkpoints."""
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m:
            steps.append(int(m.group(1)))
    for s in sorted(steps)[:-k]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
