"""Config-driven model assembly for all ten assigned architectures.

The layer stack is grouped by the config's block pattern (e.g.
recurrentgemma's ("rglru", "rglru", "local")) and scanned over groups —
per-layer params are stacked [num_groups, ...], which keeps the HLO size
O(pattern) instead of O(layers) (critical for 60-layer dry-run compiles).

Supported batch dict keys (see launch/specs.py for the exact per-cell specs):
  tokens  [B, S] int32        — always present (decoder tokens for enc-dec)
  patches [B, P, d] dtype     — vlm frontend stub (replaces first P embeds)
  frames  [B, F, d] dtype     — audio frontend stub (encoder input, post-conv)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import xlstm as X
from repro.models.config import ModelConfig
from repro.parallel.constrain import shard

PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str) -> Dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    pdt = jnp.dtype(cfg.param_dtype)
    p: Dict[str, Any] = {"ln1": L.rmsnorm_init(d, pdt)}
    if kind in ("attn", "local"):
        p["attn"] = (L.mla_init(ks[0], cfg) if cfg.attention == "mla"
                     else L.gqa_init(ks[0], cfg))
        p["ln2"] = L.rmsnorm_init(d, pdt)
        if cfg.num_experts:
            p["moe"] = MOE.moe_init(ks[1], cfg)
        else:
            p["ffn"] = L.swiglu_init(ks[1], cfg)
        if cfg.is_encoder_decoder:
            p["ln_cross"] = L.rmsnorm_init(d, pdt)
            p["cross"] = L.gqa_init(ks[2], cfg)
    elif kind == "rglru":
        p["rec"] = RG.rglru_init(ks[0], cfg)
        p["ln2"] = L.rmsnorm_init(d, pdt)
        p["ffn"] = L.swiglu_init(ks[1], cfg)
    elif kind == "mlstm":
        p["cell"] = X.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["cell"] = X.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def _stacked_group_init(key, cfg: ModelConfig) -> Dict:
    """Params for one scan step (all pattern positions), stacked over groups."""
    def one_group(k):
        ks = jax.random.split(k, cfg.group_size)
        return {f"b{i}": _block_init(ks[i], cfg, kind)
                for i, kind in enumerate(cfg.pattern)}
    keys = jax.random.split(key, cfg.num_groups)
    per_group = [one_group(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    pdt = jnp.dtype(cfg.param_dtype)
    import math
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, d), jnp.float32)
                  * (1.0 / math.sqrt(d))).astype(pdt),
        "groups": _stacked_group_init(ks[1], cfg),
        "final_norm": L.rmsnorm_init(d, pdt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(ks[2], d, cfg.vocab_size, pdt)
    if cfg.is_encoder_decoder:
        enc_cfg = dataclasses.replace(
            cfg, is_encoder_decoder=False, num_layers=cfg.encoder_layers,
            block_pattern=("attn",))
        params["encoder"] = {
            "groups": _stacked_group_init(ks[3], enc_cfg),
            "final_norm": L.rmsnorm_init(d, pdt),
        }
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_apply(cfg: ModelConfig, kind: str, p: Dict, x: jax.Array,
                 enc_out: Optional[jax.Array]) -> jax.Array:
    window = cfg.window if kind == "local" else 0
    if kind in ("attn", "local"):
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.attention == "mla":
            x = x + L.mla_apply(p["attn"], cfg, h)
        else:
            causal = not (cfg.is_encoder_decoder and enc_out is None)
            x = x + L.gqa_apply(p["attn"], cfg, h, window=window,
                                causal=causal)
        if cfg.is_encoder_decoder and enc_out is not None:
            h = L.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
            x = x + L.gqa_apply(p["cross"], cfg, h, causal=False,
                                kv_x=enc_out, use_rope=False)
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.num_experts:
            x = x + MOE.moe_apply(p["moe"], cfg, h)
        else:
            x = x + L.swiglu_apply(p["ffn"], h)
    elif kind == "rglru":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + RG.block_apply(p["rec"], cfg, h)
        h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + L.swiglu_apply(p["ffn"], h)
    elif kind == "mlstm":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + X.mlstm_block_apply(p["cell"], cfg, h)
    elif kind == "slstm":
        h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + X.slstm_block_apply(p["cell"], cfg, h)
    return x


def _run_stack(cfg: ModelConfig, groups: PyTree, x: jax.Array,
               enc_out: Optional[jax.Array] = None,
               pattern: Optional[tuple] = None) -> jax.Array:
    pattern = pattern or cfg.pattern

    def group_body(x, gp):
        x = shard(x, "batch", None, None)
        for i, kind in enumerate(pattern):
            x = _block_apply(cfg, kind, gp[f"b{i}"], x, enc_out)
        return shard(x, "batch", None, None), None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, groups)
    else:
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        for g in range(n_groups):
            x, _ = body(x, jax.tree.map(lambda a: a[g], groups))
    return x


def _encode(cfg: ModelConfig, params: PyTree, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    enc_cfg = dataclasses.replace(cfg, is_encoder_decoder=False,
                                  num_layers=cfg.encoder_layers,
                                  block_pattern=("attn",))
    x = frames.astype(jnp.dtype(cfg.dtype))
    # sinusoidal positions are folded into the stub; encoder is bidirectional
    x = _run_stack(enc_cfg, params["encoder"]["groups"], x, enc_out=None,
                   pattern=("attn",))
    return L.rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(cfg: ModelConfig, params: PyTree,
            batch: Dict[str, jax.Array]) -> jax.Array:
    """-> logits [B, S, V]."""
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = shard(jnp.take(params["embed"], tokens, axis=0).astype(dt),
              "batch", None, None)
    if cfg.frontend == "patches" and "patches" in batch:
        P = batch["patches"].shape[1]
        x = jnp.concatenate([batch["patches"].astype(dt), x[:, P:]], axis=1)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, batch["frames"])
    x = _run_stack(cfg, params["groups"], x, enc_out=enc_out)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    return shard(x @ unembed.astype(dt), "batch", None, "model")


def loss_fn(cfg: ModelConfig, params: PyTree,
            batch: Dict[str, jax.Array]) -> jax.Array:
    """Next-token cross-entropy (f32 logsumexp)."""
    logits = forward(cfg, params, batch).astype(jnp.float32)
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None],
                                 axis=-1)[..., 0]
    mask = jnp.ones_like(targets, jnp.float32)
    if cfg.frontend == "patches":
        # patch positions carry no next-token target
        pos = jnp.arange(targets.shape[1])
        mask = jnp.where(pos[None, :] < cfg.num_patches, 0.0, 1.0)
    ce = (lse - picked) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1.0)
