"""Shared neural layers: norms, RoPE, chunked (flash-style) attention,
GQA/MQA and MLA attention blocks, SwiGLU FFN.

Functional style: every layer is (init(key, cfg) -> params, apply(params, x)
-> y) over plain dict pytrees.  Param names are load-bearing — the sharding
rules in parallel/sharding.py map names -> mesh axes.

Numerics: params in cfg.param_dtype, matmul compute in cfg.dtype, softmax /
norms / router in float32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel.constrain import shard


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd], positions broadcastable to [..., S]; rotates the
    last dim pairwise.  (For head-free tensors pass [..., S, 1, hd].)"""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    positions = jnp.atleast_1d(positions)
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, half]
    angles = angles[..., None, :]                               # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash-style attention (pure JAX, O(S * chunk) memory)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0, chunk: int = 256,
                    t_valid: Optional[int] = None) -> jax.Array:
    """Query-chunked attention with per-chunk rematerialization.

    q: [B, S, H, dk], k: [B, T, H, dk], v: [B, T, H, dv]
    (GQA callers repeat KV heads to H first — repeat_kv below — so the head
    axis shards cleanly on `model` even when kv_heads < mesh model size.)
    Returns [B, S, H, dv].

    Design notes (DESIGN.md §4, EXPERIMENTS.md §Perf):
      * chunks are an UNROLLED python loop, each chunk wrapped in
        jax.checkpoint — backward recomputes one [qc, T] score block at a
        time, so residuals are O(inputs+outputs) and the transient is
        O(qc * T), which is what lets 32k-prefill cells fit HBM;
      * no lax.scan: XLA's cost_analysis counts while bodies ONCE, which
        would corrupt the roofline FLOP terms (verified 8x undercount);
      * full-rectangle scores (masked, not skipped) — HLO FLOPs for causal
        attention are ~2x the useful triangle; the roofline notes this.
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    T = k.shape[1]
    t_valid = T if t_valid is None else t_valid
    qc = min(chunk, S)
    nq = -(-S // qc)
    pad = nq * qc - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)) + ((0, 0),) * 2)

    # TP strategy: shard heads on `model` when there are enough of them.
    # For head counts below the model-axis size the heads REPLICATE
    # (§Perf iteration A: the context-parallel alternative — sharding the
    # KV/T axis — made XLA reduce O(S*T)-sized partials over the model
    # axis every chunk: 205 GB/dev of all-reduce on smollm train_4k.
    # Replicating a 15-head attention costs ~2x compute on a tiny slice of
    # the model and ZERO extra collectives; measured 0.0094 -> see
    # EXPERIMENTS.md §Perf for the after numbers).
    from repro.parallel.constrain import _ambient_mesh
    mesh = _ambient_mesh()
    model_sz = mesh.shape.get("model", 1) if mesh is not None else 1
    head_par = H >= model_sz

    if head_par:
        kf = shard(k.astype(jnp.float32), "batch", None, "model", None)
        vf = shard(v.astype(jnp.float32), "batch", None, "model", None)
    else:
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
    j_pos = jnp.arange(T)
    inv = 1.0 / math.sqrt(dk)

    def chunk_fn(q_c, k_, v_, i_pos):
        s = jnp.einsum("bshd,bthd->bhst",
                       q_c.astype(jnp.float32) * inv, k_)
        if head_par:
            s = shard(s, "batch", "model", None, None)
        mask = j_pos[None, :] < t_valid
        if causal:
            mask = mask & (j_pos[None, :] <= i_pos[:, None])
        if window:
            mask = mask & (j_pos[None, :] > i_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        # every query row has >= 1 valid key in all our uses (causal
        # includes self), so the softmax is NaN-free.
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhst,bthd->bshd", p, v_)

    remat_chunk = jax.checkpoint(chunk_fn)
    outs = []
    for ci in range(nq):
        i_pos = q_offset + ci * qc + jnp.arange(qc)
        outs.append(remat_chunk(q[:, ci * qc:(ci + 1) * qc], kf, vf, i_pos))
    out = jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]
    return out[:, :S].astype(q.dtype)                   # [B,S,H,dv]


def repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    """[B, T, KV, hd] -> [B, T, KV*groups, hd] (GQA expansion)."""
    if groups == 1:
        return x
    B, T, KV, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (B, T, KV, groups, hd))
    return x.reshape(B, T, KV * groups, hd)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     t_valid: jax.Array, window: int = 0,
                     pos: Optional[jax.Array] = None) -> jax.Array:
    """Single-position attention against a cache.

    q: [B, 1, KV, G, hd], k/v: [B, T, KV, hd]; t_valid: current length [B]
    or scalar.  Full-row softmax (T scores per query is tiny).
    """
    B, _, KVh, G, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    j = jnp.arange(T)
    tv = jnp.asarray(t_valid)
    tv = tv[:, None] if tv.ndim == 1 else tv[None, None]
    mask = j[None, :] < tv                                   # [B or 1, T]
    if window:
        mask = mask & (j[None, :] >= tv - window)
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    pdt = _pdt(cfg)
    return {
        "wq": dense_init(ks[0], d, H * hd, pdt),
        "wk": dense_init(ks[1], d, KV * hd, pdt),
        "wv": dense_init(ks[2], d, KV * hd, pdt),
        "wo": dense_init(ks[3], H * hd, d, pdt),
    }


def gqa_project_kv(params: dict, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, S, _ = x.shape
    KV, hd = cfg.num_kv_heads, cfg.hd
    dt = _dt(cfg)
    k = (x @ params["wk"].astype(dt)).reshape(B, S, KV, hd)
    v = (x @ params["wv"].astype(dt)).reshape(B, S, KV, hd)
    k = rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_project_q(params: dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.hd
    dt = _dt(cfg)
    q = shard((x @ params["wq"].astype(dt)).reshape(B, S, H, hd),
              "batch", None, "model", None)
    return rope(q, positions, cfg.rope_theta)


def gqa_apply(params: dict, cfg: ModelConfig, x: jax.Array, *,
              window: int = 0, causal: bool = True,
              kv_x: Optional[jax.Array] = None,
              use_rope: bool = True) -> jax.Array:
    """Self- (or cross-, via kv_x) attention over a full sequence."""
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = _dt(cfg)
    src = x if kv_x is None else kv_x
    pos_q = jnp.arange(S)
    pos_k = jnp.arange(src.shape[1])
    q = shard((x @ params["wq"].astype(dt)).reshape(B, S, H, hd),
              "batch", None, "model", None)
    k = shard((src @ params["wk"].astype(dt)).reshape(B, src.shape[1], KV, hd),
              "batch", None, "model", None)
    v = shard((src @ params["wv"].astype(dt)).reshape(B, src.shape[1], KV, hd),
              "batch", None, "model", None)
    if use_rope:
        q = rope(q, pos_q, cfg.rope_theta)
        k = rope(k, pos_k, cfg.rope_theta)
    o = flash_attention(q, repeat_kv(k, H // KV), repeat_kv(v, H // KV),
                        causal=causal, window=window, chunk=cfg.attn_chunk)
    o = o.reshape(B, S, H * hd)
    return shard(o @ params["wo"].astype(dt), "batch", None, None)


# ---------------------------------------------------------------------------
# MLA attention block (MiniCPM3 / DeepSeek-V2 latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> dict:
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    qr, kvr, rd = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    pdt = _pdt(cfg)
    return {
        "wq_down": dense_init(ks[0], d, qr, pdt),
        "q_norm": rmsnorm_init(qr, pdt),
        "wq_up": dense_init(ks[1], qr, H * (hd + rd), pdt),
        "wkv_down": dense_init(ks[2], d, kvr + rd, pdt),
        "kv_norm": rmsnorm_init(kvr, pdt),
        "wk_up": dense_init(ks[3], kvr, H * hd, pdt),
        "wv_up": dense_init(ks[4], kvr, H * hd, pdt),
        "wo": dense_init(ks[5], H * hd, d, pdt),
    }


def mla_latent(params: dict, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Compressed KV: (c_kv [B,S,kvr], k_rope [B,S,rd]) — the decode cache."""
    dt = _dt(cfg)
    kvr = cfg.kv_lora_rank
    down = x @ params["wkv_down"].astype(dt)
    c_kv = rmsnorm(params["kv_norm"], down[..., :kvr], cfg.norm_eps)
    k_rope = rope(down[..., kvr:][..., None, :],    # add unit head axis
                  positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_queries(params: dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, hd, rd = cfg.num_heads, cfg.hd, cfg.qk_rope_head_dim
    dt = _dt(cfg)
    cq = rmsnorm(params["q_norm"], x @ params["wq_down"].astype(dt),
                 cfg.norm_eps)
    q = (cq @ params["wq_up"].astype(dt)).reshape(B, S, H, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence MLA (train / prefill): expand latents, run GQA-style
    attention with KV=H, G=1 on concat(nope, rope) dims."""
    B, S, _ = x.shape
    H, hd, rd = cfg.num_heads, cfg.hd, cfg.qk_rope_head_dim
    dt = _dt(cfg)
    pos = jnp.arange(S)
    c_kv, k_rope = mla_latent(params, cfg, x, pos)
    q_nope, q_rope = mla_queries(params, cfg, x, pos)
    k_nope = shard((c_kv @ params["wk_up"].astype(dt)).reshape(B, S, H, hd),
                   "batch", None, "model", None)
    v = shard((c_kv @ params["wv_up"].astype(dt)).reshape(B, S, H, hd),
              "batch", None, "model", None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)            # [B,S,H,hd+rd]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rd))],
        axis=-1)
    o = flash_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
    o = o.reshape(B, S, H * hd)
    return shard(o @ params["wo"].astype(dt), "batch", None, None)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def swiglu_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    pdt = _pdt(cfg)
    return {
        "wi": dense_init(ks[0], d, ff, pdt),
        "wg": dense_init(ks[1], d, ff, pdt),
        "wo": dense_init(ks[2], ff, d, pdt),
    }


def swiglu_apply(params: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jax.nn.silu(x @ params["wg"].astype(dt)) * (x @ params["wi"].astype(dt))
    if h.ndim == 3:
        h = shard(h, "batch", None, "model")
    out = h @ params["wo"].astype(dt)
    return shard(out, *(["batch"] + [None] * (out.ndim - 1)))
