"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = (gelu gate branch) * (causal conv1d -> RG-LRU) -> out projection.
RG-LRU per channel:

    r_t = sigmoid(x_t * w_a + b_a)              recurrence gate
    i_t = sigmoid(x_t * w_x + b_x)              input gate
    a_t = exp(-c * softplus(Lambda) * r_t)      c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses jax.lax.associative_scan (log-depth, parallel over the
mesh's data axis); decode is the single-step recurrence with O(1) state —
this is what makes long_500k a legal cell for this family (DESIGN.md §4.1).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

_C = 8.0


class RecurrentState(NamedTuple):
    conv: jax.Array   # [B, conv_width-1, w] trailing inputs
    h: jax.Array      # [B, w] RG-LRU hidden


def rglru_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 5)
    pdt = jnp.dtype(cfg.param_dtype)
    # Lambda init so a ~ U(0.9, 0.999)^c at r=1 (griffin appendix)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u)))  # softplus^-1(-log u)
    return {
        "w_gate": L.dense_init(ks[1], d, w, pdt),
        "w_in": L.dense_init(ks[2], d, w, pdt),
        "w_out": L.dense_init(ks[3], w, d, pdt),
        "conv_k": (jax.random.normal(ks[4], (cfg.conv_width, w), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(pdt),
        "lam": lam,                                  # f32
        "gate_a": jnp.zeros((w,), jnp.float32),
        "gate_x": jnp.zeros((w,), jnp.float32),
        "bias_a": jnp.zeros((w,), jnp.float32),
        "bias_x": jnp.zeros((w,), jnp.float32),
    }


def _gates(params: dict, u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """u: [..., w] f32 -> (a, gated input) both f32."""
    r = jax.nn.sigmoid(u * params["gate_a"] + params["bias_a"])
    i = jax.nn.sigmoid(u * params["gate_x"] + params["bias_x"])
    decay = _C * jax.nn.softplus(params["lam"])
    a = jnp.exp(-decay * r)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u)
    return a, gated


def _conv_causal(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Per-channel causal conv, width cfg.conv_width. x: [B, S, w]."""
    kern = params["conv_k"].astype(x.dtype)
    out = x * kern[-1]
    for i in range(1, cfg.conv_width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * kern[-1 - i]
    return out


def rglru_scan(params: dict, u: jax.Array) -> jax.Array:
    """Parallel RG-LRU over a full sequence. u: [B, S, w] -> [B, S, w]."""
    uf = u.astype(jnp.float32)
    a, b = _gates(params, uf)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype)


def rglru_step(params: dict, u_t: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. u_t: [B, w], h: [B, w] f32."""
    uf = u_t.astype(jnp.float32)
    a, b = _gates(params, uf)
    h_new = a * h + b
    return h_new.astype(u_t.dtype), h_new


def block_apply(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence recurrent block. x: [B, S, d]."""
    dt = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate"].astype(dt))
    u = x @ params["w_in"].astype(dt)
    u = _conv_causal(params, u, cfg)
    h = rglru_scan(params, u)
    return (gate * h) @ params["w_out"].astype(dt)


def block_step(params: dict, cfg: ModelConfig, x_t: jax.Array,
               state: RecurrentState) -> Tuple[jax.Array, RecurrentState]:
    """One-token decode. x_t: [B, d]."""
    dt = x_t.dtype
    gate = jax.nn.gelu(x_t @ params["w_gate"].astype(dt))
    u_t = x_t @ params["w_in"].astype(dt)                      # [B, w]
    # conv over (state.conv ++ u_t)
    kern = params["conv_k"].astype(dt)
    hist = jnp.concatenate([state.conv, u_t[:, None, :]], axis=1)
    u_conv = jnp.einsum("btw,tw->bw", hist, kern)
    out_h, h_new = rglru_step(params, u_conv, state.h)
    new_state = RecurrentState(conv=hist[:, 1:], h=h_new)
    y = (gate * out_h) @ params["w_out"].astype(dt)
    return y, new_state


def init_state(cfg: ModelConfig, batch: int, dtype) -> RecurrentState:
    w = cfg.lru_width or cfg.d_model
    return RecurrentState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        h=jnp.zeros((batch, w), jnp.float32))
