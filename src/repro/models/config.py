"""Model configuration — one dataclass covers all ten assigned families.

A config fully determines parameter shapes, the block pattern, the serving
cache layout, and the analytic parameter/FLOP counts used by the roofline
(launch/roofline.py cross-checks the analytic numbers against the compiled
HLO).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention ----------------------------------------------------------
    attention: str = "gqa"           # gqa | mla
    head_dim: Optional[int] = None   # default d_model // num_heads
    window: int = 0                  # sliding-window size (local attention)
    rope_theta: float = 10_000.0

    # MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2 style) -------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 32

    # MoE ------------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # hybrid / ssm ---------------------------------------------------------
    # block pattern, repeated to num_layers; entries: "attn", "local",
    # "rglru", "mlstm", "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    lru_width: int = 0               # RG-LRU recurrence width (0 = d_model)
    conv_width: int = 4              # temporal conv in recurrent blocks

    # encoder-decoder ------------------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper frame count (stub frontend)

    # frontend stubs -------------------------------------------------------
    frontend: str = "none"           # none | patches | frames
    num_patches: int = 576           # llava anyres stub

    # numerics / runtime ---------------------------------------------------
    param_dtype: str = "bfloat16"
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_chunk: int = 256            # flash-attention kv-chunk size
    remat: bool = True
    scan_layers: bool = True

    # ----------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def pattern(self) -> Tuple[str, ...]:
        return tuple(self.block_pattern)

    @property
    def group_size(self) -> int:
        """Layers per scan step (len of block pattern)."""
        return len(self.pattern)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_size == 0, \
            f"{self.name}: num_layers % pattern length != 0"
        return self.num_layers // self.group_size

    @property
    def sub_quadratic(self) -> bool:
        """True if serve memory/time per token is O(1) in context length —
        the long_500k eligibility rule (DESIGN.md §4.1)."""
        return all(b in ("rglru", "mlstm", "slstm", "local")
                   for b in self.pattern)

    # ---- analytic counts (roofline cross-checks) -------------------------
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        H, KV = self.num_heads, self.num_kv_heads
        n = 0
        # embeddings
        n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d
        def attn_params() -> int:
            if self.attention == "mla":
                qr, kvr, rd = self.q_lora_rank, self.kv_lora_rank, self.qk_rope_head_dim
                p = d * qr + qr * H * (hd + rd)        # q down/up (+rope dim)
                p += d * (kvr + rd)                     # kv down + shared rope
                p += kvr * H * (hd + hd)                # k_up, v_up
                p += H * hd * d                         # out
                return p
            return d * H * hd + 2 * d * KV * hd + H * hd * d
        def ffn_params() -> int:
            return 3 * d * self.d_ff                    # swiglu
        def moe_params() -> int:
            e_ff = self.d_ff
            p = self.num_experts * 3 * d * e_ff
            p += self.num_shared_experts * 3 * d * e_ff
            p += d * self.num_experts                   # router
            return p
        def rglru_params() -> int:
            w = self.lru_width or d
            return 2 * d * w + w * d + 3 * w + self.conv_width * w + 3 * d * self.d_ff
        def xlstm_params(kind: str) -> int:
            # qkv + gates + out + (up/down proj factor ~2.7x) rough but exact
            # numbers come from init shapes; used only for roofline sanity.
            return 4 * d * d + 3 * d + 2 * int(2.7 * d) * d
        per_block = {
            "attn": attn_params() + (moe_params() if self.num_experts else ffn_params()),
            "local": attn_params() + (moe_params() if self.num_experts else ffn_params()),
            "rglru": rglru_params(),
            "mlstm": xlstm_params("m"),
            "slstm": xlstm_params("s"),
        }
        for g in range(self.num_layers):
            n += per_block[self.pattern[g % self.group_size]]
        if self.is_encoder_decoder:
            # encoder layers: attn + ffn, plus decoder cross-attn already in
            # num_layers accounting? encoder counted separately:
            n += self.encoder_layers * (attn_params() + ffn_params())
            n += self.num_layers * attn_params()        # cross-attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        e_ff = self.d_ff
        total = self.param_count()
        inactive = (self.num_experts - self.experts_per_token)
        return total - self.num_layers * inactive * 3 * d * e_ff
