"""LM substrate: the ten assigned architectures as composable JAX modules."""
