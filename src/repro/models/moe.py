"""Mixture-of-Experts layer: shared + routed experts, top-k routing,
capacity-bounded einsum dispatch (GShard/MaxText style).

Why capacity dispatch: shapes stay static (scatter with drop semantics), so
the layer lowers cleanly under pjit with experts sharded on the `model` axis
(EP).  XLA SPMD inserts the token all-to-all between the data-sharded token
stream and the expert-sharded buffers automatically.

Covers both assigned MoE archs:
  * deepseek-moe-16b: 2 shared + 64 routed, top-6, fine-grained d_ff=1408
  * qwen3-moe-30b-a3b: 128 routed, top-8, d_ff=768, no shared experts
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.constrain import shard


def moe_init(key, cfg: ModelConfig) -> dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.d_ff
    ks = jax.random.split(key, 5)
    pdt = jnp.dtype(cfg.param_dtype)
    import math
    scale = 1.0 / math.sqrt(d)
    params = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale
                   ).astype(jnp.float32),      # router stays f32
        "experts_wi": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
                       * scale).astype(pdt),
        "experts_wg": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
                       * scale).astype(pdt),
        "experts_wo": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                       * (1.0 / math.sqrt(ff))).astype(pdt),
    }
    if cfg.num_shared_experts:
        params["shared"] = L.swiglu_init(
            ks[4], cfg, d_ff=ff * cfg.num_shared_experts)
    return params


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    k, E = cfg.experts_per_token, cfg.num_experts
    c = int(num_tokens * k * cfg.capacity_factor / E) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def route(cfg: ModelConfig, router: jax.Array, xf: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """Top-k routing. xf: [T, d] -> (expert_idx [T,k] int32, gates [T,k] f32).

    DeepSeek-style: softmax over all experts, renormalized over the top-k.
    """
    logits = xf.astype(jnp.float32) @ router                   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return idx.astype(jnp.int32), gates


def moe_apply(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: [B, S, d] -> [B, S, d].

    Dispatches to the shard_map EP path on a mesh (§Perf iteration B):
    the naive global-scatter path below makes GSPMD replicate the token
    buffers across the mesh (measured: 4.7 TB/device of collectives on
    deepseek-moe train_4k).  Keeps the naive path for single-device runs
    — both are differentiable and numerically identical (tested).
    """
    from repro.parallel.constrain import _ambient_mesh
    mesh = _ambient_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.num_experts % mesh.shape["model"] == 0
            and mesh.shape["model"] > 1):
        return _moe_apply_ep(params, cfg, x, mesh)
    return _moe_apply_global(params, cfg, x)


def _moe_apply_global(params: dict, cfg: ModelConfig,
                      x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    T = B * S
    k, E = cfg.experts_per_token, cfg.num_experts
    C = _capacity(cfg, T)
    dt = x.dtype
    xf = x.reshape(T, d)

    idx, gates = route(cfg, params["router"], xf)              # [T,k]

    # position-in-expert via cumulative counts, one pass per routing slot
    pos = jnp.zeros((T, k), jnp.int32)
    counts = jnp.zeros((E,), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(idx[:, j], E, dtype=jnp.int32)     # [T, E]
        pos_j = jnp.cumsum(oh, axis=0) - 1 + counts[None, :]   # [T, E]
        pos = pos.at[:, j].set(jnp.take_along_axis(
            pos_j, idx[:, j][:, None], axis=1)[:, 0])
        counts = counts + jnp.sum(oh, axis=0)

    keep = pos < C                                             # [T, k]
    slot = jnp.where(keep, idx * C + pos,
                     jnp.int32(E * C))               # drop sentinel

    # dispatch: [E*C, d] — the data->expert resharding all-to-all happens
    # here under pjit (tokens batch-sharded, buffer expert-sharded)
    src = jnp.broadcast_to(xf[:, None, :], (T, k, d)).reshape(T * k, d)
    buf = jnp.zeros((E * C, d), dt).at[slot.reshape(-1)].set(
        src, mode="drop")
    buf = shard(buf.reshape(E, C, d), "model", None, None)

    # expert SwiGLU, batched over E (MXU-friendly, EP-shardable)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                params["experts_wg"].astype(dt)))
         * jnp.einsum("ecd,edf->ecf", buf, params["experts_wi"].astype(dt)))
    h = shard(h, "model", None, None)
    out_slots = jnp.einsum("ecf,efd->ecd", h,
                           params["experts_wo"].astype(dt))
    out_slots = shard(out_slots, "model", None, None)
    out_flat = out_slots.reshape(E * C, d)

    # combine: gather each token's k slots, weight by gates
    gathered = jnp.take(out_flat, jnp.minimum(slot, E * C - 1).reshape(-1),
                        axis=0).reshape(T, k, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    combined = jnp.sum(gathered * gates[..., None].astype(dt), axis=1)

    if cfg.num_shared_experts:
        combined = combined + L.swiglu_apply(params["shared"], xf)
    return combined.reshape(B, S, d)


def _moe_apply_ep(params: dict, cfg: ModelConfig, x: jax.Array,
                  mesh) -> jax.Array:
    """Expert-parallel MoE under shard_map (explicit-collective path).

    Insight: activations are replicated across the `model` axis (they are
    batch-sharded only), so every expert shard already HOLDS every token —
    dispatch is a purely local select/scatter into [E_local, C, d], and the
    only real collective is ONE psum of the combined output over `model`
    (2*T*d bytes on the wire — the Megatron-EP minimum), instead of
    GSPMD's replicated-scatter fallback.
    """
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import batch_axes

    B, S, d = x.shape
    k, E = cfg.experts_per_token, cfg.num_experts
    dt = x.dtype
    b_axes = batch_axes(mesh)
    n_model = mesh.shape["model"]
    E_loc = E // n_model
    xf = x.reshape(B * S, d)

    def body(x_loc, router, wi, wg, wo):
        T_loc = x_loc.shape[0]
        C = _capacity(cfg, T_loc)
        idx, gates = route(cfg, router, x_loc)             # [T_loc, k]
        # position-in-expert over the GLOBAL expert ids (local tokens)
        pos = jnp.zeros((T_loc, k), jnp.int32)
        counts = jnp.zeros((E,), jnp.int32)
        for j in range(k):
            oh = jax.nn.one_hot(idx[:, j], E, dtype=jnp.int32)
            pos_j = jnp.cumsum(oh, axis=0) - 1 + counts[None, :]
            pos = pos.at[:, j].set(jnp.take_along_axis(
                pos_j, idx[:, j][:, None], axis=1)[:, 0])
            counts = counts + jnp.sum(oh, axis=0)
        my_col = jax.lax.axis_index("model")
        owned = (idx // E_loc) == my_col                   # [T_loc, k]
        keep = (pos < C) & owned
        slot = jnp.where(keep, (idx % E_loc) * C + pos,
                         jnp.int32(E_loc * C))
        src = jnp.broadcast_to(x_loc[:, None, :],
                               (T_loc, k, d)).reshape(T_loc * k, d)
        buf = jnp.zeros((E_loc * C, d), dt).at[slot.reshape(-1)].set(
            src, mode="drop").reshape(E_loc, C, d)
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt)))
             * jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt)))
        out_slots = jnp.einsum("ecf,efd->ecd", h,
                               wo.astype(dt)).reshape(E_loc * C, d)
        gathered = jnp.take(out_slots,
                            jnp.minimum(slot, E_loc * C - 1).reshape(-1),
                            axis=0).reshape(T_loc, k, d)
        gathered = jnp.where(keep[..., None], gathered, 0)
        part = jnp.sum(gathered * gates[..., None].astype(dt), axis=1)
        # the one necessary EP collective:
        return jax.lax.psum(part, axis_name="model")

    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(b_axes, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=P(b_axes, None),
        check_vma=False,
    )(xf, params["router"], params["experts_wi"], params["experts_wg"],
      params["experts_wo"])

    if cfg.num_shared_experts:
        out = out + L.swiglu_apply(params["shared"], xf)
    return out.reshape(B, S, d)


def load_balance_loss(cfg: ModelConfig, router: jax.Array,
                      x: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss (fraction * prob per expert)."""
    T = x.shape[0] * x.shape[1]
    xf = x.reshape(T, -1)
    logits = xf.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(idx, cfg.num_experts), axis=0)
    prob = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac * prob)
