"""Serving path: KV/state caches, prefill, and single-token decode.

Cache layouts (all leaves carry a leading [G] = num_groups axis so the
decode step scans groups exactly like training scans them):

  gqa   : k, v            [G, B, T, KV, hd]     (keys stored post-RoPE)
  mla   : ckv             [G, B, T, kvr]        latent (the MLA cache win)
          krope           [G, B, T, rd]
  local : k, v            [G, B, W, KV, hd]     ring buffer, W = window
  cross : ck, cv          [G, B, F, KV, hd]     whisper encoder K/V (static)
  rglru : conv [G,B,cw-1,w], h [G,B,w]
  mlstm : C [G,B,H,hd,hd], n [G,B,H,hd], m [G,B,H]
  slstm : h/c/n/m         [G, B, w]

`pos` is a traced scalar — decode_32k / long_500k lower ONE decode_step with
a full-length cache, per the assignment's serve_step contract.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import xlstm as X
from repro.models.config import ModelConfig

PyTree = Any


def _dus(buf: jax.Array, update: jax.Array, pos: jax.Array,
         axis: int) -> jax.Array:
    """dynamic_update_slice at `pos` along `axis` (index dtypes unified —
    python-int zeros become int64 under x64 and then clash with int32 pos)."""
    starts = [jnp.asarray(0, pos.dtype)] * buf.ndim
    starts[axis] = pos
    return jax.lax.dynamic_update_slice(buf, update, tuple(starts))


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, kind: str, B: int, T: int, dt) -> Dict:
    KV, hd = cfg.num_kv_heads, cfg.hd
    if kind == "attn":
        if cfg.attention == "mla":
            c = {"ckv": jnp.zeros((B, T, cfg.kv_lora_rank), dt),
                 "krope": jnp.zeros((B, T, cfg.qk_rope_head_dim), dt)}
        else:
            c = {"k": jnp.zeros((B, T, KV, hd), dt),
                 "v": jnp.zeros((B, T, KV, hd), dt)}
        if cfg.is_encoder_decoder:
            c["ck"] = jnp.zeros((B, cfg.encoder_seq, KV, hd), dt)
            c["cv"] = jnp.zeros((B, cfg.encoder_seq, KV, hd), dt)
        return c
    if kind == "local":
        W = cfg.window
        return {"k": jnp.zeros((B, W, KV, hd), dt),
                "v": jnp.zeros((B, W, KV, hd), dt)}
    if kind == "rglru":
        w = cfg.lru_width or cfg.d_model
        return {"conv": jnp.zeros((B, cfg.conv_width - 1, w), dt),
                "h": jnp.zeros((B, w), jnp.float32)}
    if kind == "mlstm":
        w = 2 * cfg.d_model
        H = cfg.num_heads
        return {"C": jnp.zeros((B, H, w // H, w // H), jnp.float32),
                "n": jnp.zeros((B, H, w // H), jnp.float32),
                "m": jnp.full((B, H), -1e30, jnp.float32)}
    if kind == "slstm":
        w = cfg.d_model
        z = jnp.zeros((B, w), jnp.float32)
        return {"h": z, "c": z, "n": z,
                "m": jnp.full((B, w), -1e30, jnp.float32)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, B: int, T_max: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    G = cfg.num_groups
    blocks = {}
    for i, kind in enumerate(cfg.pattern):
        one = _block_cache(cfg, kind, B, T_max, dt)
        blocks[f"b{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), one)
    return {"pos": jnp.zeros((), jnp.int32), "blocks": blocks}


# ---------------------------------------------------------------------------
# per-kind decode steps
# ---------------------------------------------------------------------------

def _gqa_step(p: Dict, cfg: ModelConfig, x_t: jax.Array, cache: Dict,
              pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """x_t: [B, d] (already normed). Returns attn output + updated cache."""
    B, d = x_t.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x_t.dtype
    posb = pos[None]                                        # [1] -> bcast S=1
    q = (x_t @ p["wq"].astype(dt)).reshape(B, 1, H, hd)
    q = L.rope(q, posb, cfg.rope_theta)
    k_t = (x_t @ p["wk"].astype(dt)).reshape(B, 1, KV, hd)
    k_t = L.rope(k_t, posb, cfg.rope_theta)
    v_t = (x_t @ p["wv"].astype(dt)).reshape(B, 1, KV, hd)
    k = _dus(cache["k"], k_t, pos, axis=1)
    v = _dus(cache["v"], v_t, pos, axis=1)
    o = L.decode_attention(q.reshape(B, 1, KV, H // KV, hd), k, v,
                           t_valid=pos + 1)
    o = o.reshape(B, H * hd) @ p["wo"].astype(dt)
    return o, {**cache, "k": k, "v": v}


def _local_step(p: Dict, cfg: ModelConfig, x_t: jax.Array, cache: Dict,
                pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """Ring-buffer sliding-window attention step (W slots)."""
    B, d = x_t.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    W = cfg.window
    dt = x_t.dtype
    posb = pos[None]
    slot = pos % W
    q = L.rope((x_t @ p["wq"].astype(dt)).reshape(B, 1, H, hd), posb,
               cfg.rope_theta)
    k_t = L.rope((x_t @ p["wk"].astype(dt)).reshape(B, 1, KV, hd), posb,
                 cfg.rope_theta)
    v_t = (x_t @ p["wv"].astype(dt)).reshape(B, 1, KV, hd)
    k = _dus(cache["k"], k_t, slot, axis=1)
    v = _dus(cache["v"], v_t, slot, axis=1)
    # slot j holds absolute position pos - ((slot - j) mod W); valid if >= 0
    j = jnp.arange(W)
    slot_pos = pos - ((slot - j) % W)
    mask = slot_pos >= 0                                    # [W]
    qg = q.reshape(B, 1, KV, H // KV, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(mask[None, None, None, None, :], s, -jnp.inf)
    pw = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", pw, v.astype(jnp.float32))
    o = o.astype(dt).reshape(B, H * hd) @ p["wo"].astype(dt)
    return o, {**cache, "k": k, "v": v}


def _mla_step(p: Dict, cfg: ModelConfig, x_t: jax.Array, cache: Dict,
              pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """Latent-space MLA decode (never expands the KV cache)."""
    B, d = x_t.shape
    H, hd, rd = cfg.num_heads, cfg.hd, cfg.qk_rope_head_dim
    kvr = cfg.kv_lora_rank
    dt = x_t.dtype
    posb = pos[None]
    c_kv_t, k_rope_t = L.mla_latent(p, cfg, x_t[:, None, :], posb)
    q_nope, q_rope = L.mla_queries(p, cfg, x_t[:, None, :], posb)
    ckv = _dus(cache["ckv"], c_kv_t, pos, axis=1)
    krope = _dus(cache["krope"], k_rope_t, pos, axis=1)
    # absorb wk_up into the query:  q_lat[h] = q_nope[h] @ wk_up[:, h, :]^T
    wk_up = p["wk_up"].astype(dt).reshape(kvr, H, hd)
    q_lat = jnp.einsum("bhd,khd->bhk", q_nope[:, 0], wk_up)     # [B,H,kvr]
    s = (jnp.einsum("bhk,btk->bht", q_lat.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bhr,btr->bht", q_rope[:, 0].astype(jnp.float32),
                      krope.astype(jnp.float32))) / math.sqrt(hd + rd)
    mask = jnp.arange(ckv.shape[1]) < pos + 1
    s = jnp.where(mask[None, None, :], s, -jnp.inf)
    pw = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bht,btk->bhk", pw, ckv.astype(jnp.float32))  # latent ctx
    wv_up = p["wv_up"].astype(dt).reshape(kvr, H, hd)
    o = jnp.einsum("bhk,khd->bhd", ctx.astype(dt), wv_up)
    o = o.reshape(B, H * hd) @ p["wo"].astype(dt)
    return o, {**cache, "ckv": ckv, "krope": krope}


def _cross_step(p: Dict, cfg: ModelConfig, x_t: jax.Array,
                cache: Dict) -> jax.Array:
    """Cross-attention against the cached encoder K/V."""
    B, d = x_t.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = x_t.dtype
    q = (x_t @ p["wq"].astype(dt)).reshape(B, 1, KV, H // KV, hd)
    o = L.decode_attention(q, cache["ck"], cache["cv"],
                           t_valid=cache["ck"].shape[1])
    return o.reshape(B, H * hd) @ p["wo"].astype(dt)


def _block_step(cfg: ModelConfig, kind: str, p: Dict, x_t: jax.Array,
                cache: Dict, pos: jax.Array) -> Tuple[jax.Array, Dict]:
    h = L.rmsnorm(p["ln1"], x_t, cfg.norm_eps)
    if kind in ("attn", "local"):
        if kind == "local":
            o, cache = _local_step(p["attn"], cfg, h, cache, pos)
        elif cfg.attention == "mla":
            o, cache = _mla_step(p["attn"], cfg, h, cache, pos)
        else:
            o, cache = _gqa_step(p["attn"], cfg, h, cache, pos)
        x_t = x_t + o
        if cfg.is_encoder_decoder:
            h = L.rmsnorm(p["ln_cross"], x_t, cfg.norm_eps)
            x_t = x_t + _cross_step(p["cross"], cfg, h, cache)
        h = L.rmsnorm(p["ln2"], x_t, cfg.norm_eps)
        if cfg.num_experts:
            x_t = x_t + MOE.moe_apply(p["moe"], cfg, h[:, None, :])[:, 0]
        else:
            x_t = x_t + L.swiglu_apply(p["ffn"], h)
    elif kind == "rglru":
        st = RG.RecurrentState(conv=cache["conv"], h=cache["h"])
        o, st = RG.block_step(p["rec"], cfg, h, st)
        cache = {"conv": st.conv, "h": st.h}
        x_t = x_t + o
        h = L.rmsnorm(p["ln2"], x_t, cfg.norm_eps)
        x_t = x_t + L.swiglu_apply(p["ffn"], h)
    elif kind == "mlstm":
        st = X.MLstmState(C=cache["C"], n=cache["n"], m=cache["m"])
        o, st = X.mlstm_block_step(p["cell"], cfg, h, st)
        cache = {"C": st.C, "n": st.n, "m": st.m}
        x_t = x_t + o
    elif kind == "slstm":
        st = X.SLstmState(h=cache["h"], c=cache["c"], n=cache["n"],
                          m=cache["m"])
        o, st = X.slstm_block_step(p["cell"], cfg, h, st)
        cache = {"h": st.h, "c": st.c, "n": st.n, "m": st.m}
        x_t = x_t + o
    return x_t, cache


# ---------------------------------------------------------------------------
# public: decode_step / prefill
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params: PyTree, cache: Dict,
                token: jax.Array) -> Tuple[jax.Array, Dict]:
    """One new token against the cache.  token: [B] int32 -> logits [B, V]."""
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    x_t = jnp.take(params["embed"], token, axis=0).astype(dt)

    def body(x, inp):
        gp, gc = inp
        new_gc = {}
        for i, kind in enumerate(cfg.pattern):
            x, new_gc[f"b{i}"] = _block_step(cfg, kind, gp[f"b{i}"], x,
                                             gc[f"b{i}"], pos)
        return x, new_gc

    if cfg.scan_layers:
        x_t, new_blocks = jax.lax.scan(body, x_t,
                                       (params["groups"], cache["blocks"]))
    else:
        # unrolled (dry-run cost-measurement path — see launch/dryrun.py)
        G = jax.tree.leaves(params["groups"])[0].shape[0]
        outs = []
        for g in range(G):
            x_t, gc = body(x_t, jax.tree.map(
                lambda a: a[g], (params["groups"], cache["blocks"])))
            outs.append(gc)
        new_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x_t = L.rmsnorm(params["final_norm"], x_t, cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = x_t @ unembed.astype(dt)
    return logits, {"pos": pos + 1, "blocks": new_blocks}


def _block_prefill(cfg: ModelConfig, kind: str, p: Dict, x: jax.Array,
                   T_max: int, enc_out) -> Tuple[jax.Array, Dict]:
    """Full-sequence block application that also emits its decode cache."""
    B, S, d = x.shape
    dt = x.dtype
    KV, hd, H = cfg.num_kv_heads, cfg.hd, cfg.num_heads
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    pos = jnp.arange(S)
    cache: Dict[str, jax.Array] = {}
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        if cfg.attention == "mla":
            c_kv, k_rope = L.mla_latent(p["attn"], cfg, h, pos)
            pad = [(0, 0), (0, T_max - S), (0, 0)]
            cache["ckv"] = jnp.pad(c_kv, pad)
            cache["krope"] = jnp.pad(k_rope, pad)
            x = x + L.mla_apply(p["attn"], cfg, h)
        else:
            k, v = L.gqa_project_kv(p["attn"], cfg, h, pos)
            q = L.gqa_project_q(p["attn"], cfg, h, pos)
            G = H // KV
            o = L.flash_attention(q, L.repeat_kv(k, G), L.repeat_kv(v, G),
                                  causal=True, window=window,
                                  chunk=cfg.attn_chunk)
            x = x + o.reshape(B, S, H * hd) @ p["attn"]["wo"].astype(dt)
            if kind == "local":
                W = cfg.window
                # last W positions, laid out so slot j = pos (S+j-W) % W...
                # ring layout: slot j holds abs position with j == p % W
                take = jnp.arange(T_max := W) if False else None
                idx = (jnp.arange(W) - W + S) if S >= W else None
                if S >= W:
                    sel = jnp.arange(S - W, S)
                    slots = sel % W
                    kw = jnp.zeros((B, W, KV, hd), dt).at[:, slots].set(
                        k[:, sel])
                    vw = jnp.zeros((B, W, KV, hd), dt).at[:, slots].set(
                        v[:, sel])
                else:
                    kw = jnp.zeros((B, W, KV, hd), dt).at[:, :S].set(k)
                    vw = jnp.zeros((B, W, KV, hd), dt).at[:, :S].set(v)
                cache["k"], cache["v"] = kw, vw
            else:
                pad = [(0, 0), (0, T_max - S), (0, 0), (0, 0)]
                cache["k"] = jnp.pad(k, pad)
                cache["v"] = jnp.pad(v, pad)
        if cfg.is_encoder_decoder:
            h2 = L.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
            x = x + L.gqa_apply(p["cross"], cfg, h2, causal=False,
                                kv_x=enc_out, use_rope=False)
            F = enc_out.shape[1]
            ck = (enc_out @ p["cross"]["wk"].astype(dt)).reshape(
                B, F, KV, hd)
            cv = (enc_out @ p["cross"]["wv"].astype(dt)).reshape(
                B, F, KV, hd)
            cache["ck"], cache["cv"] = ck, cv
        h3 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.num_experts:
            x = x + MOE.moe_apply(p["moe"], cfg, h3)
        else:
            x = x + L.swiglu_apply(p["ffn"], h3)
    elif kind == "rglru":
        dtp = x.dtype
        gate = jax.nn.gelu(h @ p["rec"]["w_gate"].astype(dtp))
        u = h @ p["rec"]["w_in"].astype(dtp)
        from repro.models.rglru import _conv_causal, rglru_scan
        u_conv = _conv_causal(p["rec"], u, cfg)
        hh = rglru_scan(p["rec"], u_conv)
        x = x + (gate * hh) @ p["rec"]["w_out"].astype(dtp)
        cw = cfg.conv_width
        cache["conv"] = u[:, S - (cw - 1):S, :] if S >= cw - 1 else jnp.pad(
            u, [(0, 0), (cw - 1 - S, 0), (0, 0)])
        cache["h"] = hh[:, -1].astype(jnp.float32)
        h4 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + L.swiglu_apply(p["ffn"], h4)
    elif kind == "mlstm":
        u = h @ p["cell"]["w_up"].astype(dt)
        gate = jax.nn.silu(h @ p["cell"]["w_gate"].astype(dt))
        hm, st = X.mlstm_chunkwise(p["cell"], cfg, u, chunk=cfg.attn_chunk)
        hm = L.rmsnorm(p["cell"]["norm"], hm, cfg.norm_eps)
        x = x + (hm * gate) @ p["cell"]["w_down"].astype(dt)
        cache = {"C": st.C, "n": st.n, "m": st.m}
    elif kind == "slstm":
        hs, st = X.slstm_scan(p["cell"], cfg, h)
        hs = L.rmsnorm(p["cell"]["norm"], hs, cfg.norm_eps)
        up = (hs @ p["cell"]["w_up1"].astype(dt)) * jax.nn.gelu(
            hs @ p["cell"]["w_up2"].astype(dt))
        x = x + up @ p["cell"]["w_down"].astype(dt)
        cache = {"h": st.h, "c": st.c, "n": st.n, "m": st.m}
    return x, cache


def prefill(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array],
            T_max: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """Process a prompt, returning (last-position logits [B,V], cache)."""
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    T_max = T_max or S
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.frontend == "patches" and "patches" in batch:
        P = batch["patches"].shape[1]
        x = jnp.concatenate([batch["patches"].astype(dt), x[:, P:]], axis=1)
    enc_out = None
    if cfg.is_encoder_decoder:
        from repro.models.transformer import _encode
        enc_out = _encode(cfg, params, batch["frames"])

    def body(x, gp):
        gc = {}
        for i, kind in enumerate(cfg.pattern):
            x, gc[f"b{i}"] = _block_prefill(cfg, kind, gp[f"b{i}"], x,
                                            T_max, enc_out)
        return x, gc

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, blocks = jax.lax.scan(body, x, params["groups"])
    else:
        G = jax.tree.leaves(params["groups"])[0].shape[0]
        outs = []
        for g in range(G):
            x, gc = body(x, jax.tree.map(lambda a: a[g], params["groups"]))
            outs.append(gc)
        blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = L.rmsnorm(params["final_norm"], x[:, -1], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"])
    logits = x @ unembed.astype(dt)
    return logits, {"pos": jnp.asarray(S, jnp.int32), "blocks": blocks}
