"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strict recurrence).

mLSTM runs CHUNKWISE-PARALLEL for train/prefill: within a chunk the
stabilized quadratic form, across chunks a scanned (C, n, m) state — per-step
memory is O(chunk^2), which is what lets prefill_32k and train_4k lower
without an S x S (or S-step carry) blow-up.  Decode is the O(1) recurrent
step, making long_500k legal for this family.

sLSTM has hidden-to-hidden feedback (R @ h_{t-1}) and is inherently
sequential — lax.scan over time, as the paper itself concedes.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLstmState(NamedTuple):
    C: jax.Array   # [B, H, hd, hd] matrix memory (f32)
    n: jax.Array   # [B, H, hd] normalizer
    m: jax.Array   # [B, H] stabilizer


def mlstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = 2 * d                       # PF=2 up-projection (xLSTM paper)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    pdt = jnp.dtype(cfg.param_dtype)
    return {
        "w_up": L.dense_init(ks[0], d, w, pdt),
        "w_gate": L.dense_init(ks[1], d, w, pdt),
        "wq": L.dense_init(ks[2], w, w, pdt),
        "wk": L.dense_init(ks[3], w, w, pdt),
        "wv": L.dense_init(ks[4], w, w, pdt),
        "w_if": L.dense_init(ks[5], w, 2 * H, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,), jnp.float32),
                                 3.0 * jnp.ones((H,), jnp.float32)]),
        "norm": L.rmsnorm_init(w, pdt),
        "w_down": L.dense_init(ks[6], w, d, pdt),
    }


def _mlstm_qkvif(params: dict, cfg: ModelConfig, u: jax.Array):
    """u: [B, S, w] -> q,k,v [B,H,S,hd], i/f gate pre-acts [B,H,S]."""
    B, S, w = u.shape
    H = cfg.num_heads
    hd = w // H
    dt = u.dtype
    q = (u @ params["wq"].astype(dt)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (u @ params["wk"].astype(dt)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k / math.sqrt(hd)
    v = (u @ params["wv"].astype(dt)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    g = u.astype(jnp.float32) @ params["w_if"] + params["b_if"]   # [B,S,2H]
    i_pre = g[..., :H].transpose(0, 2, 1)                         # [B,H,S]
    f_pre = g[..., H:].transpose(0, 2, 1)
    return q, k, v, i_pre, f_pre


def mlstm_chunkwise(params: dict, cfg: ModelConfig, u: jax.Array,
                    state: MLstmState | None = None,
                    chunk: int = 256) -> Tuple[jax.Array, MLstmState]:
    """Chunkwise-parallel mLSTM. u: [B, S, w] -> ([B, S, w], final state)."""
    B, S, w = u.shape
    H = cfg.num_heads
    hd = w // H
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, cfg, u)
    logf = jax.nn.log_sigmoid(f_pre)                              # [B,H,S]

    Lc = min(chunk, S)
    S_orig = S
    pad = (-S) % Lc
    if pad:
        # padded steps contribute nothing: i = -inf (no write), logf = 0
        # (no decay), so the final state is exact.
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, 0), (0, pad)),
                        constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
        S = S + pad
    nc = S // Lc

    def reshape_c(x, trailing):
        return x.reshape((B, H, nc, Lc) + trailing).transpose(
            (2, 0, 1, 3) + tuple(range(4, 4 + len(trailing))))

    qc = reshape_c(q.astype(jnp.float32), (hd,))   # [nc,B,H,Lc,hd]
    kc = reshape_c(k.astype(jnp.float32), (hd,))
    vc = reshape_c(v.astype(jnp.float32), (hd,))
    ic = reshape_c(i_pre, ())                      # [nc,B,H,Lc]
    fc = reshape_c(logf, ())

    if state is None:
        state = init_mlstm_state(cfg, B, w)

    tri = jnp.tril(jnp.ones((Lc, Lc), bool))

    # NOTE: unrolled python loop + per-chunk jax.checkpoint, NOT lax.scan —
    # same rationale as layers.flash_attention (cost-analysis fidelity for
    # the dry-run roofline + lean backward residuals).
    def step(carry, inp):
        C0, n0, m0 = carry
        qq, kk, vv, ii, ff = inp
        F = jnp.cumsum(ff, axis=-1)                               # [B,H,Lc]
        A = m0[..., None] + F                                     # inter decay
        # intra log-weights W[t,j] = F_t - F_j + i_j   (j <= t)
        Wlog = F[..., :, None] - F[..., None, :] + ii[..., None, :]
        Wlog = jnp.where(tri, Wlog, -jnp.inf)
        m_t = jnp.maximum(A, jnp.max(Wlog, axis=-1))              # [B,H,Lc]
        intra = jnp.exp(Wlog - m_t[..., None])                    # [B,H,Lc,Lc]
        scores = jnp.einsum("bhtd,bhjd->bhtj", qq, kk) * intra
        h_num = (jnp.einsum("bhtj,bhjd->bhtd", scores, vv)
                 + jnp.exp(A - m_t)[..., None]
                 * jnp.einsum("bhtd,bhde->bhte", qq, C0))
        n_t = (jnp.sum(scores, axis=-1)
               + jnp.exp(A - m_t) * jnp.einsum("bhtd,bhd->bht", qq, n0))
        denom = jnp.maximum(jnp.abs(n_t), jnp.exp(-m_t))
        h = h_num / denom[..., None]                              # [B,H,Lc,hd]
        # state update to chunk end
        FL = F[..., -1:]                                          # [B,H,1]
        w_end = FL - F + ii                                       # [B,H,Lc]
        m1 = jnp.maximum((m0[..., None] + FL)[..., 0],
                         jnp.max(w_end, axis=-1))                 # [B,H]
        upd = jnp.exp(w_end - m1[..., None])                      # [B,H,Lc]
        C1 = (jnp.exp(m0 + FL[..., 0] - m1)[..., None, None] * C0
              + jnp.einsum("bhj,bhjd,bhje->bhde", upd, kk, vv))
        n1 = (jnp.exp(m0 + FL[..., 0] - m1)[..., None] * n0
              + jnp.einsum("bhj,bhjd->bhd", upd, kk))
        return (C1, n1, m1), h

    remat_step = jax.checkpoint(step)
    carry = (state.C, state.n, state.m)
    hs_list = []
    for c in range(nc):
        carry, h_c = remat_step(
            carry, (qc[c], kc[c], vc[c], ic[c], fc[c]))
        hs_list.append(h_c)
    (Cf, nf, mf) = carry
    hs = jnp.stack(hs_list) if nc > 1 else hs_list[0][None]
    # hs: [nc, B, H, Lc, hd] -> [B, S, w]
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, w).astype(u.dtype)
    return h[:, :S_orig], MLstmState(C=Cf, n=nf, m=mf)


def mlstm_step(params: dict, cfg: ModelConfig, u_t: jax.Array,
               state: MLstmState) -> Tuple[jax.Array, MLstmState]:
    """One-token recurrent mLSTM. u_t: [B, w]."""
    B, w = u_t.shape
    H = cfg.num_heads
    hd = w // H
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, cfg, u_t[:, None, :])
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]      # [B,H,hd]
    i_pre, f_pre = i_pre[:, :, 0], f_pre[:, :, 0]     # [B,H]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state.m, i_pre)
    f_s = jnp.exp(logf + state.m - m_new)[..., None]
    i_s = jnp.exp(i_pre - m_new)[..., None]
    C = f_s[..., None] * state.C + i_s[..., None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = f_s * state.n + i_s * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)),
        jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, w).astype(u_t.dtype)
    return h, MLstmState(C=C, n=n, m=m_new)


def init_mlstm_state(cfg: ModelConfig, batch: int, w: int) -> MLstmState:
    H = cfg.num_heads
    hd = w // H
    return MLstmState(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_block_apply(params: dict, cfg: ModelConfig, x: jax.Array,
                      ) -> jax.Array:
    dt = x.dtype
    u = x @ params["w_up"].astype(dt)
    gate = jax.nn.silu(x @ params["w_gate"].astype(dt))
    h, _ = mlstm_chunkwise(params, cfg, u, chunk=cfg.attn_chunk)
    h = L.rmsnorm(params["norm"], h, cfg.norm_eps)
    return (h * gate) @ params["w_down"].astype(dt)


def mlstm_block_step(params: dict, cfg: ModelConfig, x_t: jax.Array,
                     state: MLstmState) -> Tuple[jax.Array, MLstmState]:
    dt = x_t.dtype
    u = x_t @ params["w_up"].astype(dt)
    gate = jax.nn.silu(x_t @ params["w_gate"].astype(dt))
    h, new_state = mlstm_step(params, cfg, u, state)
    h = L.rmsnorm(params["norm"], h, cfg.norm_eps)
    return (h * gate) @ params["w_down"].astype(dt), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLstmState(NamedTuple):
    h: jax.Array   # [B, w]
    c: jax.Array   # [B, w]
    n: jax.Array   # [B, w]
    m: jax.Array   # [B, w]


def slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = d
    H = cfg.num_heads
    hd = w // H
    ks = jax.random.split(key, 5)
    pdt = jnp.dtype(cfg.param_dtype)
    ffd = (int(w * 4 / 3) + 7) // 8 * 8
    return {
        "w_x": L.dense_init(ks[0], d, 4 * w, pdt),
        # block-diagonal recurrent weights, one [hd, 4*hd] block per head
        "r_h": (jax.random.normal(ks[1], (H, hd, 4 * hd), jnp.float32)
                / math.sqrt(hd)).astype(jnp.float32),
        "bias": jnp.concatenate([
            jnp.zeros((w,), jnp.float32), jnp.zeros((w,), jnp.float32),
            3.0 * jnp.ones((w,), jnp.float32),
            jnp.zeros((w,), jnp.float32)]),
        "norm": L.rmsnorm_init(w, pdt),
        "w_up1": L.dense_init(ks[2], w, ffd, pdt),
        "w_up2": L.dense_init(ks[3], w, ffd, pdt),
        "w_down": L.dense_init(ks[4], ffd, d, pdt),
    }


def _slstm_cell(params: dict, H: int, xw_t: jax.Array, st: SLstmState
                ) -> SLstmState:
    """xw_t: [B, 4w] precomputed input projection at step t (f32)."""
    B, w4 = xw_t.shape
    w = w4 // 4
    hd = w // H
    hb = st.h.reshape(B, H, hd)
    rec = jnp.einsum("bhd,hde->bhe", hb, params["r_h"]).reshape(B, 4 * w)
    pre = xw_t + rec + params["bias"]
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + st.m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(logf + st.m - m_new)
    c = f_s * st.c + i_s * z
    n = f_s * st.n + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    return SLstmState(h=h, c=c, n=n, m=m_new)


def slstm_scan(params: dict, cfg: ModelConfig, x: jax.Array,
               state: SLstmState | None = None
               ) -> Tuple[jax.Array, SLstmState]:
    """x: [B, S, d] -> hidden sequence [B, S, w]. Strictly sequential."""
    B, S, d = x.shape
    w = d
    H = cfg.num_heads
    if state is None:
        state = init_slstm_state(cfg, B)
    xw = (x @ params["w_x"].astype(x.dtype)).astype(jnp.float32)

    def step(st, xw_t):
        new = _slstm_cell(params, H, xw_t, st)
        return new, new.h

    final, hs = jax.lax.scan(step, state, xw.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2).astype(x.dtype), final


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLstmState:
    w = cfg.d_model
    z = jnp.zeros((batch, w), jnp.float32)
    return SLstmState(h=z, c=z, n=z,
                      m=jnp.full((batch, w), -1e30, jnp.float32))


def slstm_block_apply(params: dict, cfg: ModelConfig, x: jax.Array
                      ) -> jax.Array:
    h, _ = slstm_scan(params, cfg, x)
    h = L.rmsnorm(params["norm"], h, cfg.norm_eps)
    dt = x.dtype
    up = (h @ params["w_up1"].astype(dt)) * jax.nn.gelu(
        h @ params["w_up2"].astype(dt))
    return up @ params["w_down"].astype(dt)


def slstm_block_step(params: dict, cfg: ModelConfig, x_t: jax.Array,
                     state: SLstmState) -> Tuple[jax.Array, SLstmState]:
    xw = (x_t @ params["w_x"].astype(x_t.dtype)).astype(jnp.float32)
    new = _slstm_cell(params, cfg.num_heads, xw, state)
    h = L.rmsnorm(params["norm"], new.h.astype(x_t.dtype), cfg.norm_eps)
    dt = x_t.dtype
    up = (h @ params["w_up1"].astype(dt)) * jax.nn.gelu(
        h @ params["w_up2"].astype(dt))
    return up @ params["w_down"].astype(dt), new
