"""CKKS-profile helpers (paper §6.1: floating-point operands).

The compare pipeline is scheme-agnostic once operands are fixed-point
encoded; this module provides the float encode/decode contract and the
approximate-equality threshold used by Alg. 2's τ in the CKKS profile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.params import HadesParams


def encode(params: HadesParams, x: jax.Array) -> jax.Array:
    """Real -> fixed-point payload units (what encrypt._payload does)."""
    return jnp.round(jnp.asarray(x, jnp.float64) * params.delta_enc
                     ).astype(jnp.int64)


def decode(params: HadesParams, v: jax.Array) -> jax.Array:
    return v.astype(jnp.float64) / params.delta_enc


def equality_tolerance(params: HadesParams) -> float:
    """Smallest |x0 - x1| the CKKS profile can distinguish from equality:
    below this, Alg. 2 returns 0 (approximate equality) by design."""
    return params.tau / (params.scale * params.delta_enc)


def eps_to_tau(params: HadesParams, eps: float) -> int:
    """Plaintext-units tolerance ε -> integer eval-domain threshold.

    The eval value of a comparison is ≈ scale·Δ_enc·(m0-m1) + noise, so a
    caller-chosen ε-band |m0-m1| <= ε becomes the decode threshold
    τ_ε = ε·scale·Δ_enc.  The result is clamped from below to the
    profile's own τ: an ε under `equality_tolerance(params)` would sit
    inside the noise floor and cannot be resolved — it silently degrades
    to the profile's native equality semantics (documented contract,
    checked by tests).
    """
    if eps < 0:
        raise ValueError(f"epsilon must be non-negative, got {eps}")
    return max(int(round(eps * params.scale * params.delta_enc)), params.tau)
