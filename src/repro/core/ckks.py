"""CKKS-profile helpers (paper §6.1: floating-point operands).

The compare pipeline is scheme-agnostic once operands are fixed-point
encoded; this module provides the float encode/decode contract and the
approximate-equality threshold used by Alg. 2's τ in the CKKS profile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.params import HadesParams


def encode(params: HadesParams, x: jax.Array) -> jax.Array:
    """Real -> fixed-point payload units (what encrypt._payload does)."""
    return jnp.round(jnp.asarray(x, jnp.float64) * params.delta_enc
                     ).astype(jnp.int64)


def decode(params: HadesParams, v: jax.Array) -> jax.Array:
    return v.astype(jnp.float64) / params.delta_enc


def equality_tolerance(params: HadesParams) -> float:
    """Smallest |x0 - x1| the CKKS profile can distinguish from equality:
    below this, Alg. 2 returns 0 (approximate equality) by design."""
    return params.tau / (params.scale * params.delta_enc)
