"""Polynomial samplers (paper §4.2 "Noise in Cryptography").

All samplers are counter-based (jax.random), so distributed workers can
regenerate any sample deterministically from (seed, role, index) — this is
what makes checkpoints/elastic restarts replayable (DESIGN.md §5).

Note on the secret key: Alg. 1 line 1 says "uniformly from the ring", but
line 5 requires scale > ||sk||_inf, which is unsatisfiable for a uniform
sk (||sk||_inf ~ q/2).  We follow standard RLWE practice (and the paper's
own OpenFHE backend) and sample sk ternary, making ||sk||_inf = 1 and the
scale condition trivially satisfiable.  Recorded as a deviation in
DESIGN.md §7.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.params import HadesParams


def uniform_poly(params: HadesParams, key: jax.Array,
                 shape: tuple = ()) -> jax.Array:
    """Uniform element of R_q (per-tower uniform residues). [..., K, n]."""
    keys = jax.random.split(key, params.num_towers)
    cols = []
    for k, q in enumerate(params.qs):
        cols.append(jax.random.randint(
            keys[k], shape + (params.n,), 0, q, dtype=jnp.int64))
    return jnp.stack(cols, axis=-2)


def _small_to_rns(params: HadesParams, small: jax.Array) -> jax.Array:
    """Lift a small signed integer poly [..., n] into RNS [..., K, n]."""
    import numpy as np
    qs = jnp.asarray(np.asarray(params.qs, dtype=jnp.int64))  # [K]
    return small[..., None, :] % qs[:, None]


def ternary_poly(params: HadesParams, key: jax.Array,
                 shape: tuple = ()) -> jax.Array:
    """sk / encryption randomness u: coefficients in {-1, 0, 1}."""
    small = jax.random.randint(key, shape + (params.n,), -1, 2,
                               dtype=jnp.int64)
    return _small_to_rns(params, small)


def noise_poly(params: HadesParams, key: jax.Array,
               shape: tuple = (), bound: int | None = None) -> jax.Array:
    """e ~ U(-B_e, B_e)^n per the paper; verified |e|_inf <= B_e by range."""
    b = params.noise_bound if bound is None else bound
    small = jax.random.randint(key, shape + (params.n,), -b, b + 1,
                               dtype=jnp.int64)
    return _small_to_rns(params, small)


def small_signed(params: HadesParams, key: jax.Array, shape: tuple,
                 bound: int) -> jax.Array:
    """Small signed integers (NOT lifted to RNS) — used for perturbations."""
    return jax.random.randint(key, shape, -bound, bound + 1, dtype=jnp.int64)
