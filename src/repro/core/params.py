"""HADES parameter selection (paper §4.2, §6.1).

The paper's OpenFHE deployment uses BFV (n=4096, t=65537) and CKKS (n=16384)
with multi-limb ~60-bit moduli.  On TPU we keep every residue below 2^31 so a
product of two residues fits in a signed int64 multiply-accumulate, and reach
the paper's dynamic range with a 2-tower RNS modulus Q = q0*q1 ~ 2^62
(DESIGN.md §3).  All moduli are NTT-friendly primes (q ≡ 1 mod 2n).

Headroom algebra for the compare path (DESIGN.md §1.1/§1.2):

    Eval = scale * (Δ_enc*(m0-m1) + e_enc) + e_key-switch      (mod Q)

so correctness needs
    scale * Δ_enc * max|m0-m1|  <  Q/2            (no wrap)
    |scale*e_enc + e_ks|        <  scale*Δ_enc/2  (τ threshold separates 0/±1)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# prime / root-of-unity machinery (host-side, pure python ints)
# ---------------------------------------------------------------------------

_MR_BASES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    """Deterministic Miller-Rabin for n < 3.3e24."""
    if n < 2:
        return False
    for p in _MR_BASES:
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in _MR_BASES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


@functools.lru_cache(maxsize=None)
def ntt_primes(n: int, count: int, max_bits: int = 31) -> Tuple[int, ...]:
    """Largest `count` primes q < 2^max_bits with q ≡ 1 (mod 2n)."""
    two_n = 2 * n
    q = ((1 << max_bits) // two_n) * two_n + 1
    out = []
    while len(out) < count and q > two_n:
        if q < (1 << max_bits) and is_prime(q):
            out.append(q)
        q -= two_n
    if len(out) < count:
        raise ValueError(f"not enough NTT primes for n={n}")
    return tuple(out)


def _primitive_root(q: int) -> int:
    """Smallest generator of Z_q^* (q prime)."""
    phi = q - 1
    factors = []
    m = phi
    d = 2
    while d * d <= m:
        if m % d == 0:
            factors.append(d)
            while m % d == 0:
                m //= d
        d += 1
    if m > 1:
        factors.append(m)
    for g in range(2, q):
        if all(pow(g, phi // f, q) != 1 for f in factors):
            return g
    raise ValueError("no generator found")


@functools.lru_cache(maxsize=None)
def negacyclic_root(q: int, n: int) -> int:
    """psi: a primitive 2n-th root of unity mod q (psi^n = -1)."""
    g = _primitive_root(q)
    psi = pow(g, (q - 1) // (2 * n), q)
    assert pow(psi, n, q) == q - 1, "psi^n != -1"
    return psi


def bit_reverse_perm(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


# ---------------------------------------------------------------------------
# Parameter containers
# ---------------------------------------------------------------------------

CompareKeyMode = Literal["paper", "gadget"]
Scheme = Literal["bfv", "ckks"]


@dataclasses.dataclass(frozen=True)
class Profile:
    """A named parameter profile. `paper-bfv`/`paper-ckks` match §6.1."""

    name: str
    scheme: Scheme
    n: int                    # ring dimension
    num_towers: int           # RNS towers (31-bit primes)
    t: int                    # BFV plaintext modulus (ignored for ckks)
    log_delta_enc: int        # encoding scale Δ_enc = 2^log_delta_enc
    log_scale: int            # HADES `scale` parameter (paper: [1e2, 1e4])
    noise_bound: int          # B_e: coefficients of e ~ U(-B_e, B_e)
    epsilon: float            # FAE perturbation range (paper: [1e-3, 1e-2])
    gadget_log_base: int      # digit base B = 2^gadget_log_base (gadget mode)
    # equality threshold: BFV uses the integer semantics tau = s*Δ/2
    # (min nonzero diff is 1); CKKS uses precision semantics — values
    # within 2^-equality_bits count as equal (must stay above the noise
    # floor; noise.py checks).  0 = integer semantics.
    equality_bits: int = 0


PROFILES = {
    # Paper §6.1: BFV with n=4096, t=65537, 128-bit-class ring. 2 RNS towers
    # stand in for OpenFHE's 60-bit limbs (DESIGN.md §3, §7).
    "paper-bfv": Profile(
        name="paper-bfv", scheme="bfv", n=4096, num_towers=2, t=65537,
        log_delta_enc=13, log_scale=12, noise_bound=2, epsilon=0.01,
        gadget_log_base=8,
    ),
    # Paper §6.1: CKKS with n=16384, scaling modulus ~2^59 -> Δ_enc=2^20 here.
    "paper-ckks": Profile(
        name="paper-ckks", scheme="ckks", n=16384, num_towers=2, t=0,
        log_delta_enc=20, log_scale=12, noise_bound=2, epsilon=0.01,
        gadget_log_base=8, equality_bits=7,
    ),
    # Small profiles for unit tests / CI (single tower).
    "test-bfv": Profile(
        name="test-bfv", scheme="bfv", n=256, num_towers=1, t=257,
        log_delta_enc=9, log_scale=6, noise_bound=1, epsilon=0.01,
        gadget_log_base=6,
    ),
    "test-ckks": Profile(
        name="test-ckks", scheme="ckks", n=512, num_towers=2, t=0,
        log_delta_enc=16, log_scale=10, noise_bound=1, epsilon=0.01,
        gadget_log_base=8, equality_bits=6,
    ),
    # Mid-size profile for benchmarks where n=4096 x 2 towers is overkill.
    "bench-bfv": Profile(
        name="bench-bfv", scheme="bfv", n=1024, num_towers=2, t=65537,
        log_delta_enc=13, log_scale=12, noise_bound=2, epsilon=0.01,
        gadget_log_base=8,
    ),
}


@dataclasses.dataclass(frozen=True)
class HadesParams:
    """Fully-resolved parameters + precomputed NTT tables (host numpy).

    Device code receives the numpy tables as jnp arrays; this object itself
    is static (hashable) and can be closed over by jit.
    """

    profile: Profile
    mode: CompareKeyMode
    qs: Tuple[int, ...]                  # RNS towers

    # -- derived ---------------------------------------------------------
    @property
    def n(self) -> int:
        return self.profile.n

    @property
    def num_towers(self) -> int:
        return self.profile.num_towers

    @property
    def Q(self) -> int:
        out = 1
        for q in self.qs:
            out *= q
        return out

    @property
    def t(self) -> int:
        return self.profile.t

    @property
    def delta_enc(self) -> int:
        return 1 << self.profile.log_delta_enc

    @property
    def scale(self) -> int:
        return 1 << self.profile.log_scale

    @property
    def noise_bound(self) -> int:
        return self.profile.noise_bound

    @property
    def epsilon(self) -> float:
        return self.profile.epsilon

    @property
    def gadget_base(self) -> int:
        return 1 << self.profile.gadget_log_base

    @property
    def gadget_digits_per_tower(self) -> int:
        bits = max(q.bit_length() for q in self.qs)
        b = self.profile.gadget_log_base
        return -(-bits // b)  # ceil

    @property
    def tau(self) -> int:
        """Decode threshold τ (paper Alg. 2 line 5).  BFV: scale*Δ_enc/2
        (integer tie semantics); CKKS: scale*Δ_enc*2^-equality_bits."""
        if self.profile.equality_bits:
            return (self.scale * self.delta_enc
                    ) >> self.profile.equality_bits
        return (self.scale * self.delta_enc) // 2

    @property
    def max_operand(self) -> int:
        """Largest |m0 - m1| the compare path supports without wrap."""
        return self.Q // (2 * self.scale * self.delta_enc) - 1

    # -- NTT tables ------------------------------------------------------
    def ntt_tables(self) -> "NttTables":
        return make_ntt_tables(self.qs, self.n)

    # -- CRT constants for decode ---------------------------------------
    def crt_alphas(self) -> Tuple[int, ...]:
        """alpha_k = (Q/q_k) * [(Q/q_k)^-1 mod q_k]  (mod Q)."""
        Q = self.Q
        out = []
        for q in self.qs:
            m = Q // q
            out.append((m * pow(m % q, q - 2, q)) % Q)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class NttTables:
    """Per-tower twiddle tables, host numpy (converted to device by callers).

    Layout (K towers, ring dim n, S = log2 n stages):
      psi_pow      [K, n]  psi^i            (negacyclic pre-twist)
      psi_inv_pow  [K, n]  psi^-i * n^-1    (post-twist, n^-1 folded in)
      stage_w      [K, S, n//2] per-stage butterfly twiddles (DIT layout)
      stage_w_inv  [K, S, n//2] inverse-NTT stage twiddles (DIF layout)
      bitrev       [n]
    """

    qs: Tuple[int, ...]
    n: int
    psi_pow: np.ndarray
    psi_inv_pow: np.ndarray
    stage_w: np.ndarray
    stage_w_inv: np.ndarray
    bitrev: np.ndarray


@functools.lru_cache(maxsize=None)
def make_ntt_tables(qs: Sequence[int], n: int) -> NttTables:
    qs = tuple(qs)
    stages = n.bit_length() - 1
    K = len(qs)
    psi_pow = np.zeros((K, n), dtype=np.int64)
    psi_inv_pow = np.zeros((K, n), dtype=np.int64)
    stage_w = np.zeros((K, stages, n // 2), dtype=np.int64)
    stage_w_inv = np.zeros((K, stages, n // 2), dtype=np.int64)
    for k, q in enumerate(qs):
        psi = negacyclic_root(q, n)
        psi_inv = pow(psi, q - 2, q)
        omega = psi * psi % q          # primitive n-th root
        omega_inv = pow(omega, q - 2, q)
        n_inv = pow(n, q - 2, q)
        acc = 1
        for i in range(n):
            psi_pow[k, i] = acc
            acc = acc * psi % q
        acc = n_inv
        for i in range(n):
            psi_inv_pow[k, i] = acc
            acc = acc * psi_inv % q
        # Stage s of a DIT NTT on bit-reversed input: half-block size
        # h = 2^s; twiddle for in-block position j is omega^(j * n / (2h)).
        for s in range(stages):
            h = 1 << s
            wbase = pow(omega, n // (2 * h), q)
            wbase_inv = pow(omega_inv, n // (2 * h), q)
            w = np.zeros(n // 2, dtype=np.int64)
            wi = np.zeros(n // 2, dtype=np.int64)
            for j in range(n // 2):
                e = j % h
                w[j] = pow(wbase, e, q)
                wi[j] = pow(wbase_inv, e, q)
            stage_w[k, s] = w
            stage_w_inv[k, s] = wi
    return NttTables(
        qs=qs, n=n,
        psi_pow=psi_pow, psi_inv_pow=psi_inv_pow,
        stage_w=stage_w, stage_w_inv=stage_w_inv,
        bitrev=bit_reverse_perm(n),
    )


def make_params(profile: str | Profile = "paper-bfv",
                mode: CompareKeyMode = "gadget") -> HadesParams:
    prof = PROFILES[profile] if isinstance(profile, str) else profile
    qs = ntt_primes(prof.n, prof.num_towers)
    return HadesParams(profile=prof, mode=mode, qs=qs)
