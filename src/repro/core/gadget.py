"""RNS-gadget CEK evaluation (DESIGN.md §1.1, mode="gadget").

Digit-decomposes ctΔ,1 before hitting the CEK so the key-noise contribution
stays bounded by  K*D * sqrt(n) * B * B_e  instead of wrapping mod Q:

    ctΔ,1  =  Σ_{k}  (ctΔ,1 mod q_k) · alpha_k                  (CRT)
           =  Σ_{k,j}  d_{k,j} · B^j · alpha_k,   ||d_{k,j}||_inf < B

    Σ_{k,j}  d_{k,j} ⊛ cek[k,j]  =  ctΔ,1 · sk · scale  +  Σ d⊛e   (mod Q)

Schedule (the part the Pallas kernel accelerates): forward-NTT all K*D digit
polys, MAC against the precomputed eval-domain CEK, one inverse NTT total.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ring as R
from repro.core.keys import KeySet
from repro.core.params import HadesParams


def digit_decompose(params: HadesParams, c1: jax.Array) -> jax.Array:
    """c1: [..., K, n] residues -> digits [..., K, D, n] in [0, B).

    Digits are tiny (< B <= 2^8 by default) so their RNS lift is the digit
    value itself in every tower.
    """
    D = params.gadget_digits_per_tower
    b = params.profile.gadget_log_base
    shifts = jnp.arange(D, dtype=jnp.int64) * b          # [D]
    mask = params.gadget_base - 1
    return (c1[..., :, None, :] >> shifts[None, :, None]) & mask


def gadget_keymul(ks: KeySet, c1: jax.Array) -> jax.Array:
    """Compute  c1 · sk · scale + (bounded noise)   via the gadget CEK.

    c1: [..., K, n]  ->  [..., K, n]
    """
    params, rng = ks.params, ks.ring
    K, n = params.num_towers, params.n
    D = params.gadget_digits_per_tower

    digits = digit_decompose(params, c1)                 # [..., K, D, n]
    # lift each digit poly to full RNS: value is < B so residue == value.
    # new axis ordering: [..., K_src, D, K_tower, n]
    dig_rns = jnp.broadcast_to(
        digits[..., :, :, None, :],
        digits.shape[:-1] + (K, n))
    flat = dig_rns.reshape(dig_rns.shape[:-4] + (K * D, K, n))
    dig_ntt = R.ntt(rng, flat)                           # [..., K*D, K, n]

    cek_ntt = ks.cek_gadget_ntt.reshape(K * D, K, n)     # [K*D, K, n]
    prod = (dig_ntt * cek_ntt) % rng.q_arr               # eval domain
    acc = jnp.sum(prod, axis=-3) % rng.q_arr             # [..., K, n]
    return R.intt(rng, acc)
