"""Algorithm 1: Key Generation.

Outputs (pk, sk, cek).  Two CEK realizations (DESIGN.md §1.1):

* mode="paper"  : cek = sk*scale + e_cek — the literal Alg. 1 lines 5-8.
  Correct only while |<e_cek, ctΔ,1>| < scale/2 (the paper's own
  precondition, Thm 4.1), which for uniform ctΔ,1 forces ||e_cek|| ≈ 0;
  we therefore expose `paper_ecek_weight` (number of nonzero noise
  coefficients) so experiments can dial the correctness/security tension.

* mode="gadget" : RNS-gadget CEK, cek[k,j] = B^j * alpha_k * sk * scale + e
  (key-switching form).  Comparisons stay correct with full-strength noise
  because Eval digit-decomposes ctΔ,1 first (gadget.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ring as R
from repro.core import sampling
from repro.core.params import HadesParams


@dataclasses.dataclass
class KeySet:
    params: HadesParams
    ring: R.Ring
    sk: jax.Array                      # [K, n] (ternary, RNS-lifted)
    pk0: jax.Array                     # [K, n]  -(a*sk + e_pk)
    pk1: jax.Array                     # [K, n]  a
    cek: Optional[jax.Array]           # paper mode: [K, n]
    cek_gadget: Optional[jax.Array]    # gadget mode: [K_src, D, K, n]
    cek_gadget_ntt: Optional[jax.Array]  # same, eval domain (precomputed)

    @property
    def mode(self) -> str:
        return self.params.mode


def _gadget_cek(params: HadesParams, rng: R.Ring, sk: jax.Array,
                key: jax.Array) -> jax.Array:
    """cek[k_src, j] = alpha_{k_src} * B^j * scale * sk + e  (mod Q), RNS.

    alpha_k = (Q/q_k) * [(Q/q_k)^{-1}]_{q_k}: the CRT lifting constant, so
    that sum_k (c1 mod q_k) * alpha_k = c1 (mod Q).  Each entry is a full
    RNS polynomial [K, n].
    """
    K, n = params.num_towers, params.n
    D = params.gadget_digits_per_tower
    B = params.gadget_base
    alphas = params.crt_alphas()
    scale = params.scale

    entries = []
    keys = jax.random.split(key, K * D)
    for k_src in range(K):
        for j in range(D):
            # host-side big-int constant:  alpha_k * B^j * scale  mod Q
            c = (alphas[k_src] * pow(B, j) % params.Q) * scale % params.Q
            # reduce into each tower
            c_rns = jnp.asarray(
                np.asarray([c % q for q in params.qs], dtype=np.int64)
            )[:, None]                                   # [K, 1]
            e = sampling.noise_poly(params, keys[k_src * D + j])
            entry = ((sk * c_rns) % rng.q_arr + e) % rng.q_arr
            entries.append(entry)
    return jnp.stack(entries).reshape(K, D, K, n)


def keygen(params: HadesParams, key: jax.Array,
           paper_ecek_weight: Optional[int] = None) -> KeySet:
    """Algorithm 1.  paper_ecek_weight: #nonzero coeffs of e_cek (paper mode);
    None => full-density U(-B_e,B_e) noise exactly as written."""
    rng = R.make_ring(params)
    k_sk, k_a, k_epk, k_cek, k_g = jax.random.split(key, 5)

    sk = sampling.ternary_poly(params, k_sk)                       # line 1
    a = sampling.uniform_poly(params, k_a)                         # line 2
    e_pk = sampling.noise_poly(params, k_epk)                      # line 3
    pk0 = R.neg(rng, R.add(rng, R.negacyclic_mul(rng, a, sk), e_pk))  # line 4

    # line 5: scale > max(2*B_e, ||sk||_inf) — checked statically.
    assert params.scale > max(2 * params.noise_bound, 1), \
        "profile violates Alg.1 line 5 scale condition"

    cek = None
    cek_gadget = None
    cek_gadget_ntt = None
    if params.mode == "paper":
        e_cek = sampling.noise_poly(params, k_cek)                 # line 6
        if paper_ecek_weight is not None:
            # keep only the first `weight` coefficients of the noise — the
            # knob for the §1.1 correctness/security study.
            mask = (jnp.arange(params.n) < paper_ecek_weight)
            e_cek = e_cek * mask
        sk_scaled = R.scalar_mul(rng, sk, params.scale)            # line 7
        cek = R.add(rng, sk_scaled, e_cek)                         # line 8
    else:
        cek_gadget = _gadget_cek(params, rng, sk, k_g)
        # Precompute the eval-domain form: Eval does (digit ⊛ cek) products,
        # so keeping cek in NTT form saves one forward NTT per entry/compare.
        flat = cek_gadget.reshape(-1, params.num_towers, params.n)
        cek_gadget_ntt = R.ntt(rng, flat).reshape(cek_gadget.shape)

    return KeySet(params=params, ring=rng, sk=sk, pk0=pk0, pk1=a,
                  cek=cek, cek_gadget=cek_gadget,
                  cek_gadget_ntt=cek_gadget_ntt)
