"""R_q = Z_q[x]/(x^n + 1) arithmetic in RNS form, pure JAX.

Polynomials are int64 arrays of shape [..., K, n] (K = number of RNS towers),
with residues kept in [0, q_k).  All products of two residues fit a signed
int64 (q_k < 2^31), so `%` gives exact modular arithmetic on CPU and in
Pallas interpret mode.  This module is also the *reference oracle* for the
Pallas NTT kernels (kernels/ref.py re-exports it).

The NTT is the standard negacyclic transform: pre-twist by psi^i, DIT
Cooley-Tukey forward, Gentleman-Sande inverse, post-twist by psi^-i * n^-1.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import HadesParams, NttTables


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Ring:
    """Device-side ring context. Static metadata + jnp twiddle tables.

    Registered as a pytree (qs/n static) so jit'd kernels can close over it
    or take it as an argument.
    """

    qs: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    q_arr: jax.Array = None          # [K, 1] int64
    psi_pow: jax.Array = None        # [K, n]
    psi_inv_pow: jax.Array = None    # [K, n]
    stage_w: jax.Array = None        # [K, S, n/2]
    stage_w_inv: jax.Array = None    # [K, S, n/2]
    bitrev: jax.Array = None         # [n]

    @property
    def num_towers(self) -> int:
        return len(self.qs)

    @property
    def stages(self) -> int:
        return self.n.bit_length() - 1


def make_ring(params: HadesParams) -> Ring:
    t: NttTables = params.ntt_tables()
    return Ring(
        qs=tuple(params.qs),
        n=params.n,
        q_arr=jnp.asarray(np.asarray(params.qs, dtype=np.int64)[:, None]),
        psi_pow=jnp.asarray(t.psi_pow),
        psi_inv_pow=jnp.asarray(t.psi_inv_pow),
        stage_w=jnp.asarray(t.stage_w),
        stage_w_inv=jnp.asarray(t.stage_w_inv),
        bitrev=jnp.asarray(t.bitrev),
    )


# ---------------------------------------------------------------------------
# elementwise ring ops
# ---------------------------------------------------------------------------

def add(ring: Ring, a: jax.Array, b: jax.Array) -> jax.Array:
    return (a + b) % ring.q_arr


def sub(ring: Ring, a: jax.Array, b: jax.Array) -> jax.Array:
    return (a - b) % ring.q_arr


def neg(ring: Ring, a: jax.Array) -> jax.Array:
    return (-a) % ring.q_arr


def scalar_mul(ring: Ring, a: jax.Array, s: jax.Array | int) -> jax.Array:
    """a * s mod q, s an int64 scalar already reduced below 2^31."""
    return (a * jnp.int64(s)) % ring.q_arr


def pointwise_mul(ring: Ring, a: jax.Array, b: jax.Array) -> jax.Array:
    return (a * b) % ring.q_arr


# ---------------------------------------------------------------------------
# NTT (pure-jnp reference implementation)
# ---------------------------------------------------------------------------

def _dit_stages(a: jax.Array, stage_w: jax.Array, q: jax.Array,
                n: int) -> jax.Array:
    """Forward DIT butterflies on bit-reversed input. a: [..., K, n]."""
    stages = n.bit_length() - 1
    for s in range(stages):
        h = 1 << s
        m = h * 2
        w = stage_w[:, s, :h]                      # [K, h]
        x = a.reshape(a.shape[:-1] + (n // m, m))
        u = x[..., :h]                             # [..., K, n/m, h]
        v = x[..., h:]
        t = (v * w[:, None, :]) % q[..., None]
        a = jnp.concatenate([(u + t) % q[..., None],
                             (u - t) % q[..., None]], axis=-1)
        a = a.reshape(a.shape[:-2] + (n,))
    return a


def _gs_stages(a: jax.Array, stage_w_inv: jax.Array, q: jax.Array,
               n: int) -> jax.Array:
    """Inverse Gentleman-Sande butterflies, natural-order input."""
    stages = n.bit_length() - 1
    for s in reversed(range(stages)):
        h = 1 << s
        m = h * 2
        w = stage_w_inv[:, s, :h]
        x = a.reshape(a.shape[:-1] + (n // m, m))
        u = x[..., :h]
        v = x[..., h:]
        a = jnp.concatenate([(u + v) % q[..., None],
                             ((u - v) * w[:, None, :]) % q[..., None]],
                            axis=-1)
        a = a.reshape(a.shape[:-2] + (n,))
    return a


def ntt(ring: Ring, a: jax.Array) -> jax.Array:
    """Negacyclic forward NTT. a: [..., K, n] -> [..., K, n] (eval domain)."""
    q = ring.q_arr  # [K, 1]
    a = (a * ring.psi_pow) % q            # pre-twist
    a = jnp.take(a, ring.bitrev, axis=-1)
    return _dit_stages(a, ring.stage_w, q, ring.n)


def intt(ring: Ring, a: jax.Array) -> jax.Array:
    """Negacyclic inverse NTT (includes n^-1 scaling)."""
    q = ring.q_arr
    a = _gs_stages(a, ring.stage_w_inv, q, ring.n)
    a = jnp.take(a, ring.bitrev, axis=-1)
    return (a * ring.psi_inv_pow) % q     # post-twist * n^-1


def negacyclic_mul(ring: Ring, a: jax.Array, b: jax.Array) -> jax.Array:
    """a * b in R_q via NTT."""
    return intt(ring, pointwise_mul(ring, ntt(ring, a), ntt(ring, b)))


def naive_negacyclic_mul(ring: Ring, a: jax.Array, b: jax.Array) -> jax.Array:
    """O(n^2) schoolbook negacyclic product — oracle for the NTT itself.

    Only for tests with small n. a, b: [K, n].
    """
    n = ring.n
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    k = (i + j) % n
    sign = jnp.where(i + j >= n, -1, 1).astype(jnp.int64)
    # out[k] = sum_{i+j = k mod n} sign * a[i]*b[j]; accumulate per tower
    # with mod after each outer-product row to stay inside int64.
    def tower(a_k, b_k, q):
        prod = (a_k[:, None] * b_k[None, :]) % q          # [n, n]
        contrib = (sign * prod) % q
        out = jnp.zeros((n,), jnp.int64)
        flat_k = k.reshape(-1)
        out = out.at[flat_k].add(contrib.reshape(-1) % q)
        return out % q
    outs = [tower(a[t], b[t], ring.qs[t]) for t in range(ring.num_towers)]
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# CRT decode (centered representative of a coefficient mod Q)
# ---------------------------------------------------------------------------

def _mulmod(a: jax.Array, b_int: int, m_int: int) -> jax.Array:
    """(a * b) mod m with m up to 2^62, via double-and-add. a: any shape."""
    acc = jnp.zeros_like(a)
    cur = a % m_int
    b = b_int % m_int
    while b:
        if b & 1:
            acc = (acc + cur) % m_int
        cur = (cur * 2) % m_int
        b >>= 1
    return acc


def crt_centered(params: HadesParams, residues: jax.Array) -> jax.Array:
    """Reconstruct centered value in (-Q/2, Q/2] from residues [..., K].

    Exact for Q < 2^62 (int64 double-and-add; the Python loop over bits is
    unrolled at trace time, b is a static host integer).
    """
    Q = params.Q
    alphas = params.crt_alphas()
    acc = jnp.zeros(residues.shape[:-1], dtype=jnp.int64)
    for k, alpha in enumerate(alphas):
        acc = (acc + _mulmod(residues[..., k], alpha, Q)) % Q
    # center
    return jnp.where(acc > Q // 2, acc - Q, acc)


def to_rns(params: HadesParams, coeffs: np.ndarray) -> np.ndarray:
    """Host helper: integer coefficient array [..., n] -> residues [..., K, n]."""
    coeffs = np.asarray(coeffs, dtype=object)
    out = np.stack([np.asarray(coeffs % q, dtype=np.int64)
                    for q in params.qs], axis=-2)
    return out


def const_poly(params: HadesParams, value: jax.Array) -> jax.Array:
    """Embed integer scalar(s) as the constant coefficient of an RNS poly.

    value: [...] int64 (may be negative) -> [..., K, n].
    """
    K, n = params.num_towers, params.n
    qs = jnp.asarray(np.asarray(params.qs, dtype=np.int64))  # [K]
    res = value[..., None] % qs                              # [..., K]
    zeros = jnp.zeros(value.shape + (K, n), dtype=jnp.int64)
    return zeros.at[..., 0].set(res)
