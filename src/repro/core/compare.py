"""Algorithms 2 & 4: HADES ciphertext comparison + database operations.

    ctΔ      = ct0 - ct1                      (component-wise, mod q)
    ct_eval  = ctΔ,0 * scale + ctΔ,1 ⊛ cek    (paper mode)
             = ctΔ,0 * scale + GadgetKeyMul(ctΔ,1)   (gadget mode)
    value    = CRT-centered coefficient 0 of ct_eval
    Alg. 2   -> -1 / 0 / +1   with |value| < τ  =>  0
    Alg. 4   -> strict bool (m_a > m_b); equality obfuscated by FAE noise

Everything is batched: ciphertext components carry arbitrary leading batch
dims, so a range query over 35k rows is ONE vectorized eval (paper §5.3's
O(n) comparison claim — here it is also a single XLA program).

Database ops built on the comparator:
  * range_query     — membership mask for lo <= m <= hi
  * encrypted_sort  — bitonic network (data-independent => jit/TPU friendly)
  * encrypted_topk  — bitonic top-k
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import gadget
from repro.core import ring as R
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet


# ---------------------------------------------------------------------------
# the Eval primitive
# ---------------------------------------------------------------------------

def ct_sub(rng: R.Ring, a: Ciphertext, b: Ciphertext) -> Ciphertext:
    return Ciphertext(R.sub(rng, a.c0, b.c0), R.sub(rng, a.c1, b.c1))


def eval_value(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext) -> jax.Array:
    """Centered integer eval value ≈ scale*Δ_enc*(m0-m1) + noise.  [...]."""
    params, rng = ks.params, ks.ring
    d = ct_sub(rng, ct0, ct1)                                  # Alg.2 line 2
    scaled = R.scalar_mul(rng, d.c0, params.scale)             # line 3a
    if params.mode == "paper":
        keyed = R.negacyclic_mul(rng, d.c1, ks.cek)            # line 3b
    else:
        keyed = gadget.gadget_keymul(ks, d.c1)
    ct_eval = R.add(rng, scaled, keyed)
    coeff0 = ct_eval[..., :, 0]                                # line 4 Decode
    return R.crt_centered(params, coeff0)


def compare(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext) -> jax.Array:
    """Algorithm 2: three-way comparison -1/0/+1 (τ-thresholded)."""
    v = eval_value(ks, ct0, ct1)
    tau = ks.params.tau                                        # line 5
    return jnp.where(jnp.abs(v) < tau, 0, jnp.sign(v)).astype(jnp.int32)


def compare_fae(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext) -> jax.Array:
    """Algorithm 4: strict bool m_a > m_b.  No equality outcome — on FAE
    ciphertexts of equal plaintexts the perturbation makes the answer an
    independent coin flip (tested property), which is exactly the paper's
    equality-obfuscation contract."""
    return eval_value(ks, ct0, ct1) > 0


def compare_many(ks: KeySet, cts_a: Ciphertext,
                 cts_b: Ciphertext) -> jax.Array:
    """Vectorized Alg. 2 over matching batch shapes."""
    return compare(ks, cts_a, cts_b)


# ---------------------------------------------------------------------------
# database operations
# ---------------------------------------------------------------------------

def _gather_ct(ct: Ciphertext, idx: jax.Array) -> Ciphertext:
    return Ciphertext(ct.c0[idx], ct.c1[idx])


def _broadcast_like(ct: Ciphertext, batch: int) -> Ciphertext:
    return Ciphertext(
        jnp.broadcast_to(ct.c0, (batch,) + ct.c0.shape[-2:]),
        jnp.broadcast_to(ct.c1, (batch,) + ct.c1.shape[-2:]))


def range_query(ks: KeySet, column: Ciphertext, ct_lo: Ciphertext,
                ct_hi: Ciphertext) -> jax.Array:
    """Mask of rows with lo <= m <= hi.  column: batched ct over N rows."""
    n_rows = column.c0.shape[0]
    lo = _broadcast_like(ct_lo, n_rows)
    hi = _broadcast_like(ct_hi, n_rows)
    ge_lo = compare(ks, column, lo) >= 0
    le_hi = compare(ks, column, hi) <= 0
    return ge_lo & le_hi


def _bitonic_pairs(n: int):
    """Yield (stage) index arrays for a bitonic sorting network over n=2^k."""
    import numpy as np
    k = n.bit_length() - 1
    for phase in range(1, k + 1):
        for sub in range(phase - 1, -1, -1):
            stride = 1 << sub
            i = np.arange(n)
            partner = i ^ stride
            first = i < partner
            # ascending iff bit `phase` of i is 0
            up = ((i >> phase) & 1) == 0
            lo = i[first]
            hi = partner[first]
            asc = up[first]
            yield (jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(asc))


def encrypted_sort(ks: KeySet, column: Ciphertext,
                   comparator: Callable | None = None,
                   ) -> Tuple[Ciphertext, jax.Array]:
    """Bitonic sort of a ciphertext column (ascending by plaintext).

    Returns (sorted ciphertexts, permutation).  The network is
    data-independent: each stage is ONE batched Eval over n/2 pairs —
    O(log^2 n) stages total, each embarrassingly parallel on the mesh.
    """
    cmp = comparator or compare_fae
    n_rows = column.c0.shape[0]
    assert n_rows & (n_rows - 1) == 0, "pad column to a power of two"
    perm = jnp.arange(n_rows)
    c0, c1 = column.c0, column.c1
    for lo, hi, asc in _bitonic_pairs(n_rows):
        a = Ciphertext(c0[lo], c1[lo])
        b = Ciphertext(c0[hi], c1[hi])
        a_gt_b = cmp(ks, a, b)
        swap = jnp.where(asc, a_gt_b, ~a_gt_b)              # [pairs]
        sw = swap[:, None, None]
        new_lo0 = jnp.where(sw, b.c0, a.c0)
        new_lo1 = jnp.where(sw, b.c1, a.c1)
        new_hi0 = jnp.where(sw, a.c0, b.c0)
        new_hi1 = jnp.where(sw, a.c1, b.c1)
        c0 = c0.at[lo].set(new_lo0).at[hi].set(new_hi0)
        c1 = c1.at[lo].set(new_lo1).at[hi].set(new_hi1)
        p_lo, p_hi = perm[lo], perm[hi]
        perm = perm.at[lo].set(jnp.where(swap, p_hi, p_lo))
        perm = perm.at[hi].set(jnp.where(swap, p_lo, p_hi))
    return Ciphertext(c0, c1), perm


def encrypted_topk(ks: KeySet, column: Ciphertext, k: int,
                   ) -> Tuple[Ciphertext, jax.Array]:
    """Top-k by plaintext value (descending): sort + slice.

    Used by the secure-serving example to pick the k best encrypted scores
    without the server learning the values.
    """
    sorted_ct, perm = encrypted_sort(ks, column)
    n_rows = column.c0.shape[0]
    sel = jnp.arange(n_rows - 1, n_rows - 1 - k, -1)
    return _gather_ct(sorted_ct, sel), perm[sel]
