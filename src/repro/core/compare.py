"""Algorithms 2 & 4: HADES ciphertext comparison + database operations.

    ctΔ      = ct0 - ct1                      (component-wise, mod q)
    ct_eval  = ctΔ,0 * scale + ctΔ,1 ⊛ cek    (paper mode)
             = ctΔ,0 * scale + GadgetKeyMul(ctΔ,1)   (gadget mode)
    value    = CRT-centered coefficient 0 of ct_eval
    Alg. 2   -> -1 / 0 / +1   with |value| < τ  =>  0
    Alg. 4   -> strict bool (m_a > m_b); equality obfuscated by FAE noise

Everything is batched: ciphertext components carry arbitrary leading batch
dims, so a range query over 35k rows is ONE vectorized eval (paper §5.3's
O(n) comparison claim — here it is also a single XLA program).

Database ops built on the comparator (the primitives under `repro.db`):
  * range_query     — membership mask for lo <= m <= hi (ONE fused eval)
  * encrypted_sort  — bitonic network (data-independent => jit/TPU friendly);
                      non-power-of-two columns are padded with encrypted
                      sentinel rows that are stripped from the output
  * encrypted_topk  — partial bitonic top-k network, O(n log^2 k) compares
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ckks as _CK
from repro.core import encrypt as _E
from repro.core import gadget
from repro.core import ring as R
from repro.core.encrypt import Ciphertext
from repro.core.keys import KeySet

# Fixed public-key randomness for server-side sentinel padding rows.  The
# pad rows carry no secret (their value is the public +/-max_operand bound),
# so a static key only fixes *which* valid encryption of the sentinel is
# appended — callers that care can pass their own `pad_key`.
_PAD_KEY_SEED = 0x4ADE5


# ---------------------------------------------------------------------------
# the Eval primitive
# ---------------------------------------------------------------------------

def ct_sub(rng: R.Ring, a: Ciphertext, b: Ciphertext) -> Ciphertext:
    return Ciphertext(R.sub(rng, a.c0, b.c0), R.sub(rng, a.c1, b.c1))


def eval_value(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext) -> jax.Array:
    """Centered integer eval value ≈ scale*Δ_enc*(m0-m1) + noise.  [...]."""
    params, rng = ks.params, ks.ring
    d = ct_sub(rng, ct0, ct1)                                  # Alg.2 line 2
    scaled = R.scalar_mul(rng, d.c0, params.scale)             # line 3a
    if params.mode == "paper":
        keyed = R.negacyclic_mul(rng, d.c1, ks.cek)            # line 3b
    else:
        keyed = gadget.gadget_keymul(ks, d.c1)
    ct_eval = R.add(rng, scaled, keyed)
    coeff0 = ct_eval[..., :, 0]                                # line 4 Decode
    return R.crt_centered(params, coeff0)


def resolve_tau(ks: KeySet, eps: Optional[float]) -> int:
    """The decode threshold an ε-tolerance request resolves to.

    eps=None keeps the profile's native τ (BFV: integer tie semantics;
    CKKS: `ckks.equality_tolerance` precision semantics).  An explicit ε
    (plaintext units) widens the equality band: values within ε compare
    as 0.  ε below the noise floor clamps up to the native τ.
    """
    if eps is None:
        return ks.params.tau
    return _CK.eps_to_tau(ks.params, eps)


def three_way(ks: KeySet, v: jax.Array, *,
              eps: Optional[float] = None) -> jax.Array:
    """Alg. 2 line 5: eval value -> -1/0/+1 (τ-thresholded).

    `eps` widens the equality band to |m0-m1| <= ε (plaintext units) —
    the ε-tolerant semantics CKKS float columns need (the static τ_ε is
    closed over by jit, so per-ε compiled compares cache like the
    default)."""
    tau = resolve_tau(ks, eps)
    return jnp.where(jnp.abs(v) < tau, 0, jnp.sign(v)).astype(jnp.int32)


def compare(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext, *,
            eps: Optional[float] = None) -> jax.Array:
    """Algorithm 2: three-way comparison -1/0/+1 (τ-thresholded; `eps`
    optionally widens the equality band, see `three_way`)."""
    return three_way(ks, eval_value(ks, ct0, ct1), eps=eps)


def compare_fae(ks: KeySet, ct0: Ciphertext, ct1: Ciphertext) -> jax.Array:
    """Algorithm 4: strict bool m_a > m_b.  No equality outcome — on FAE
    ciphertexts of equal plaintexts the perturbation makes the answer an
    independent coin flip (tested property), which is exactly the paper's
    equality-obfuscation contract."""
    return eval_value(ks, ct0, ct1) > 0


def compare_many(ks: KeySet, cts_a: Ciphertext, cts_b: Ciphertext, *,
                 eps: Optional[float] = None) -> jax.Array:
    """Vectorized Alg. 2 over matching batch shapes."""
    return compare(ks, cts_a, cts_b, eps=eps)


# ---------------------------------------------------------------------------
# database operations
# ---------------------------------------------------------------------------

def _gather_ct(ct: Ciphertext, idx: jax.Array) -> Ciphertext:
    return Ciphertext(ct.c0[idx], ct.c1[idx])


def range_query(ks: KeySet, column: Ciphertext, ct_lo: Ciphertext,
                ct_hi: Ciphertext, *,
                eps: Optional[float] = None) -> jax.Array:
    """Mask of rows with lo <= m <= hi.  column: batched ct over N rows.

    Both bound comparisons run in ONE batched `eval_value` call: the bounds
    are stacked into a [2, 1] batch that broadcasts against the column's
    [N] rows, halving kernel launches on the hot path versus the naive
    compare-vs-lo + compare-vs-hi pipeline.

    `eps` widens the boundary tolerance on float (CKKS) columns: rows
    within ε of a bound count as inside (ε-inclusive bounds).
    """
    bounds = Ciphertext(
        jnp.stack([ct_lo.c0, ct_hi.c0])[:, None],    # [2, 1, K, n]
        jnp.stack([ct_lo.c1, ct_hi.c1])[:, None])
    cmp = three_way(ks, eval_value(ks, column, bounds), eps=eps)   # [2, N]
    return (cmp[0] >= 0) & (cmp[1] <= 0)


def next_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1) (n >= 0).  THE pow2-padding
    geometry: table ingest, sort/top-k sentinel padding and the sharded
    merge networks all size their rows through this one helper, so their
    padded shapes can never drift apart.  n <= 1 returns 1 — the minimum
    block: an EMPTY column still pads to one slot, not two (naively,
    `(0 - 1).bit_length() == 1` would give 2), which is what lets empty
    tables and freshly-compacted delta runs share the ordinary geometry.
    A negative count is always a caller bug, never a geometry.
    """
    n = int(n)
    if n < 0:
        raise ValueError(f"row count must be >= 0, got {n}")
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _bitonic_pairs(n: int):
    """Yield (stage) index arrays for a bitonic sorting network over n=2^k."""
    import numpy as np
    k = n.bit_length() - 1
    for phase in range(1, k + 1):
        for sub in range(phase - 1, -1, -1):
            stride = 1 << sub
            i = np.arange(n)
            partner = i ^ stride
            first = i < partner
            # ascending iff bit `phase` of i is 0
            up = ((i >> phase) & 1) == 0
            lo = i[first]
            hi = partner[first]
            asc = up[first]
            yield (jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(asc))


def bitonic_compare_count(n: int) -> int:
    """Compare-exchanges the `encrypted_sort` network performs for an
    n-row column (after its padding to 2^ceil(log2 n)).  Kept next to
    `_bitonic_pairs` so stats/benchmark counts stay definitionally tied
    to the network actually run."""
    n_pad = next_pow2(n)
    stages = sum(range(1, n_pad.bit_length()))
    return stages * (n_pad // 2)


def _pad_to_pow2(ks: KeySet, column: Ciphertext, pad_value: int,
                 pad_key: Optional[jax.Array], *,
                 n_target: Optional[int] = None) -> Tuple[Ciphertext, int]:
    """Append encrypted `pad_value` sentinel rows up to the next power of
    two (or to an explicit power-of-two `n_target` — the sharded merge
    networks pad every shard's candidates to one common block size).
    Returns (padded column, original row count)."""
    n_rows = column.c0.shape[0]
    n_pad = n_target if n_target is not None else next_pow2(n_rows)
    assert n_pad >= n_rows and n_pad == next_pow2(n_pad)
    if n_pad == n_rows:
        return column, n_rows
    key = pad_key if pad_key is not None else jax.random.PRNGKey(_PAD_KEY_SEED)
    pad = _E.encrypt(ks, jnp.full((n_pad - n_rows,), pad_value, jnp.int64),
                     key)
    return Ciphertext(jnp.concatenate([column.c0, pad.c0]),
                      jnp.concatenate([column.c1, pad.c1])), n_rows


def _compare_swap(ks: KeySet, cmp: Callable, c0: jax.Array, c1: jax.Array,
                  perm: jax.Array, lo: jax.Array, hi: jax.Array,
                  asc: jax.Array):
    """One batched compare-exchange stage over index pairs (lo[i], hi[i]).

    asc[i] True  => the smaller plaintext lands at lo[i] (ascending pair);
    asc[i] False => the larger lands at lo[i].  ONE batched Eval per call.
    """
    a = Ciphertext(c0[lo], c1[lo])
    b = Ciphertext(c0[hi], c1[hi])
    a_gt_b = cmp(ks, a, b)                                  # [pairs] bool
    swap = jnp.where(asc, a_gt_b, ~a_gt_b)
    sw = swap[:, None, None]
    new_lo0 = jnp.where(sw, b.c0, a.c0)
    new_lo1 = jnp.where(sw, b.c1, a.c1)
    new_hi0 = jnp.where(sw, a.c0, b.c0)
    new_hi1 = jnp.where(sw, a.c1, b.c1)
    c0 = c0.at[lo].set(new_lo0).at[hi].set(new_hi0)
    c1 = c1.at[lo].set(new_lo1).at[hi].set(new_hi1)
    p_lo, p_hi = perm[lo], perm[hi]
    perm = perm.at[lo].set(jnp.where(swap, p_hi, p_lo))
    perm = perm.at[hi].set(jnp.where(swap, p_lo, p_hi))
    return c0, c1, perm


def encrypted_sort(ks: KeySet, column: Ciphertext,
                   comparator: Callable | None = None, *,
                   pad_value: Optional[int] = None,
                   pad_key: Optional[jax.Array] = None,
                   ) -> Tuple[Ciphertext, jax.Array]:
    """Bitonic sort of a ciphertext column (ascending by plaintext).

    Returns (sorted ciphertexts, permutation).  The network is
    data-independent: each stage is ONE batched Eval over n/2 pairs —
    O(log^2 n) stages total, each embarrassingly parallel on the mesh.

    Non-power-of-two columns are padded with encrypted `pad_value` sentinel
    rows (default +max_operand//2: the compare path needs |value - sentinel|
    <= max_operand, so the default assumes |values| <= max_operand/2 — the
    regime every profile's datasets live in); the sentinels are stripped
    from both returned arrays, so the output always has exactly the input's
    row count.  Stripping selects by permutation id, not position, so real
    rows that happen to *equal* the sentinel (FAE ties order coin-flip)
    are still returned.  Callers with values above max_operand/2 should
    pass their own in-headroom `pad_value`.
    """
    cmp = comparator or compare_fae
    if pad_value is None:
        pad_value = ks.params.max_operand // 2
    column, n_rows = _pad_to_pow2(ks, column, pad_value, pad_key)
    n_padded = column.c0.shape[0]
    perm = jnp.arange(n_padded)
    c0, c1 = column.c0, column.c1
    for lo, hi, asc in _bitonic_pairs(n_padded):
        c0, c1, perm = _compare_swap(ks, cmp, c0, c1, perm, lo, hi, asc)
    if n_padded == n_rows:
        return Ciphertext(c0, c1), perm
    # real rows are the ones whose permutation id predates the padding;
    # exactly n_rows of them exist, in sorted order
    keep = jnp.nonzero(perm < n_rows, size=n_rows)[0]
    return Ciphertext(c0[keep], c1[keep]), perm[keep]


def _block_pairs(n_blocks: int, block: int, lo, hi, asc):
    """Tile block-local pair indices across n_blocks contiguous blocks."""
    import numpy as np
    base = (np.arange(n_blocks) * block)[:, None]
    glo = (base + np.asarray(lo)[None, :]).ravel()
    ghi = (base + np.asarray(hi)[None, :]).ravel()
    gasc = np.tile(np.asarray(asc), n_blocks)
    return jnp.asarray(glo), jnp.asarray(ghi), jnp.asarray(gasc)


def encrypted_topk(ks: KeySet, column: Ciphertext, k: int,
                   comparator: Callable | None = None, *,
                   pad_value: Optional[int] = None,
                   pad_key: Optional[jax.Array] = None,
                   ) -> Tuple[Ciphertext, jax.Array]:
    """Top-k by plaintext value (descending) via a partial bitonic top-k
    network — O(n log^2 k) compares instead of the O(n log^2 n) full sort.

    Tournament reduction (the standard GPU bitonic top-k):
      1. sort each contiguous block of kp = 2^ceil(log2 k) rows descending;
      2. max-merge block pairs: position i of block A against position
         kp-1-i of block B keeps the larger at A — A then holds a bitonic
         sequence containing the top-kp of A∪B;
      3. bitonic-merge each surviving block back to sorted descending
         (log kp stages), halve the block count, repeat.

    Every stage is ONE batched Eval.  Non-power-of-two columns are padded
    with encrypted `pad_value` sentinels (default -max_operand//2, losing
    every tournament round while staying inside the |a-b| <= max_operand
    compare headroom for |values| <= max_operand/2) which never reach the
    result, since k <= n_rows real rows exist.  A real row that *equals*
    the sentinel can tie its way out of the tournament (FAE coin flip);
    that case is detected from the returned ids and resolved by falling
    back to the tie-robust sort-based path.

    Used by the secure-serving example to pick the k best encrypted scores
    without the server learning the values.
    """
    cmp = comparator or compare_fae
    orig = column
    n_rows = column.c0.shape[0]
    k = min(k, n_rows)
    kp = next_pow2(k)                               # power-of-two block
    if pad_value is None:
        pad_value = -(ks.params.max_operand // 2)
    column, n_rows = _pad_to_pow2(ks, column, pad_value, pad_key)
    n_padded = column.c0.shape[0]
    if kp >= n_padded:
        # degenerate: block covers everything — full sort is optimal
        return _topk_via_sort(ks, orig, k, cmp, pad_key)

    c0, c1 = column.c0, column.c1
    perm = jnp.arange(n_padded)
    # phase 1: sort every kp-block descending (flip the ascending flags of
    # the standard network); all blocks ride in the same batched stages
    for lo, hi, asc in _bitonic_pairs(kp):
        glo, ghi, gasc = _block_pairs(n_padded // kp, kp, lo, hi, ~asc)
        c0, c1, perm = _compare_swap(ks, cmp, c0, c1, perm, glo, ghi, gasc)
    # phase 2: tournament of max-merges
    n_live = n_padded
    while n_live > kp:
        blocks = n_live // kp
        j = jnp.arange(blocks // 2)
        i = jnp.arange(kp)
        lo_idx = ((2 * j * kp)[:, None] + i[None, :]).ravel()
        hi_idx = (((2 * j + 1) * kp)[:, None] + (kp - 1 - i)[None, :]).ravel()
        keep_larger = jnp.zeros(lo_idx.shape[0], bool)      # asc=False
        c0, c1, perm = _compare_swap(ks, cmp, c0, c1, perm,
                                     lo_idx, hi_idx, keep_larger)
        # compact surviving (even) blocks to the front
        c0, c1, perm = c0[lo_idx], c1[lo_idx], perm[lo_idx]
        n_live //= 2
        # re-sort each bitonic survivor block descending: log kp merge stages
        stride = kp // 2
        while stride >= 1:
            within = jnp.arange(kp)
            p = within[(within & stride) == 0]               # [kp/2]
            glo, ghi, gasc = _block_pairs(
                n_live // kp, kp, p, p + stride,
                jnp.zeros(p.shape[0], bool))
            c0, c1, perm = _compare_swap(ks, cmp, c0, c1, perm,
                                         glo, ghi, gasc)
            stride //= 2
    top_idx = perm[:k]
    if bool(jnp.any(top_idx >= n_rows)):
        # a real row equal to the sentinel lost a coin-flip tie and a pad
        # row took its slot — rare; the sort path strips by id, not value
        return _topk_via_sort(ks, orig, k, cmp, pad_key)
    return Ciphertext(c0[:k], c1[:k]), top_idx


def _topk_via_sort(ks: KeySet, column: Ciphertext, k: int, cmp: Callable,
                   pad_key: Optional[jax.Array],
                   ) -> Tuple[Ciphertext, jax.Array]:
    """Tie-robust top-k: full ascending sort (id-based sentinel stripping)
    then take the k largest, descending."""
    sorted_ct, perm = encrypted_sort(ks, column, cmp, pad_key=pad_key)
    n = column.c0.shape[0]
    sel = jnp.arange(n - 1, n - 1 - k, -1)
    return _gather_ct(sorted_ct, sel), perm[sel]
