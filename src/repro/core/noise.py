"""Noise-budget accounting: the §4.4 correctness predicates, checkable.

These are *predictions* (worst-case and 6-sigma estimates) used by tests and
by EXPERIMENTS.md's noise ablation; `encrypt.noise_magnitude` measures the
real thing.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.params import HadesParams


@dataclasses.dataclass(frozen=True)
class NoiseBudget:
    fresh_worst: float          # worst-case |phase - Δ_enc m| after encrypt
    fresh_sigma: float          # ~std of the same
    eval_worst: float           # worst-case |eval noise| (compare path)
    eval_sigma: float
    tau: int                    # decode threshold
    headroom_bits: float        # log2( (scale*Δ_enc/2) / 6*eval_sigma )


def predict(params: HadesParams) -> NoiseBudget:
    n, B = params.n, params.noise_bound
    # fresh encryption noise coeff0: e0 + e1*sk + u*e_pk (+ e_m for FAE)
    # each cross term is a sum of n products (bounded B) * ternary(2/3 mass)
    var_term = n * (2.0 / 3.0) * (B * (B + 1) / 3.0)   # var of e*ternary sum
    fresh_var = (B * (B + 1) / 3.0) + 2 * var_term
    fresh_sigma = math.sqrt(fresh_var)
    fresh_worst = B + 2 * n * B

    scale = params.scale
    if params.mode == "paper":
        # <e_cek, ctΔ,1>: ctΔ,1 uniform mod q — worst/typ are both ~q/2·n·B;
        # report the honest (catastrophic) figure (DESIGN.md §1.1).
        q_half = max(params.qs) / 2
        ks_sigma = math.sqrt(n * (2.0 / 3.0)) * q_half * math.sqrt(B * (B + 1) / 3.0)
        ks_worst = n * q_half * B
    else:
        K = params.num_towers
        D = params.gadget_digits_per_tower
        Bg = params.gadget_base
        # K*D inner products of digit(<Bg) x noise(B) over n coeffs
        ks_var = K * D * n * ((Bg ** 2) / 12.0) * (B * (B + 1) / 3.0)
        ks_sigma = math.sqrt(ks_var)
        ks_worst = K * D * n * Bg * B

    eval_sigma = math.sqrt((scale * fresh_sigma * math.sqrt(2)) ** 2
                           + ks_sigma ** 2)
    eval_worst = scale * 2 * fresh_worst + ks_worst
    tau = params.tau
    headroom = math.log2(max(tau / (6 * eval_sigma), 1e-30))
    return NoiseBudget(fresh_worst=fresh_worst, fresh_sigma=fresh_sigma,
                       eval_worst=eval_worst, eval_sigma=eval_sigma,
                       tau=tau, headroom_bits=headroom)


def compare_is_sound(params: HadesParams, sigmas: float = 6.0) -> bool:
    """True if the compare path separates 0 from ±1 at `sigmas` confidence."""
    b = predict(params)
    return b.tau > sigmas * b.eval_sigma
