"""HADES core: RLWE-based homomorphic symbol comparison (the paper's contribution).

Everything in this package operates on int64 coefficient arrays in RNS
(residue-number-system) representation; 64-bit mode is required for exact
modular arithmetic on CPU/TPU-interpret backends.
"""
import jax

# Exact mod-q arithmetic needs 64-bit integers. Model code pins its own
# dtypes explicitly, so enabling x64 here is safe for the whole package.
jax.config.update("jax_enable_x64", True)

# NOTE: functions named like their submodule (encrypt.encrypt,
# compare.compare) are deliberately NOT re-exported — rebinding them here
# would shadow the submodules for `import repro.core.encrypt` users.
from repro.core.params import HadesParams, Profile, make_params  # noqa: E402,F401
from repro.core.keys import KeySet, keygen  # noqa: E402,F401
from repro.core.encrypt import (  # noqa: E402,F401
    Ciphertext,
    encrypt_fae,
    decrypt,
    decrypt_raw,
)
from repro.core.compare import (  # noqa: E402,F401
    compare_many,
    compare_fae,
    range_query,
    encrypted_sort,
    encrypted_topk,
)
