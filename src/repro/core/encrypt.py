"""Encryption / decryption (LPR public-key RLWE) + Algorithm 3 (FAE).

Encoding (DESIGN.md §1.2): operands live in the constant coefficient,
payload = Δ_enc * m (BFV: m integer, |m| < t; CKKS: m real, payload =
round(m * Δ_enc)).  The HADES compare path later multiplies the phase by
`scale`, so Δ_enc deliberately leaves headroom: scale*Δ_enc*|m0-m1| < Q/2.

Algorithm 3 (perturbation-aware / FAE) adds Δ_m ~ U(-ε, ε) in plaintext
units plus an extra bounded noise e_m before encrypting, so equal
plaintexts yield statistically independent ciphertexts AND independent
compare outcomes (the equality-obfuscation property tested in
tests/test_fae.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import ring as R
from repro.core import sampling
from repro.core.keys import KeySet
from repro.core.params import HadesParams


class Ciphertext(NamedTuple):
    """RLWE ciphertext (c0, c1), each [..., K, n].  2 components, no
    expansion for comparability (paper §3.4)."""
    c0: jax.Array
    c1: jax.Array

    def __sub__(self, other: "Ciphertext") -> "Ciphertext":
        raise TypeError("use compare.ct_sub(ring, a, b) — needs the modulus")


def _payload(params: HadesParams, m: jax.Array) -> jax.Array:
    """Scaled plaintext payload (integer, possibly negative). m: [...]."""
    if params.profile.scheme == "bfv":
        m_int = m.astype(jnp.int64)
        return m_int * params.delta_enc
    # ckks: fixed-point encode
    return jnp.round(m.astype(jnp.float64) * params.delta_enc).astype(jnp.int64)


def _encrypt_payload(ks: KeySet, payload: jax.Array,
                     key: jax.Array) -> Ciphertext:
    """payload: [...] integer -> ct with batch shape [...]."""
    params, rng = ks.params, ks.ring
    batch = payload.shape
    k_u, k_e0, k_e1 = jax.random.split(key, 3)
    u = sampling.ternary_poly(params, k_u, batch)      # [..., K, n]
    e0 = sampling.noise_poly(params, k_e0, batch)
    e1 = sampling.noise_poly(params, k_e1, batch)
    m_poly = R.const_poly(params, payload)             # [..., K, n]
    c0 = R.add(rng, R.add(rng, R.negacyclic_mul(rng, ks.pk0, u), e0), m_poly)
    c1 = R.add(rng, R.negacyclic_mul(rng, ks.pk1, u), e1)
    return Ciphertext(c0=c0, c1=c1)


def encrypt(ks: KeySet, m: jax.Array, key: jax.Array) -> Ciphertext:
    """Basic encryption (EncBasic). m: scalar or batch of operands."""
    m = jnp.asarray(m)
    return _encrypt_payload(ks, _payload(ks.params, m), key)


def encrypt_fae(ks: KeySet, m: jax.Array, key: jax.Array) -> Ciphertext:
    """Algorithm 3: perturbation-aware encryption (EncFAE).

    line 2: m_scaled = m * Δ_enc
    line 3: Δ_m ~ U(-ε, ε)
    line 4: m_perturbed = m_scaled + Δ_m * Δ_enc
    line 5/6: + e_m  (extra bounded noise on the payload)
    line 7: Encrypt(pk, ·)
    """
    params = ks.params
    m = jnp.asarray(m)
    k_pert, k_em, k_enc = jax.random.split(key, 3)
    base = _payload(params, m)
    pert = jax.random.uniform(
        k_pert, m.shape, dtype=jnp.float64,
        minval=-params.epsilon, maxval=params.epsilon)
    pert_int = jnp.round(pert * params.delta_enc).astype(jnp.int64)
    e_m = jax.random.randint(k_em, m.shape, -params.noise_bound,
                             params.noise_bound + 1, dtype=jnp.int64)
    return _encrypt_payload(ks, base + pert_int + e_m, k_enc)


def decrypt_raw(ks: KeySet, ct: Ciphertext) -> jax.Array:
    """Centered phase of coefficient 0: Δ_enc*m + noise.  [...] int64."""
    rng = ks.ring
    phase = R.add(rng, ct.c0, R.negacyclic_mul(rng, ct.c1, ks.sk))
    coeff0 = phase[..., :, 0]                       # [..., K]
    return R.crt_centered(ks.params, coeff0)


def decrypt(ks: KeySet, ct: Ciphertext) -> jax.Array:
    """Recover m (exact for BFV given |noise| < Δ_enc/2; approx for CKKS)."""
    v = decrypt_raw(ks, ct)
    params = ks.params
    if params.profile.scheme == "bfv":
        half = params.delta_enc // 2
        return (v + half) // params.delta_enc
    return v.astype(jnp.float64) / params.delta_enc


def noise_magnitude(ks: KeySet, ct: Ciphertext, m: jax.Array) -> jax.Array:
    """|phase - Δ_enc*m|: the live noise budget of a ciphertext (noise.py
    uses this for the §4.4 correctness predicates)."""
    v = decrypt_raw(ks, ct)
    return jnp.abs(v - _payload(ks.params, jnp.asarray(m)))
