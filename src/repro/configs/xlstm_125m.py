"""xlstm-125m [ssm]: 12L d=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks
(1:1 alternating; the blocks carry their own up/down projections, hence
d_ff=0).  RUNS long_500k: decode state is a constant-size matrix memory.
[arXiv:2405.04517; unverified]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="xlstm-smoke", num_layers=2, d_model=32,
        num_heads=4, num_kv_heads=4, vocab_size=256,
        param_dtype="float32", dtype="float32", attn_chunk=8)
