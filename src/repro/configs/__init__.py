"""Assigned-architecture registry: `--arch <id>` resolves here.

Each module defines CONFIG (the exact assigned numbers) and reduced()
(a small same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "llava_next_34b",
    "minitron_8b",
    "smollm_360m",
    "minicpm3_4b",
    "internlm2_20b",
    "recurrentgemma_9b",
    "xlstm_125m",
    "deepseek_moe_16b",
    "qwen3_moe_30b_a3b",
    "whisper_base",
)

# CLI ids use dashes; module names use underscores.
def canon(arch: str) -> str:
    return arch.replace("-", "_")


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.CONFIG


def get_reduced(arch: str):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.reduced()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
