"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) d_ff=768(per expert)
vocab=151936, 128 routed experts top-8, no shared experts.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936,
    head_dim=128,
    num_experts=128, num_shared_experts=0, experts_per_token=8,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen3-moe-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=512,
        head_dim=16, num_experts=8, num_shared_experts=0,
        # no-drop capacity so decode == forward exactly in smoke tests
        experts_per_token=2, capacity_factor=8.0,
        param_dtype="float32", dtype="float32", attn_chunk=16)
