"""minitron-8b [dense]: 32L d=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Width/depth-pruned Nemotron-4. The 256k vocab makes the unembed matmul and
embedding table the sharding-sensitive pieces (vocab on `model` axis).
[arXiv:2407.14679; hf]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="minitron-smoke", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=512,
        param_dtype="float32", dtype="float32", attn_chunk=16)
