"""recurrentgemma-9b [hybrid]: 38L... pattern note below. d=4096 16H (MQA
kv=1) d_ff=12288 vocab=256000. RG-LRU + local attention, 1:2 ratio.

The assigned 38 layers do not divide by the 3-layer (rglru, rglru, local)
Griffin pattern; we follow the paper's pattern exactly and round the depth
to 39 layers (13 groups) — noted in DESIGN.md §7.  Window = 2048 (paper).

This arch RUNS long_500k: decode state is O(window + lru_width), not O(S).
[arXiv:2402.19427; unverified]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=39, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"), window=2048,
    lru_width=4096,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-smoke", num_layers=3, d_model=64,
        num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=512,
        window=16, lru_width=64, param_dtype="float32", dtype="float32",
        attn_chunk=16)
