"""minicpm3-4b [dense]: 62L d=2560 40H (GQA kv=40) d_ff=6400 vocab=73448.

Multi-head Latent Attention (MLA): KV is cached as a rank-256 latent + a
shared 32-dim rope key, shrinking decode cache ~20x vs full MHA.
[hf:openbmb/MiniCPM3-4B; hf]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    attention="mla", head_dim=64,
    q_lora_rank=768, kv_lora_rank=256, qk_rope_head_dim=32,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="minicpm3-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        head_dim=16, q_lora_rank=32, kv_lora_rank=16, qk_rope_head_dim=8,
        param_dtype="float32", dtype="float32", attn_chunk=16)
