"""deepseek-moe-16b [moe]: 28L d=2048 16H d_ff=1408(per expert)
vocab=102400, 2 shared + 64 routed experts top-6 (fine-grained).
[arXiv:2401.06066; hf]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    num_experts=64, num_shared_experts=2, experts_per_token=6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-moe-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=32, vocab_size=512,
        num_experts=8, num_shared_experts=1, experts_per_token=2,
        # no-drop capacity so decode == forward exactly in smoke tests
        capacity_factor=8.0,
        param_dtype="float32", dtype="float32", attn_chunk=16)
