"""llava-next-34b [vlm]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Transformer backbone only; the anyres vision tower is a STUB — input specs
provide precomputed patch embeddings [B, 576, d] that replace the first 576
token positions (multimodal fusion stub, DESIGN.md §4).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    frontend="patches", num_patches=576,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llava-next-smoke", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=256,
        num_patches=4, param_dtype="float32", dtype="float32",
        attn_chunk=16)
