"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H d_ff=2048 vocab=51865.

Encoder-decoder; the conv frontend is a STUB — input specs provide
precomputed frame embeddings [B, 1500, d] (post-conv mel frames).
Decode shapes exercise self- + cross-attention KV caches.
[arXiv:2212.04356; unverified]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    is_encoder_decoder=True, encoder_layers=6, encoder_seq=1500,
    frontend="frames",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
        encoder_layers=2, encoder_seq=16, param_dtype="float32",
        dtype="float32", attn_chunk=16)
