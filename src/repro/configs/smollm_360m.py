"""smollm-360m [dense]: 32L d=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

Llama-architecture small model; also the backbone of the end-to-end train
example (examples/train_lm.py uses reduced()).
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="smollm-smoke", num_layers=2, d_model=60,
        num_heads=3, num_kv_heads=1, d_ff=160, vocab_size=512,
        param_dtype="float32", dtype="float32", attn_chunk=16)


def train_100m() -> ModelConfig:
    """~100M-param variant for the end-to-end training driver."""
    return dataclasses.replace(
        CONFIG, name="smollm-100m", num_layers=12, d_model=640,
        num_heads=10, num_kv_heads=5, d_ff=1706, vocab_size=32000,
        param_dtype="float32", dtype="float32")
