"""internlm2-20b [dense]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
[arXiv:2403.17297; hf]
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="internlm2-smoke", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=512,
        param_dtype="float32", dtype="float32", attn_chunk=16)
