"""The paper's comparison baselines, implemented: HOPE (Paillier-based,
stateless) and POPE (client-interactive partial order)."""
