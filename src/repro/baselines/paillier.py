"""Paillier cryptosystem (additively homomorphic) — substrate for the HOPE
baseline [31]/[24].  Python big-int arithmetic; this is a BASELINE the paper
compares against, not the contribution, so CPU bignum is the honest match
to the original (HOPE's artifact is CPU Paillier too).
"""
from __future__ import annotations

import dataclasses
import math
import secrets


def _is_probable_prime(n: int, rounds: int = 20) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for _ in range(rounds):
        a = secrets.randbelow(n - 3) + 2
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def _random_prime(bits: int) -> int:
    while True:
        c = secrets.randbits(bits) | (1 << (bits - 1)) | 1
        if _is_probable_prime(c):
            return c


@dataclasses.dataclass
class PaillierPublicKey:
    n: int
    n_sq: int
    g: int


@dataclasses.dataclass
class PaillierPrivateKey:
    lam: int
    mu: int
    pub: PaillierPublicKey


def keygen(bits: int = 1024):
    p = _random_prime(bits // 2)
    q = _random_prime(bits // 2)
    while q == p:
        q = _random_prime(bits // 2)
    n = p * q
    n_sq = n * n
    g = n + 1
    lam = (p - 1) * (q - 1) // math.gcd(p - 1, q - 1)
    # mu = (L(g^lam mod n^2))^-1 mod n,  L(x) = (x-1)/n
    x = pow(g, lam, n_sq)
    L = (x - 1) // n
    mu = pow(L, -1, n)
    pub = PaillierPublicKey(n=n, n_sq=n_sq, g=g)
    return pub, PaillierPrivateKey(lam=lam, mu=mu, pub=pub)


def encrypt(pub: PaillierPublicKey, m: int) -> int:
    m %= pub.n
    r = secrets.randbelow(pub.n - 1) + 1
    return (pow(pub.g, m, pub.n_sq) * pow(r, pub.n, pub.n_sq)) % pub.n_sq


def decrypt(priv: PaillierPrivateKey, ct: int) -> int:
    pub = priv.pub
    x = pow(ct, priv.lam, pub.n_sq)
    L = (x - 1) // pub.n
    return (L * priv.mu) % pub.n


def add(pub: PaillierPublicKey, ct_a: int, ct_b: int) -> int:
    return (ct_a * ct_b) % pub.n_sq


def cmul(pub: PaillierPublicKey, ct: int, k: int) -> int:
    return pow(ct, k % pub.n, pub.n_sq)
