"""HOPE baseline [31]: Homomorphic OPE, stateless, via Paillier.

Comparison by randomized difference: to compare Enc(a) vs Enc(b), the
evaluator computes Enc(r*(a-b)) with a fresh r > 0 (homomorphic subtract +
scalar multiply) and a decryption oracle reveals only the SIGN of the
blinded difference.  Stateless (no client storage, no interaction during
the compare itself) — the properties Table 1 credits HOPE with.  Supports
addition, integers only (the limitation HADES lifts).
"""
from __future__ import annotations

import dataclasses
import secrets
from typing import Tuple

from repro.baselines import paillier as P


@dataclasses.dataclass
class HopeContext:
    pub: P.PaillierPublicKey
    priv: P.PaillierPrivateKey
    r_bits: int = 40


def keygen(bits: int = 1024) -> HopeContext:
    pub, priv = P.keygen(bits)
    return HopeContext(pub=pub, priv=priv)


def encrypt(ctx: HopeContext, m: int) -> int:
    return P.encrypt(ctx.pub, m)


def add(ctx: HopeContext, a: int, b: int) -> int:
    return P.add(ctx.pub, a, b)


def compare(ctx: HopeContext, ct_a: int, ct_b: int) -> int:
    """-1 / 0 / +1 on plaintexts, revealing only the blinded sign."""
    pub = ctx.pub
    # Enc(a - b) = Enc(a) * Enc(b)^-1
    neg_b = P.cmul(pub, ct_b, pub.n - 1)
    diff = P.add(pub, ct_a, neg_b)
    r = secrets.randbits(ctx.r_bits) | 1
    blinded = P.cmul(pub, diff, r)
    v = P.decrypt(ctx.priv, blinded)
    if v == 0:
        return 0
    # centered representative
    return 1 if v < pub.n // 2 else -1
