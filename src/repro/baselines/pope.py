"""POPE baseline [27]: Partial Order Preserving Encoding.

POPE keeps ciphertexts unordered until queries force comparisons; the
server maintains a buffered POPE-tree and asks the CLIENT to sort/compare
small sets during queries.  The defining cost (paper §6.5: 385 ms vs
HADES 6.5 ms) is the client round-trips — we implement the protocol with
an explicit transport so network latency is a measured, configurable part
of every comparison, exactly as the paper attributes.

Crypto: client-side values are encrypted with a semantically-secure
scheme; the client decrypts privately when asked to compare (POPE's
actual design — the server never learns plaintexts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

from repro.baselines import paillier as P


@dataclasses.dataclass
class Transport:
    """Simulated client<->server link; latency applied per round trip."""
    latency_s: float = 0.001
    rounds: int = 0

    def round_trip(self):
        self.rounds += 1
        if self.latency_s:
            time.sleep(self.latency_s)


class PopeClient:
    """Holds the key; answers comparison oracles (decrypt + compare)."""

    def __init__(self, bits: int = 512):
        self.pub, self.priv = P.keygen(bits)

    def encrypt(self, m: int) -> int:
        return P.encrypt(self.pub, m)

    def compare_oracle(self, ct_a: int, ct_b: int) -> int:
        a = P.decrypt(self.priv, ct_a)
        b = P.decrypt(self.priv, ct_b)
        return (a > b) - (a < b)


class PopeServer:
    """Buffered POPE tree, degenerate-cased to a sorted list + buffer.

    Inserts are O(1) (append to buffer — POPE's cheap-ingest property).
    Queries flush the buffer by asking the client to place each buffered
    ciphertext (binary search => O(log n) round trips per element).
    """

    def __init__(self, client: PopeClient, transport: Transport):
        self.client = client
        self.t = transport
        self.sorted: List[int] = []
        self.buffer: List[int] = []

    def insert(self, ct: int) -> None:
        self.buffer.append(ct)

    def _place(self, ct: int, left: bool = False) -> int:
        """Binary-search insertion point; left=True -> before equal keys
        (inclusive lower bound for range queries)."""
        lo, hi = 0, len(self.sorted)
        while lo < hi:
            mid = (lo + hi) // 2
            self.t.round_trip()                      # ask client to compare
            c = self.client.compare_oracle(ct, self.sorted[mid])
            if c < 0 or (left and c == 0):
                hi = mid
            else:
                lo = mid + 1
        return lo

    def _flush(self) -> None:
        for ct in self.buffer:
            self.sorted.insert(self._place(ct), ct)
        self.buffer = []

    def compare(self, ct_a: int, ct_b: int) -> int:
        """One comparison costs a client round trip (plus any flush)."""
        self._flush()
        self.t.round_trip()
        return self.client.compare_oracle(ct_a, ct_b)

    def range_query(self, ct_lo: int, ct_hi: int) -> List[int]:
        """Inclusive [lo, hi] range."""
        self._flush()
        lo = self._place(ct_lo, left=True)
        hi = self._place(ct_hi, left=False)
        return self.sorted[lo:hi]
