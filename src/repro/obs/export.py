"""Exporters: Chrome-trace JSON, flat metrics dumps, BENCH fields.

Two consumers: humans (load `write_chrome_trace` output into
chrome://tracing or ui.perfetto.dev; print `tree_lines`), and the
benchmark harness (`bench_fields()` rides each BENCH pass's `derived`
dict so BENCH_db.json carries launch/lane/retrace counts across PRs).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs import jitwatch, metrics
from repro.obs.trace import TRACER, Tracer

_REQUIRED_EVENT_KEYS = ("ph", "ts", "pid")


def chrome_trace(tracer: Optional[Tracer] = None) -> Dict[str, Any]:
    """The Chrome-trace JSON object for `tracer` (default: global)."""
    return (tracer or TRACER).chrome_trace()


def write_chrome_trace(path, tracer: Optional[Tracer] = None) -> None:
    """Write the Chrome-trace JSON for `tracer` to `path`."""
    (tracer or TRACER).write_chrome_trace(path)


def validate_chrome_trace(doc: Any) -> List[str]:
    """Validate a Chrome-trace object (or JSON string): `traceEvents`
    must be a list and every event must carry `ph`/`ts`/`pid` (plus
    `name`/`tid`/`dur` for complete events).  Returns a list of error
    strings — empty means valid."""
    errors: List[str] = []
    if isinstance(doc, str):
        try:
            doc = json.loads(doc)
        except json.JSONDecodeError as e:
            return [f"not JSON: {e}"]
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        return ["missing traceEvents list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for k in _REQUIRED_EVENT_KEYS:
            if k not in ev:
                errors.append(f"event {i}: missing '{k}'")
        if ev.get("ph") == "X":
            for k in ("name", "tid", "dur"):
                if k not in ev:
                    errors.append(f"event {i}: complete event missing '{k}'")
    return errors


def metrics_dump(registry: Optional[metrics.Registry] = None
                 ) -> Dict[str, Any]:
    """Flat JSON-safe metrics snapshot, plus the jit signature sets."""
    reg = registry or metrics.REGISTRY
    return {"metrics": reg.snapshot(),
            "jit_signatures": jitwatch.signatures()}


def write_metrics(path, registry: Optional[metrics.Registry] = None) -> None:
    """Serialize `metrics_dump()` to `path`."""
    with open(path, "w") as fh:
        json.dump(metrics_dump(registry), fh, indent=1, sort_keys=True)


def bench_fields(registry: Optional[metrics.Registry] = None
                 ) -> Dict[str, int]:
    """The launch-accounting triple every BENCH pass carries:
    eval_launches / compare_lanes / jit_retraces."""
    reg = registry or metrics.REGISTRY
    return {
        "eval_launches": reg.value("eval.launches"),
        "compare_lanes": reg.value("eval.lanes"),
        "jit_retraces": reg.value("jit.retraces"),
    }
