"""Span tracing for the encrypted query engine.

One global trace buffer, contextvar-nested spans, device-true timing.
The design constraint is the disabled path: `obs.span(...)` must cost
one global-bool check and return a shared no-op object, so
instrumentation can live inside the executor hot path permanently.

Usage::

    with obs.tracing() as tr:
        server.run()
    tr.write_chrome_trace("trace.json")   # chrome://tracing / Perfetto

Spans nest through a `contextvars.ContextVar`, so server batches,
shard_map launches, index probes and compactions all attach to the
span that was live when they started — including across threads
spawned with a copied context.

Device-true timing: jax dispatch is async, so a naive
`perf_counter()` pair around a launch measures dispatch, not compute.
`Span.sync(value)` calls `jax.block_until_ready` on the value *inside*
the span when tracing is enabled, and is the identity function when
disabled — enabling a trace tightens timing attribution without
changing what the engine computes.
"""
from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

_enabled: bool = os.environ.get("REPRO_OBS", "") not in ("", "0")

_current: contextvars.ContextVar[Optional["Span"]] = contextvars.ContextVar(
    "repro_obs_current_span", default=None)


def enable() -> None:
    """Turn span recording + metrics collection on (module-global)."""
    global _enabled
    _enabled = True


def disable() -> None:
    """Turn span recording + metrics collection off (module-global)."""
    global _enabled
    _enabled = False


def is_enabled() -> bool:
    """Whether the observability layer is currently recording."""
    return _enabled


class Span:
    """One timed, attributed region.  Created by `span()`; use as a
    context manager.  Finished spans land in the global `Tracer`."""

    __slots__ = ("name", "args", "t0", "t1", "sid", "parent_sid",
                 "depth", "tid", "_token")

    def __init__(self, name: str, args: Dict[str, Any]):
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.t1 = 0.0
        self.sid = -1
        self.parent_sid = -1
        self.depth = 0
        self.tid = 0
        self._token = None

    def __enter__(self) -> "Span":
        parent = _current.get()
        tr = TRACER
        with tr._lock:
            self.sid = tr._next_sid
            tr._next_sid += 1
        self.parent_sid = parent.sid if parent is not None else -1
        self.depth = parent.depth + 1 if parent is not None else 0
        self.tid = threading.get_ident()
        self._token = _current.set(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = time.perf_counter()
        if self._token is not None:
            _current.reset(self._token)
        TRACER._finish(self)

    def set(self, **kw) -> "Span":
        """Attach attributes to the span (shown in the trace `args`)."""
        self.args.update(kw)
        return self

    def sync(self, value):
        """Block until `value` (a jax array / pytree) is device-ready,
        so the span's duration includes the device work it launched.
        Returns `value` unchanged."""
        import jax
        jax.block_until_ready(value)
        return value

    @property
    def dur_s(self) -> float:
        """Span duration in seconds (0 until the span closes)."""
        return max(0.0, self.t1 - self.t0)


class _NullSpan:
    """Shared do-nothing span handed out when tracing is disabled.
    `sync` is the identity — no forced device sync on the fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def set(self, **kw) -> "_NullSpan":
        """No-op attribute setter (disabled-path stand-in)."""
        return self

    def sync(self, value):
        """Identity: no device sync when tracing is off."""
        return value


_NULL_SPAN = _NullSpan()


def span(name: str, **args):
    """Open a span named `name` with attributes `args`.  Returns the
    shared no-op span when tracing is disabled (near-zero cost)."""
    if not _enabled:
        return _NULL_SPAN
    return Span(name, args)


def current_span():
    """The innermost live span in this context, or None."""
    return _current.get()


class Tracer:
    """Global buffer of finished spans.  Thread-safe appends; spans
    keep their id / parent-id so the tree is reconstructible."""

    def __init__(self):
        self._lock = threading.Lock()
        self._next_sid = 0
        self.spans: List[Span] = []
        self._epoch = time.perf_counter()

    def _finish(self, sp: Span) -> None:
        with self._lock:
            self.spans.append(sp)

    def clear(self) -> None:
        """Drop all recorded spans and restart the trace clock."""
        with self._lock:
            self.spans = []
            self._next_sid = 0
            self._epoch = time.perf_counter()

    # -- views -------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace/Perfetto JSON object: one `ph: "X"` complete
        event per span (load in chrome://tracing or ui.perfetto.dev)."""
        pid = os.getpid()
        events = []
        with self._lock:
            spans = list(self.spans)
        for sp in sorted(spans, key=lambda s: s.t0):
            events.append({
                "name": sp.name,
                "ph": "X",
                "ts": (sp.t0 - self._epoch) * 1e6,
                "dur": max(0.0, sp.t1 - sp.t0) * 1e6,
                "pid": pid,
                "tid": sp.tid,
                "args": {k: _jsonable(v) for k, v in sp.args.items()},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path) -> None:
        """Serialize `chrome_trace()` to `path`."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh, indent=1)

    def roots(self) -> List[Span]:
        """Spans whose parent finished outside this trace (tree roots)."""
        sids = {sp.sid for sp in self.spans}
        return [sp for sp in self.spans if sp.parent_sid not in sids]

    def children(self, sp: Span) -> List[Span]:
        """Direct child spans of `sp`, in start order."""
        kids = [s for s in self.spans if s.parent_sid == sp.sid]
        return sorted(kids, key=lambda s: s.t0)

    def tree_lines(self) -> List[str]:
        """The span tree as indented text lines (for terminals/tests)."""
        lines: List[str] = []

        def walk(sp: Span, depth: int) -> None:
            attrs = " ".join(f"{k}={_jsonable(v)}" for k, v in sp.args.items())
            ms = (sp.t1 - sp.t0) * 1e3
            lines.append(f"{'  ' * depth}{sp.name}  {ms:.2f}ms"
                         + (f"  [{attrs}]" if attrs else ""))
            for kid in self.children(sp):
                walk(kid, depth + 1)

        for root in sorted(self.roots(), key=lambda s: s.t0):
            walk(root, 0)
        return lines


def _jsonable(v):
    """Coerce span-attribute values to JSON-safe scalars."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:
        import numpy as np
        if isinstance(v, (np.integer,)):
            return int(v)
        if isinstance(v, (np.floating,)):
            return float(v)
    except Exception:
        pass
    return str(v)


TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-global `Tracer` buffer."""
    return TRACER


class tracing:
    """Context manager: enable tracing (and metrics) for a region,
    restore the previous state on exit, yield the global tracer.

    `fresh=True` (default) clears previously-recorded spans and resets
    the metrics registry so the trace covers exactly this region."""

    def __init__(self, fresh: bool = True):
        self.fresh = fresh
        self._was_enabled = False

    def __enter__(self) -> Tracer:
        self._was_enabled = is_enabled()
        if self.fresh:
            TRACER.clear()
            from repro.obs import jitwatch, metrics
            metrics.REGISTRY.reset()
            jitwatch.reset()
        enable()
        return TRACER

    def __exit__(self, *exc) -> None:
        if not self._was_enabled:
            disable()
