"""Jit-cache observer: launch signatures per call site.

jax.jit re-specializes (retraces + recompiles) for every distinct
`(shape, dtype)` signature entering a jitted function.  The engine's
"jit cache stays hot" invariant says the pow2 padding discipline keeps
the signature set per site at ~1 — a violation shows up as a silent
10x latency cliff.  This module makes it loud: every instrumented
launch records its signature, and any signature beyond the first at a
site increments the `jit.retraces` counter.

Gated on `obs.is_enabled()` like everything else in the layer.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Set, Tuple

from repro.obs import metrics
from repro.obs import trace as _trace

_lock = threading.Lock()
_sites: Dict[str, Set[Tuple]] = {}


def _sig_of(x) -> Tuple:
    """(shape, dtype) signature of an array-like (or passthrough tuple)."""
    shape = getattr(x, "shape", None)
    if shape is None:
        return (tuple(x),) if isinstance(x, (tuple, list)) else (str(x),)
    return (tuple(int(d) for d in shape), str(getattr(x, "dtype", "?")))


def launch(site: str, *operands) -> None:
    """Record one launch at `site` with the given operands (arrays or
    explicit shape tuples).  New-signature-beyond-the-first increments
    `jit.retraces` (total and per-site)."""
    if not _trace._enabled:
        return
    sig = tuple(_sig_of(x) for x in operands)
    metrics.count("launches", 1, site=site)
    with _lock:
        seen = _sites.setdefault(site, set())
        fresh = sig not in seen
        if fresh:
            seen.add(sig)
            retrace = len(seen) > 1
        else:
            retrace = False
    if retrace:
        metrics.count("jit.retraces", 1)
        metrics.count("jit.retraces", 1, site=site)


def signatures() -> Dict[str, List[Tuple]]:
    """Site → sorted list of distinct signatures seen so far."""
    with _lock:
        return {site: sorted(map(repr, sigs))
                for site, sigs in sorted(_sites.items())}


def retraces() -> int:
    """Total distinct-signatures-beyond-the-first across all sites."""
    with _lock:
        return sum(max(0, len(s) - 1) for s in _sites.values())


def reset() -> None:
    """Forget every signature (fresh trace region)."""
    with _lock:
        _sites.clear()
