"""`repro.obs`: tracing, metrics, and launch accounting in one layer.

The engine's cost story — eval launches, compare lanes, index probes,
jit retraces, batch latency — flows through this package so the
planner, the serving loop, and the benchmarks all hang measurements on
the same counters.  Three pieces:

  * spans  (`obs.span`, `obs.tracing`) — nested, device-true timing,
    Chrome-trace export; near-zero cost when disabled;
  * metrics (`obs.count`, `obs.observe`, `obs.metrics.REGISTRY`) —
    counters + histograms that absorb the per-call stats dataclasses;
  * jitwatch (`obs.jit_launch`) — launch-signature sets per site,
    surfacing pow2-bucketing violations as a `jit.retraces` counter.

Enable for a region with `with obs.tracing() as tr:` (or process-wide
via `REPRO_OBS=1`); everything is a one-bool-check no-op otherwise.
"""
from repro.obs import export, jitwatch, metrics
from repro.obs.export import (bench_fields, chrome_trace, metrics_dump,
                              validate_chrome_trace, write_chrome_trace,
                              write_metrics)
from repro.obs.jitwatch import launch as jit_launch
from repro.obs.jitwatch import retraces as jit_retraces
from repro.obs.jitwatch import signatures as jit_signatures
from repro.obs.metrics import (REGISTRY, Counter, Histogram, Registry,
                               absorb_batch_stats, absorb_compaction_stats,
                               absorb_exec_stats, absorb_join_stats, count,
                               observe)
from repro.obs.trace import (TRACER, Span, Tracer, current_span, disable,
                             enable, get_tracer, is_enabled, span, tracing)

__all__ = [
    "export", "jitwatch", "metrics",
    "bench_fields", "chrome_trace", "metrics_dump", "validate_chrome_trace",
    "write_chrome_trace", "write_metrics",
    "jit_launch", "jit_retraces", "jit_signatures",
    "REGISTRY", "Counter", "Histogram", "Registry",
    "absorb_batch_stats", "absorb_compaction_stats",
    "absorb_exec_stats", "absorb_join_stats", "count", "observe",
    "TRACER", "Span", "Tracer", "current_span", "disable", "enable",
    "get_tracer", "is_enabled", "span", "tracing",
]
