"""Counters + histograms for the encrypted query engine.

The registry is the aggregation layer OVER the per-call stats
dataclasses (`ExecStats`, `BatchStats`, `JoinStats`,
`CompactionStats`): those stay as cheap always-on return values, and
`absorb_*` folds them into process-wide counters whenever the
observability layer is enabled.  Direct instrumentation (launch
counts, lane totals, ciphertext bytes, pad-waste) lands here too.

All record helpers are gated on `obs.is_enabled()` — one global bool
check when disabled.

Counter glossary (the span taxonomy lives in docs/architecture.md):

  eval.launches        batched raw-eval launches (fused scan, index
                       probe steps, pair-grid tiles, merge rounds,
                       adjacency/verify passes)
  eval.lanes           total compare lanes through those launches
  index.probes         encrypted binary-search probe lanes
  bytes.moved          ciphertext bytes entering launches
  jit.retraces         distinct launch signatures beyond the first
                       per site (see jitwatch)
  pad.waste            histogram of n_padded / n_rows per executed plan
  server.batch_wall_s  histogram of drained-batch wall seconds
  server.queries       queries served (label: tenant)
  server.compares      compare lanes attributed per tenant
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Tuple, Union

from repro.obs import trace as _trace


class Counter:
    """Monotonic integer counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        """Add `n` (default 1) to the counter."""
        self.value += int(n)


class Histogram:
    """Value distribution; keeps raw observations (engine cardinality
    is batches, not rows, so the buffer stays small) and derives
    count/sum/percentiles on demand."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        """Record one observation."""
        self.values.append(float(v))

    @property
    def count(self) -> int:
        """Number of observations."""
        return len(self.values)

    @property
    def total(self) -> float:
        """Sum of observations."""
        return float(sum(self.values))

    def percentile(self, p: float) -> float:
        """The p-th percentile (0..100) by nearest-rank
        (ceil(p/100·n)-th sorted value); 0.0 if empty."""
        if not self.values:
            return 0.0
        xs = sorted(self.values)
        k = max(0, min(len(xs) - 1,
                       -(-int(p * len(xs)) // 100) - 1))  # ceil w/o math
        return xs[k]

    def summary(self) -> Dict[str, float]:
        """count / sum / p50 / p99 as a flat dict."""
        return {"count": self.count, "sum": self.total,
                "p50": self.percentile(50), "p99": self.percentile(99)}


MetricKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: Dict[str, Any]) -> MetricKey:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


def _key_str(key: MetricKey) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Registry:
    """Name+labels → Counter/Histogram map with a flat snapshot view."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[MetricKey, Union[Counter, Histogram]] = {}

    def counter(self, name: str, **labels) -> Counter:
        """Get-or-create the counter `name{labels}`."""
        key = _key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = Counter()
            return m

    def histogram(self, name: str, **labels) -> Histogram:
        """Get-or-create the histogram `name{labels}`."""
        key = _key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = Histogram()
            return m

    def value(self, name: str, **labels) -> int:
        """Current value of a counter (0 if never touched)."""
        key = _key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
        return m.value if isinstance(m, Counter) else 0

    def snapshot(self) -> Dict[str, Any]:
        """Flat `{name_string: int | summary-dict}` dump (JSON-safe),
        sorted by key for stable diffs."""
        with self._lock:
            items = sorted(self._metrics.items(), key=lambda kv: kv[0])
        out: Dict[str, Any] = {}
        for key, m in items:
            out[_key_str(key)] = (m.value if isinstance(m, Counter)
                                  else m.summary())
        return out

    def reset(self) -> None:
        """Drop every metric (fresh trace region)."""
        with self._lock:
            self._metrics = {}


REGISTRY = Registry()


def count(name: str, n: int = 1, **labels) -> None:
    """Increment counter `name{labels}` by `n` iff obs is enabled."""
    if not _trace._enabled:
        return
    REGISTRY.counter(name, **labels).inc(n)


def observe(name: str, v: float, **labels) -> None:
    """Record `v` into histogram `name{labels}` iff obs is enabled."""
    if not _trace._enabled:
        return
    REGISTRY.histogram(name, **labels).observe(v)


# -- stats-dataclass absorption -------------------------------------------
#
# The engine's return-value dataclasses are the ground truth for one
# call; these helpers fold them into the process-wide registry so the
# registry supersedes the scattered counters as the aggregate view.

def absorb_exec_stats(stats, **labels) -> None:
    """Fold one `ExecStats`/`ShardedExecStats` into the registry."""
    if not _trace._enabled:
        return
    count("exec.eval_calls", stats.eval_calls, **labels)
    count("exec.scan_compares", stats.scan_compares, **labels)
    count("exec.index_compares", stats.index_compares, **labels)
    count("exec.order_compares", stats.order_compares, **labels)
    count("exec.scan_leaves", stats.scan_leaves, **labels)
    count("exec.indexed_leaves", stats.indexed_leaves, **labels)
    if getattr(stats, "merge_compares", 0):
        count("exec.merge_compares", stats.merge_compares, **labels)


def absorb_batch_stats(bstats, **labels) -> None:
    """Fold one `BatchStats`/`ShardedBatchStats` into the registry."""
    if not _trace._enabled:
        return
    count("server.batches", 1, **labels)
    count("server.batch_queries", bstats.queries, **labels)
    count("server.batch_eval_calls", bstats.eval_calls, **labels)
    count("server.batch_scan_compares", bstats.scan_compares, **labels)
    count("server.batch_index_compares", bstats.index_compares, **labels)
    observe("server.batch_wall_s", bstats.wall_s, **labels)


def absorb_join_stats(jstats, **labels) -> None:
    """Fold one `JoinStats` into the registry."""
    if not _trace._enabled:
        return
    count("join.executions", 1, strategy=jstats.strategy, **labels)
    count("join.eval_calls", jstats.eval_calls, **labels)
    count("join.compares", jstats.join_compares, **labels)


def absorb_compaction_stats(cstats, **labels) -> None:
    """Fold one `CompactionStats` into the registry."""
    if not _trace._enabled:
        return
    count("compact.runs", 1, **labels)
    count("compact.merge_compares", cstats.merge_compares, **labels)
    count("compact.indexes_merged", cstats.indexes_merged, **labels)
