"""Datasets for the paper's evaluation (§6.2.1) + LM token pipelines."""
from repro.data.datasets import load_dataset, DATASETS  # noqa: F401
