"""The paper's three evaluation datasets (§6.2.1), reproduced offline.

The originals are web downloads (blockchain.com trade volume, covidtracking
national history, UCSC hg38 tables); this container is offline, so we
generate *statistically faithful* stand-ins with the exact row counts the
paper reports and value distributions matching the sources' character:

  bitcoin : 1,085 daily trade-volume floats, lognormal with regime drift
  covid19 : 340 daily case-count integers, logistic-growth + noise
  hg38    : 34,423 genomic coordinates, mixture over chromosome lengths

All values are preprocessed to fit the BFV plaintext modulus (mod 65537)
or left as floats for CKKS — exactly the preprocessing §6.2.1 describes.
Deterministic (seeded) so benchmark numbers are reproducible.
"""
from __future__ import annotations

import zlib

import numpy as np

ROW_COUNTS = {"bitcoin": 1085, "covid19": 340, "hg38": 34423}
DATASETS = tuple(ROW_COUNTS)


def _bitcoin(rng: np.random.Generator) -> np.ndarray:
    n = ROW_COUNTS["bitcoin"]
    drift = np.cumsum(rng.normal(0, 0.05, n))
    vol = np.exp(rng.normal(9.5, 0.8, n) + drift)
    return vol


def _covid19(rng: np.random.Generator) -> np.ndarray:
    n = ROW_COUNTS["covid19"]
    t = np.arange(n, dtype=np.float64)
    waves = (2e5 / (1 + np.exp(-(t - 120) / 12))
             + 1.5e5 / (1 + np.exp(-(t - 260) / 9)))
    noise = rng.lognormal(0, 0.35, n)
    return waves * noise + rng.integers(0, 2000, n)


def _hg38(rng: np.random.Generator) -> np.ndarray:
    n = ROW_COUNTS["hg38"]
    chrom_lens = np.array([248956422, 242193529, 198295559, 190214555,
                           181538259, 170805979, 159345973, 145138636,
                           138394717, 133797422, 135086622, 133275309,
                           114364328, 107043718, 101991189, 90338345,
                           83257441, 80373285, 58617616, 64444167,
                           46709983, 50818468], dtype=np.float64)
    probs = chrom_lens / chrom_lens.sum()
    chrom = rng.choice(len(chrom_lens), size=n, p=probs)
    return rng.uniform(0, chrom_lens[chrom])


def load_dataset(name: str, *, scheme: str = "bfv",
                 t: int = 65537, seed: int = 1234) -> np.ndarray:
    # crc32, NOT hash(): str hashes are randomized per process
    # (PYTHONHASHSEED), which would make every "deterministic" dataset —
    # and the whole BENCH_db.json trajectory — differ run to run
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 1000)
    raw = {"bitcoin": _bitcoin, "covid19": _covid19, "hg38": _hg38}[name](rng)
    if scheme == "bfv":
        return (raw.astype(np.int64) % t).astype(np.int64)
    return raw.astype(np.float64)
