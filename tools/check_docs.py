#!/usr/bin/env python
"""Docs gate (CI): markdown links must resolve, public db API documented.

Two checks, both fail-on-regression:

  1. LINKS.  Every relative markdown link in README.md, docs/**/*.md and
     src/repro/db/README.md must point at an existing file (resolved
     from the linking file's directory); same-file `#anchor` links must
     match a heading in that file.  External (http/https/mailto) links
     are out of scope — CI must not flake on the network.
  2. DOCSTRINGS.  Every public module / class / function / method under
     src/repro/db/ and src/repro/obs/ (names not starting with "_") must carry a
     docstring.  The db layer is the repo's public query API; an
     undocumented entry point is a regression.

Usage:  python tools/check_docs.py  (exit 1 on any failure)
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", REPO / "src" / "repro" / "db" / "README.md"]
DOC_GLOBS = [REPO / "docs"]
PY_ROOTS = [REPO / "src" / "repro" / "db",
            REPO / "src" / "repro" / "obs"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _heading_slugs(text: str) -> set:
    """GitHub-style anchor slugs for every markdown heading."""
    slugs = set()
    for line in text.splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            slug = m.group(1).strip().lower()
            slug = re.sub(r"[^\w\s-]", "", slug)
            slugs.add(re.sub(r"\s+", "-", slug))
    return slugs


def check_links() -> list:
    """Relative links + same-file anchors across the doc set."""
    files = list(DOC_FILES)
    for root in DOC_GLOBS:
        if root.is_dir():
            files.extend(sorted(root.rglob("*.md")))
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"{md.relative_to(REPO)}: doc file missing")
            continue
        text = md.read_text()
        slugs = _heading_slugs(text)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if target.startswith("#"):
                if target[1:] not in slugs:
                    errors.append(f"{md.relative_to(REPO)}: dangling anchor "
                                  f"{target!r}")
                continue
            path = target.split("#", 1)[0]
            if not (md.parent / path).resolve().exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"{target!r}")
    return errors


def _missing_docstrings(tree: ast.Module, rel: str) -> list:
    """Public defs (module/class level) without docstrings."""
    errors = []
    if ast.get_docstring(tree) is None:
        errors.append(f"{rel}: missing module docstring")

    def walk(node, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                name = child.name
                if name.startswith("_"):        # private / dunder: exempt
                    continue
                if ast.get_docstring(child) is None:
                    kind = ("class" if isinstance(child, ast.ClassDef)
                            else "function")
                    errors.append(
                        f"{rel}: public {kind} {prefix}{name} "
                        f"(line {child.lineno}) has no docstring")
                if isinstance(child, ast.ClassDef):
                    walk(child, f"{prefix}{name}.")

    walk(tree, "")
    return errors


def check_docstrings() -> list:
    """Every public function/class under src/repro/db/ and
    src/repro/obs/ is documented."""
    errors = []
    for root in PY_ROOTS:
        for py in sorted(root.rglob("*.py")):
            rel = str(py.relative_to(REPO))
            tree = ast.parse(py.read_text())
            errors.extend(_missing_docstrings(tree, rel))
    return errors


def main() -> int:
    """Run both checks; print findings; nonzero exit on any."""
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        print(f"{len(errors)} docs check failure(s)")
        return 1
    print("docs checks passed (links + public db docstrings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
