#!/usr/bin/env python
"""CI trace smoke: one traced QueryServer batch must export a valid
Chrome trace.

Runs a small encrypted table through a batched `QueryServer` drain
under `obs.tracing()`, then fails loudly unless:

  * the export is structurally valid Chrome-trace JSON — every event
    carries `ph` / `ts` / `pid` (checked event by event here, on top of
    `obs.validate_chrome_trace`);
  * the spans the batch MUST produce are present: the batch span, the
    fused raw-eval launch, and the index binary search;
  * the server runs with a deliberately tiny `lane_budget`, so the
    fused scan splits into lane tiles — every `executor.eval_tile`
    span must nest under an `executor.fused_eval` parent (the tiling
    must refine the launch accounting, never restructure the tree);
  * per-query compare lanes reconcile exactly with the batch totals.

The trace lands at --out (default trace_smoke.json) and CI uploads it
as a workflow artifact, so every green run leaves an openable
ui.perfetto.dev trace behind.

Usage:  PYTHONPATH=src python tools/trace_smoke.py [--out trace.json]
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro import db, obs
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params


def main(argv=None) -> int:
    """Run the traced batch; validate; write the trace artifact."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="trace_smoke.json")
    args = ap.parse_args(argv)

    ks = keygen(make_params("test-bfv", mode="gadget"),
                jax.random.PRNGKey(0))
    vals = np.array([3, 14, 15, 9, 26, 5, 35, 8, 97, 93, 23, 84], np.int64)
    aux = np.array([1, 2, 3, 1, 2, 3, 1, 2, 3, 1, 2, 3], np.int64)
    table = db.Table.from_arrays(ks, "smoke", {"v": vals, "a": aux},
                                 jax.random.PRNGKey(1))
    idx = db.SortedIndex.build(ks, table, "v")   # "a" stays unindexed

    def enc(v, s):
        return E.encrypt(ks, np.int64(int(v)), jax.random.PRNGKey(s))

    # one batch mixing indexed lanes ("v") and a fused-scan atom: both
    # launch kinds must show up in the trace.  lane_budget=8 forces the
    # 16-wide fused scan into 2 tiles so the tile spans are exercised.
    server = db.QueryServer(ks, table, indexes={"v": idx}, batch=3,
                            lane_budget=8)
    qids = [server.submit(db.Range("v", enc(5, 2), enc(30, 3))),
            server.submit(db.Eq("a", enc(2, 4))),    # unindexed -> scan
            server.submit(db.Query(where=db.Range("v", enc(3, 5),
                                                  enc(95, 6)),
                                   top_k=db.TopK("v", 3)))]
    with obs.tracing() as tr:
        results = server.run()
        spans = list(tr.spans)
        tr.write_chrome_trace(args.out)

    errors = []

    # tile spans must NEST under the fused launch: the lane tiling is a
    # refinement of executor.fused_eval, not a sibling of it
    by_sid = {s.sid: s for s in spans}
    tiles = [s for s in spans if s.name == "executor.eval_tile"]
    if len(tiles) < 2:
        errors.append(f"lane_budget=8 on a 16-wide scan must produce "
                      f">=2 executor.eval_tile spans, got {len(tiles)}")
    for s in tiles:
        parent = by_sid.get(s.parent_sid)
        if parent is None or parent.name != "executor.fused_eval":
            errors.append(
                f"executor.eval_tile span (sid={s.sid}) not nested under "
                f"executor.fused_eval (parent="
                f"{parent.name if parent else None})")

    doc = json.load(open(args.out))
    errors += obs.validate_chrome_trace(doc)
    events = doc.get("traceEvents", [])
    for i, ev in enumerate(events):
        for field in ("ph", "ts", "pid"):
            if field not in ev:
                errors.append(f"event {i} missing {field!r}: {ev}")

    names = {ev.get("name") for ev in events}
    for must in ("server.batch", "index.search", "executor.fused_eval"):
        if must not in names:
            errors.append(f"required span {must!r} absent from trace")

    b = server.batch_log[-1]
    per_q = sum(results[q].stats.index_compares for q in qids)
    if per_q != b.index_compares:
        errors.append(f"per-query index compares {per_q} != "
                      f"batch total {b.index_compares}")
    per_s = sum(results[q].stats.scan_compares for q in qids)
    if per_s != b.scan_compares:
        errors.append(f"per-query scan compares {per_s} != "
                      f"batch total {b.scan_compares}")

    for e in errors:
        print(f"FAIL {e}")
    if errors:
        return 1
    print(f"trace smoke passed: {len(events)} events -> {args.out} "
          f"(batch: {b.queries} queries, {b.eval_calls} fused launch, "
          f"{b.index_compares} probe + {b.scan_compares} scan lanes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
