"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real (single) device; only launch/dryrun.py forces 512 host devices."""
import zlib

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng(request):
    """Deterministic per-test np.random.Generator.

    Seeded from the test's nodeid, so (a) every run of a given test —
    including hypothesis-less fallback sweeps of the encrypted-compare
    property tests — draws the same values, and (b) failures replay
    exactly from the failing test's name alone.  Parametrized tests get
    distinct streams per parameter (the id is part of the nodeid).
    """
    seed = zlib.crc32(request.node.nodeid.encode())
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# cross-scheme engine matrix: one cached KeySet per profile for the whole
# session (test-ckks keygen alone is ~10s — pay it once, not per test)
# ---------------------------------------------------------------------------

_SCHEME_KS_CACHE = {}

# keygen seeds match the historical bfv_keys/ckks_keys fixtures, which now
# delegate here — one keygen per profile for the whole session, regardless
# of whether a test reaches the keyset via scheme_ks or the named fixtures
_SCHEME_SEEDS = {"test-bfv": 42, "test-ckks": 7}


def get_scheme_ks(profile: str):
    """Shared small-profile KeySet cache (importable by tests that need a
    specific scheme outside the `scheme_ks` parametrization)."""
    if profile not in _SCHEME_KS_CACHE:
        from repro.core.keys import keygen
        from repro.core.params import make_params
        _SCHEME_KS_CACHE[profile] = keygen(
            make_params(profile, mode="gadget"),
            jax.random.PRNGKey(_SCHEME_SEEDS[profile]))
    return _SCHEME_KS_CACHE[profile]


@pytest.fixture(scope="session", params=["test-bfv", "test-ckks"],
                ids=["bfv", "ckks"])
def scheme_ks(request):
    """Parametrizes a test over the bfv and ckks engine profiles."""
    return get_scheme_ks(request.param)


@pytest.fixture(scope="session")
def bfv_engine_ks():
    """The bfv KeySet from the same shared cache, for scheme-independent
    engine tests (plan compilation etc.) that shouldn't double-run."""
    return get_scheme_ks("test-bfv")


@pytest.fixture(scope="session")
def bfv_params():
    from repro.core.params import make_params
    return make_params("test-bfv", mode="gadget")


@pytest.fixture(scope="session")
def bfv_keys(bfv_params):
    return get_scheme_ks("test-bfv")


@pytest.fixture(scope="session")
def paper_params():
    from repro.core.params import make_params
    return make_params("test-bfv", mode="paper")


@pytest.fixture(scope="session")
def paper_keys(paper_params):
    from repro.core.keys import keygen
    # weight=0 satisfies the paper's own correctness precondition exactly
    return keygen(paper_params, jax.random.PRNGKey(42), paper_ecek_weight=0)


@pytest.fixture(scope="session")
def ckks_params():
    from repro.core.params import make_params
    return make_params("test-ckks", mode="gadget")


@pytest.fixture(scope="session")
def ckks_keys(ckks_params):
    return get_scheme_ks("test-ckks")
