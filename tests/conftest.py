"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real (single) device; only launch/dryrun.py forces 512 host devices."""
import jax
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def bfv_params():
    from repro.core.params import make_params
    return make_params("test-bfv", mode="gadget")


@pytest.fixture(scope="session")
def bfv_keys(bfv_params):
    from repro.core.keys import keygen
    return keygen(bfv_params, jax.random.PRNGKey(42))


@pytest.fixture(scope="session")
def paper_params():
    from repro.core.params import make_params
    return make_params("test-bfv", mode="paper")


@pytest.fixture(scope="session")
def paper_keys(paper_params):
    from repro.core.keys import keygen
    # weight=0 satisfies the paper's own correctness precondition exactly
    return keygen(paper_params, jax.random.PRNGKey(42), paper_ecek_weight=0)


@pytest.fixture(scope="session")
def ckks_params():
    from repro.core.params import make_params
    return make_params("test-ckks", mode="gadget")


@pytest.fixture(scope="session")
def ckks_keys(ckks_params):
    from repro.core.keys import keygen
    return keygen(ckks_params, jax.random.PRNGKey(7))
