"""Baselines: Paillier substrate, HOPE comparisons, POPE interaction."""
import pytest

from repro.baselines import hope as HOPE
from repro.baselines import paillier as P
from repro.baselines import pope as POPE


@pytest.fixture(scope="module")
def paillier_keys():
    return P.keygen(bits=512)     # small-but-real for test speed


def test_paillier_roundtrip(paillier_keys):
    pub, priv = paillier_keys
    for m in (0, 1, 12345, pub.n - 1):
        assert P.decrypt(priv, P.encrypt(pub, m)) == m % pub.n


def test_paillier_additive_homomorphism(paillier_keys):
    pub, priv = paillier_keys
    a, b = 1234, 5678
    ct = P.add(pub, P.encrypt(pub, a), P.encrypt(pub, b))
    assert P.decrypt(priv, ct) == a + b


def test_paillier_scalar_mul(paillier_keys):
    pub, priv = paillier_keys
    ct = P.cmul(pub, P.encrypt(pub, 111), 7)
    assert P.decrypt(priv, ct) == 777


def test_hope_compare():
    ctx = HOPE.keygen(bits=512)
    pairs = [(5, 3), (3, 5), (7, 7), (10**6, 1), (0, 10**6)]
    for a, b in pairs:
        out = HOPE.compare(ctx, HOPE.encrypt(ctx, a), HOPE.encrypt(ctx, b))
        assert out == (a > b) - (a < b), (a, b, out)


def test_hope_addition_then_compare():
    ctx = HOPE.keygen(bits=512)
    ct_sum = HOPE.add(ctx, HOPE.encrypt(ctx, 40), HOPE.encrypt(ctx, 2))
    assert HOPE.compare(ctx, ct_sum, HOPE.encrypt(ctx, 41)) == 1


def test_pope_compare_and_rounds():
    client = POPE.PopeClient(bits=256)
    tr = POPE.Transport(latency_s=0.0)
    server = POPE.PopeServer(client, tr)
    vals = [9, 2, 7, 1]
    cts = [client.encrypt(v) for v in vals]
    for c in cts:
        server.insert(c)
    assert server.compare(cts[0], cts[1]) == 1
    assert tr.rounds > 0, "POPE must consume client round trips"


def test_pope_range_query():
    client = POPE.PopeClient(bits=256)
    server = POPE.PopeServer(client, POPE.Transport(latency_s=0.0))
    vals = [5, 17, 3, 99, 42, 8]
    cts = {v: client.encrypt(v) for v in vals}
    for v, c in cts.items():
        server.insert(c)
    got = server.range_query(client.encrypt(8), client.encrypt(50))
    got_plain = sorted(POPE.P.decrypt(client.priv, c) for c in got)
    assert got_plain == [8, 17, 42]
