"""repro.db joins: plan node, nested-loop vs sort-merge, shard grid.

THE contracts under test:

  * EQUIVALENCE — both strategies return the same canonical `pairs`
    array as the plaintext reference, on both schemes (ckks data lives
    on the usual coarse GRID so every decision has noise-proof margins).
  * COST — sort-merge issues measurably fewer compare lanes than the
    nested-loop pair grid once tables are non-trivial.
  * SHARD INVARIANCE — `from_table`-sharded joins are byte-identical to
    the unsharded plan for S ∈ {1, 2, 3, 4}, nested AND sort-merge
    (nested re-evaluates the SAME ciphertext pairs, so even the raw
    grid values must agree).
  * ε-BAND — float keys within ε join, keys beyond ε don't, and the
    sort-merge candidate verification restores non-transitive band
    semantics (adjacency chaining alone would overclaim).

Edge cases from the issue checklist ride along: empty results,
duplicate keys on both sides, non-power-of-two tables, batched K-join
serving.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import db
from repro.core import encrypt as E

GRID = 0.25        # ckks float grid (>> test-ckks equality tolerance)
EPS_BAND = 0.3     # captures exactly the ±1-grid-step neighbors
SHARD_COUNTS = (1, 2, 3, 4)


def _is_ckks(ks) -> bool:
    return ks.params.profile.scheme == "ckks"


def _vals(ks, ints) -> np.ndarray:
    ints = np.asarray(ints)
    if _is_ckks(ks):
        return ints.astype(np.float64) * GRID
    return ints.astype(np.int64)


def _enc(ks, v, seed):
    v = float(v) if _is_ckks(ks) else int(v)
    return E.encrypt(ks, jnp.asarray(v), jax.random.PRNGKey(seed))


def _bound(ks, v, side):
    return float(v) + side * GRID / 2 if _is_ckks(ks) else int(v)


def _tables(ks, rng, n_l=21, n_r=13, key_lo=0, key_hi=9):
    """Two tables with overlapping duplicate-heavy keys (non-pow2 rows)."""
    lk = _vals(ks, rng.integers(key_lo, key_hi, n_l))
    rk = _vals(ks, rng.integers(key_lo, key_hi, n_r))
    lv = _vals(ks, rng.integers(0, 200, n_l))
    rw = _vals(ks, rng.integers(0, 200, n_r))
    lt = db.Table.from_arrays(ks, "L", {"k": lk, "v": lv},
                              jax.random.PRNGKey(1))
    rt = db.Table.from_arrays(ks, "R", {"k": rk, "w": rw},
                              jax.random.PRNGKey(2))
    return lt, rt, lk, rk, lv, rw


def _want_pairs(lk, rk, lmask=None, rmask=None, eps=None):
    """Plaintext reference pairs in the canonical lexicographic order."""
    if eps is None:
        grid = lk[:, None] == rk[None, :]
    else:
        grid = np.abs(lk[:, None] - rk[None, :]) <= eps
    if lmask is not None:
        grid &= np.asarray(lmask)[:, None]
    if rmask is not None:
        grid &= np.asarray(rmask)[None, :]
    return np.argwhere(grid)


def _indexes(ks, lt, rt):
    return ({"k": db.SortedIndex.build(ks, lt, "k")},
            {"k": db.SortedIndex.build(ks, rt, "k")})


# ---------------------------------------------------------------------------
# plan node / compilation
# ---------------------------------------------------------------------------

def test_join_node_compiles_and_validates(bfv_engine_ks):
    ks = bfv_engine_ks
    j = db.Join(db.Eq("v", _enc(ks, 5, 0)), None, on="k")
    cj = db.compile_join(j)
    assert cj.on_columns == ("k", "k")
    assert cj.left_plan is not None and cj.right_plan is None
    assert db.Join(None, None, on=("a", "b")).on_columns == ("a", "b")
    with pytest.raises(ValueError, match="kind"):
        db.compile_join(db.Join(None, None, on="k", kind="theta"))
    with pytest.raises(TypeError):
        db.compile_join(db.Join("not a plan", None, on="k"))


def test_join_strategy_resolution():
    from repro.db.join import resolve_strategy
    assert resolve_strategy("auto", True, True) == "sort_merge"
    assert resolve_strategy("auto", True, False) == "nested"
    assert resolve_strategy("nested", True, True) == "nested"
    with pytest.raises(ValueError):
        resolve_strategy("hash", True, True)


# ---------------------------------------------------------------------------
# nested-loop vs sort-merge equivalence (cross-scheme)
# ---------------------------------------------------------------------------

def test_join_matches_plaintext_both_strategies(scheme_ks, rng):
    """Duplicate keys on BOTH sides: every cross pair appears exactly
    once, canonical order, identical across strategies."""
    ks = scheme_ks
    lt, rt, lk, rk, _, _ = _tables(ks, rng)
    want = _want_pairs(lk, rk)
    assert len(want)                       # keys overlap by construction
    j = db.Join(None, None, on="k")
    res_n = db.execute_join(ks, lt, rt, j, strategy="nested")
    li, ri = _indexes(ks, lt, rt)
    res_s = db.execute_join(ks, lt, rt, j, left_indexes=li,
                            right_indexes=ri)
    assert res_s.stats.strategy == "sort_merge"       # auto picked it
    np.testing.assert_array_equal(res_n.pairs, want)
    np.testing.assert_array_equal(res_s.pairs, want)
    # the whole nested grid rode tiled batched Evals over padded rows
    assert res_n.stats.pair_compares == lt.n_padded * rt.n_padded
    assert res_n.stats.eval_calls >= 1


def test_sort_merge_uses_fewer_compares(scheme_ks, rng):
    """The cost claim: sort-merge's merge+adjacency+verify lanes stay
    well under the nested-loop pair grid (the strategy's reason to
    exist, asserted where it is produced)."""
    ks = scheme_ks
    lt, rt, _, _, _, _ = _tables(ks, rng, n_l=48, n_r=48, key_hi=30)
    j = db.Join(None, None, on="k")
    res_n = db.execute_join(ks, lt, rt, j, strategy="nested")
    li, ri = _indexes(ks, lt, rt)
    res_s = db.execute_join(ks, lt, rt, j, left_indexes=li,
                            right_indexes=ri)
    np.testing.assert_array_equal(res_s.pairs, res_n.pairs)
    assert res_s.stats.build_compares == 0        # runs reused from indexes
    assert res_s.stats.join_compares < res_n.stats.join_compares / 2


def test_join_empty_result(scheme_ks, rng):
    """Disjoint key ranges -> zero pairs on every path."""
    ks = scheme_ks
    lk = _vals(ks, rng.integers(0, 10, 12))
    rk = _vals(ks, rng.integers(100, 110, 9))
    lt = db.Table.from_arrays(ks, "L", {"k": lk}, jax.random.PRNGKey(3))
    rt = db.Table.from_arrays(ks, "R", {"k": rk}, jax.random.PRNGKey(4))
    j = db.Join(None, None, on="k")
    li, ri = _indexes(ks, lt, rt)
    for res in (db.execute_join(ks, lt, rt, j, strategy="nested"),
                db.execute_join(ks, lt, rt, j, left_indexes=li,
                                right_indexes=ri)):
        assert len(res) == 0
        assert res.pairs.shape == (0, 2)


def test_join_with_side_filters_and_projection(scheme_ks, rng):
    """Per-side sub-plans filter before the join; `select` columns come
    back as still-encrypted "left./right." projections at pair rows."""
    ks = scheme_ks
    lt, rt, lk, rk, lv, rw = _tables(ks, rng, n_l=26, n_r=17)
    lo = _bound(ks, _vals(ks, 40), -1)
    hi = _bound(ks, _vals(ks, 160), +1)
    j = db.Join(
        db.Query(where=db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                 select=("v",)),
        db.Query(select=("w",)),
        on="k")
    lmask = (lv >= lo) & (lv <= hi)
    want = _want_pairs(lk, rk, lmask=lmask)
    li, ri = _indexes(ks, lt, rt)
    for res in (db.execute_join(ks, lt, rt, j, strategy="nested"),
                db.execute_join(ks, lt, rt, j, left_indexes=li,
                                right_indexes=ri)):
        np.testing.assert_array_equal(res.pairs, want)
        np.testing.assert_array_equal(res.left_mask, lmask)
        got_v = np.asarray(E.decrypt(ks, res.columns["left.v"]))
        got_w = np.asarray(E.decrypt(ks, res.columns["right.w"]))
        if _is_ckks(ks):
            from repro.core.ckks import equality_tolerance
            tol = equality_tolerance(ks.params)
            np.testing.assert_allclose(got_v, lv[want[:, 0]], atol=tol)
            np.testing.assert_allclose(got_w, rw[want[:, 1]], atol=tol)
        else:
            np.testing.assert_array_equal(got_v, lv[want[:, 0]])
            np.testing.assert_array_equal(got_w, rw[want[:, 1]])


def test_join_on_distinct_column_names(scheme_ks, rng):
    ks = scheme_ks
    a = _vals(ks, rng.integers(0, 8, 11))
    b = _vals(ks, rng.integers(0, 8, 7))
    lt = db.Table.from_arrays(ks, "L", {"ka": a}, jax.random.PRNGKey(5))
    rt = db.Table.from_arrays(ks, "R", {"kb": b}, jax.random.PRNGKey(6))
    res = db.execute_join(ks, lt, rt, db.Join(None, None, on=("ka", "kb")),
                          strategy="nested")
    np.testing.assert_array_equal(res.pairs, _want_pairs(a, b))


# ---------------------------------------------------------------------------
# ε-band joins (ckks float keys)
# ---------------------------------------------------------------------------

def test_eps_band_join_both_strategies(scheme_ks, rng):
    """Keys differing by < ε join, > ε don't — and the sort-merge
    verification pass keeps the band NON-transitive (a chained class
    wider than ε must not produce cross pairs farther than ε)."""
    ks = scheme_ks
    if not _is_ckks(ks):
        pytest.skip("ε-band joins are a float-key (ckks) feature")
    # adjacent grid steps chain: 0, .25, .5, ... each within ε of its
    # neighbor but NOT of its 2nd neighbor (.5 > ε = .3)
    lk = _vals(ks, np.asarray([0, 1, 2, 4, 8, 9]))
    rk = _vals(ks, np.asarray([1, 2, 3, 8, 30]))
    lt = db.Table.from_arrays(ks, "L", {"k": lk}, jax.random.PRNGKey(7))
    rt = db.Table.from_arrays(ks, "R", {"k": rk}, jax.random.PRNGKey(8))
    want = _want_pairs(lk, rk, eps=EPS_BAND)
    j = db.Join(None, None, on="k", eps=EPS_BAND)
    res_n = db.execute_join(ks, lt, rt, j, strategy="nested")
    li, ri = _indexes(ks, lt, rt)
    res_s = db.execute_join(ks, lt, rt, j, left_indexes=li,
                            right_indexes=ri)
    np.testing.assert_array_equal(res_n.pairs, want)
    np.testing.assert_array_equal(res_s.pairs, want)
    assert res_s.stats.verify_compares > 0      # the band WAS verified
    # native-tolerance join is strictly tighter: exact key matches only
    res_0 = db.execute_join(ks, lt, rt, db.Join(None, None, on="k"),
                            strategy="nested")
    np.testing.assert_array_equal(res_0.pairs, _want_pairs(lk, rk))


# ---------------------------------------------------------------------------
# cross-shard joins: the [S, S] pair grid
# ---------------------------------------------------------------------------

def test_join_shard_invariance_matrix(scheme_ks, rng):
    """S ∈ {1, 2, 3, 4}: sharded join pairs byte-identical to the
    unsharded plan, nested AND sort-merge (acceptance criterion)."""
    ks = scheme_ks
    lt, rt, lk, rk, _, _ = _tables(ks, rng, n_l=23, n_r=15)
    j = db.Join(None, None, on="k")
    ref = db.execute_join(ks, lt, rt, j, strategy="nested")
    np.testing.assert_array_equal(ref.pairs, _want_pairs(lk, rk))
    for S in SHARD_COUNTS:
        sl = db.ShardedTable.from_table(ks, lt, spec=db.ShardSpec.create(S))
        sr = db.ShardedTable.from_table(ks, rt, spec=db.ShardSpec.create(S))
        res = db.execute_join(ks, sl, sr, j, strategy="nested")
        np.testing.assert_array_equal(res.pairs, ref.pairs,
                                      err_msg=f"nested pairs differ at S={S}")
        assert res.stats.shards == (S, S)
        sil = db.ShardedIndex.build(ks, sl, "k")
        sir = db.ShardedIndex.build(ks, sr, "k")
        res_s = db.execute_join(ks, sl, sr, j, left_indexes={"k": sil},
                                right_indexes={"k": sir})
        assert res_s.stats.strategy == "sort_merge"
        np.testing.assert_array_equal(
            res_s.pairs, ref.pairs,
            err_msg=f"sort-merge pairs differ at S={S}")


def test_join_mixed_table_and_sharded(scheme_ks, rng):
    """Table × ShardedTable joins dispatch to the shard executor and
    stay byte-identical (the plain side wraps as one ciphertext-reusing
    shard)."""
    ks = scheme_ks
    lt, rt, lk, rk, _, _ = _tables(ks, rng, n_l=14, n_r=10)
    j = db.Join(None, None, on="k")
    ref = db.execute_join(ks, lt, rt, j, strategy="nested")
    sr = db.ShardedTable.from_table(ks, rt, spec=db.ShardSpec.create(2))
    res = db.execute_join(ks, lt, sr, j, strategy="nested")
    np.testing.assert_array_equal(res.pairs, ref.pairs)
    assert res.stats.shards == (1, 2)


def test_sharded_join_with_filters(scheme_ks, rng):
    """Side filters resolve through the sharded filter machinery before
    the pair grid; pairs match the unsharded filtered join."""
    ks = scheme_ks
    lt, rt, lk, rk, lv, rw = _tables(ks, rng, n_l=27, n_r=19)
    lo = _bound(ks, _vals(ks, 30), -1)
    hi = _bound(ks, _vals(ks, 150), +1)
    j = db.Join(db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)), None,
                on="k")
    ref = db.execute_join(ks, lt, rt, j, strategy="nested")
    want = _want_pairs(lk, rk, lmask=(lv >= lo) & (lv <= hi))
    np.testing.assert_array_equal(ref.pairs, want)
    for S in (2, 3):
        sl = db.ShardedTable.from_table(ks, lt, spec=db.ShardSpec.create(S))
        sr = db.ShardedTable.from_table(ks, rt, spec=db.ShardSpec.create(S))
        res = db.execute_join(ks, sl, sr, j, strategy="nested")
        np.testing.assert_array_equal(res.pairs, want)


def test_eps_band_join_sharded(scheme_ks, rng):
    ks = scheme_ks
    if not _is_ckks(ks):
        pytest.skip("ε-band joins are a float-key (ckks) feature")
    lk = _vals(ks, np.asarray([0, 1, 2, 4, 8, 9, 12]))
    rk = _vals(ks, np.asarray([1, 2, 3, 8, 30]))
    lt = db.Table.from_arrays(ks, "L", {"k": lk}, jax.random.PRNGKey(9))
    rt = db.Table.from_arrays(ks, "R", {"k": rk}, jax.random.PRNGKey(10))
    want = _want_pairs(lk, rk, eps=EPS_BAND)
    j = db.Join(None, None, on="k", eps=EPS_BAND)
    for S in (2, 4):
        sl = db.ShardedTable.from_table(ks, lt, spec=db.ShardSpec.create(S))
        sr = db.ShardedTable.from_table(ks, rt, spec=db.ShardSpec.create(S))
        res = db.execute_join(ks, sl, sr, j, strategy="nested")
        np.testing.assert_array_equal(res.pairs, want)
        sil = db.ShardedIndex.build(ks, sl, "k")
        sir = db.ShardedIndex.build(ks, sr, "k")
        res_s = db.execute_join(ks, sl, sr, j, left_indexes={"k": sil},
                                right_indexes={"k": sir})
        np.testing.assert_array_equal(res_s.pairs, want)


# ---------------------------------------------------------------------------
# batched K-query joins through the QueryServer
# ---------------------------------------------------------------------------

def test_query_server_dedupes_join_grids(scheme_ks, rng):
    """K joins against the same right table/key share ONE pair-grid
    launch, and their left filter leaves fuse into the batch's shared
    scan Eval alongside a plain query."""
    ks = scheme_ks
    lt, rt, lk, rk, lv, rw = _tables(ks, rng, n_l=30, n_r=14)
    server = db.QueryServer(ks, lt, batch=4)
    lo = _bound(ks, _vals(ks, 20), -1)
    hi = _bound(ks, _vals(ks, 90), +1)
    q1 = server.submit(db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)))
    j1 = server.submit_join(db.Join(None, None, on="k"), rt)
    j2 = server.submit_join(
        db.Join(db.Range("v", _enc(ks, lo, 2), _enc(ks, hi, 3)), None,
                on="k"), rt)
    j3 = server.submit_join(
        db.Join(None, db.Eq("w", _enc(ks, rw[2], 4)), on="k"), rt)
    res = server.run()
    b = server.batch_log[0]
    assert (b.queries, b.joins) == (1, 3)
    assert b.grid_evals == 1              # three joins, ONE deduped grid
    assert b.eval_calls == 1              # query + join left leaves fused
    lmask = (lv >= lo) & (lv <= hi)
    np.testing.assert_array_equal(res[q1].mask, lmask)
    np.testing.assert_array_equal(res[j1].pairs, _want_pairs(lk, rk))
    np.testing.assert_array_equal(res[j2].pairs,
                                  _want_pairs(lk, rk, lmask=lmask))
    np.testing.assert_array_equal(res[j3].pairs,
                                  _want_pairs(lk, rk, rmask=rw == rw[2]))


def test_query_server_sort_merge_join(scheme_ks, rng):
    ks = scheme_ks
    lt, rt, lk, rk, _, _ = _tables(ks, rng, n_l=16, n_r=12)
    li, ri = _indexes(ks, lt, rt)
    server = db.QueryServer(ks, lt, indexes=li, batch=2)
    jid = server.submit_join(db.Join(None, None, on="k"), rt,
                             right_indexes=ri)
    res = server.run()
    assert res[jid].stats.strategy == "sort_merge"
    assert server.batch_log[0].grid_evals == 0
    np.testing.assert_array_equal(res[jid].pairs, _want_pairs(lk, rk))
