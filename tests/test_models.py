"""Per-architecture smoke tests: reduced config, one forward + train step
on CPU, output shapes + no NaNs (the assignment's smoke contract)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T


def _batch(cfg, B=2, S=32, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "patches":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.num_patches, cfg.d_model))
    if cfg.frontend == "frames":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = configs.get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = T.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, batch))(params)
    assert not bool(jnp.isnan(loss)) and float(loss) > 0
    gn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.abs(g.astype(jnp.float32))),
                     grads))
    assert not bool(jnp.isnan(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_exact_assignment(arch):
    """The full configs carry the exact assigned numbers."""
    cfg = configs.get_config(arch)
    expected = {
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "recurrentgemma_9b": (39, 4096, 16, 1, 12288, 256000),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
    }[configs.canon(arch)]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)


def test_moe_active_params_less_than_total():
    for arch in ("deepseek_moe_16b", "qwen3_moe_30b_a3b"):
        cfg = configs.get_config(arch)
        assert cfg.active_param_count() < cfg.param_count() / 3


def test_param_counts_in_expected_band():
    """Analytic N lands near each arch's nameplate size."""
    bands = {"llava_next_34b": (30e9, 40e9), "minitron_8b": (8e9, 11e9),
             "smollm_360m": (0.3e9, 0.5e9), "minicpm3_4b": (3.5e9, 5e9),
             "internlm2_20b": (17e9, 23e9),
             "recurrentgemma_9b": (8e9, 11e9),
             "xlstm_125m": (0.08e9, 0.16e9),
             "deepseek_moe_16b": (14e9, 19e9),
             "qwen3_moe_30b_a3b": (27e9, 33e9),
             "whisper_base": (0.05e9, 0.15e9)}
    for arch, (lo, hi) in bands.items():
        n = configs.get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_sub_quadratic_flags():
    assert configs.get_config("recurrentgemma_9b").sub_quadratic
    assert configs.get_config("xlstm_125m").sub_quadratic
    for arch in ("llava_next_34b", "minitron_8b", "smollm_360m",
                 "minicpm3_4b", "internlm2_20b", "deepseek_moe_16b",
                 "qwen3_moe_30b_a3b", "whisper_base"):
        assert not configs.get_config(arch).sub_quadratic, arch
