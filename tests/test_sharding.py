"""Sharding rules + input specs for the dry-run cells."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.specs import SHAPES, cell_supported, input_specs
from repro.models import transformer as T
from repro.parallel import sharding as SH
from repro.parallel.constrain import shard


def test_param_rules_spot_checks():
    cfg = configs.get_reduced("minitron_8b")
    params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    specs = SH.param_specs(params)
    assert specs["embed"] == P("model", None)
    assert specs["unembed"] == P(None, "model")
    g = specs["groups"]["b0"]
    assert g["attn"]["wq"] == P(None, "data", "model")
    assert g["attn"]["wo"] == P(None, "model", "data")
    assert g["ffn"]["wi"] == P(None, "data", "model")
    assert g["ffn"]["wo"] == P(None, "model", "data")
    assert g["ln1"]["scale"] == P()


def test_moe_param_rules():
    cfg = configs.get_reduced("qwen3_moe_30b_a3b")
    params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    g = SH.param_specs(params)["groups"]["b0"]
    assert g["moe"]["experts_wi"] == P(None, "model", "data", None)
    assert g["moe"]["experts_wo"] == P(None, "model", None, "data")
    assert g["moe"]["router"] == P(None, "data", None)


def test_constrain_noop_without_mesh():
    x = jnp.zeros((4, 8))
    y = shard(x, "batch", "model")
    assert y.shape == x.shape     # and no error on a single device


def test_constrain_drops_small_dims():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with mesh:
        x = jnp.zeros((4, 8))
        y = shard(x, "batch", "model")
        assert y.shape == x.shape


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_all_cells(arch, shape):
    cfg = configs.get_config(arch)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        assert shape == "long_500k" and not cfg.sub_quadratic
        assert why
        return
    spec = input_specs(cfg, shape)
    meta = SHAPES[shape]
    if spec["kind"] == "train":
        state, batch = spec["args"]
        assert batch["tokens"].shape == (meta["global_batch"],
                                         meta["seq_len"])
    elif spec["kind"] == "prefill":
        _, batch = spec["args"]
        assert batch["tokens"].shape == (meta["global_batch"],
                                         meta["seq_len"])
    else:
        params, cache, token = spec["args"]
        assert token.shape == (meta["global_batch"],)
        # cache covers seq_len positions for attention archs
        leaves = jax.tree.leaves(cache)
        assert leaves, "empty cache specs"


def test_skip_list_is_exactly_the_full_attention_archs():
    skipped = [a for a in configs.ARCH_IDS
               if not cell_supported(configs.get_config(a), "long_500k")[0]]
    assert sorted(skipped) == sorted([
        "llava_next_34b", "minitron_8b", "smollm_360m", "minicpm3_4b",
        "internlm2_20b", "deepseek_moe_16b", "qwen3_moe_30b_a3b",
        "whisper_base"])


def test_40_cells_accounted():
    total = len(configs.ARCH_IDS) * len(SHAPES)
    assert total == 40
    runnable = sum(
        cell_supported(configs.get_config(a), s)[0]
        for a in configs.ARCH_IDS for s in SHAPES)
    assert runnable == 32    # 8 noted skips
