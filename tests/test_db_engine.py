"""repro.db engine: plan IR, fused executor, sorted index, batched serving.

All assertions compare against the plaintext answer — the engine must be
*exact* on BFV integer columns.  Dataset slices keep CI time bounded; the
full-row runs live in benchmarks/db_engine.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import db
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import DATASETS, load_dataset

_CACHE = {}


def _ks():
    if "ks" not in _CACHE:
        _CACHE["ks"] = keygen(make_params("test-bfv", mode="gadget"),
                              jax.random.PRNGKey(3))
    return _CACHE["ks"]


def _enc(ks, v, seed):
    return E.encrypt(ks, jnp.asarray(int(v)), jax.random.PRNGKey(seed))


def _dataset_rows(name, n_rows):
    ks = _ks()
    vals = load_dataset(name, scheme="bfv", t=ks.params.t)[:n_rows]
    return vals.astype(np.int64)


# ---------------------------------------------------------------------------
# plan construction / compilation
# ---------------------------------------------------------------------------

def test_plan_compile_structure():
    ks = _ks()
    r = db.Range("v", _enc(ks, 10, 0), _enc(ks, 20, 1))
    e = db.Eq("s", _enc(ks, 5, 2))
    plan = db.compile_plan(db.Query(where=db.And(r, e)))
    assert plan.num_leaves == 2
    assert plan.tree == ("and", [("leaf", 0), ("leaf", 1)])
    # Range lowers to 2 scan atoms, Eq to 1
    assert [a.op for a in plan.scan_atoms(0)] == [">=", "<="]
    assert [a.op for a in plan.scan_atoms(1)] == ["=="]


def test_plan_compile_dedups_repeated_leaves():
    ks = _ks()
    r = db.Range("v", _enc(ks, 10, 0), _enc(ks, 20, 1))
    e1 = db.Eq("s", _enc(ks, 5, 2))
    e2 = db.Eq("s", _enc(ks, 6, 3))
    # r appears twice but compiles to ONE leaf
    plan = db.compile_plan(db.Or(db.And(r, e1), db.And(r, e2)))
    assert plan.num_leaves == 3
    assert plan.tree == ("or", [("and", [("leaf", 0), ("leaf", 1)]),
                                ("and", [("leaf", 0), ("leaf", 2)])])


def test_predicate_operator_sugar():
    ks = _ks()
    r = db.Range("v", _enc(ks, 10, 0), _enc(ks, 20, 1))
    e = db.Eq("v", _enc(ks, 5, 2))
    assert isinstance(r & e, db.And)
    assert isinstance(r | e, db.Or)
    assert isinstance(~r, db.Not)


def test_bare_predicate_compiles_to_query():
    ks = _ks()
    plan = db.compile_plan(db.Eq("v", _enc(ks, 5, 0)))
    assert plan.num_leaves == 1 and plan.tree == ("leaf", 0)
    assert plan.query.where is not None


# ---------------------------------------------------------------------------
# table
# ---------------------------------------------------------------------------

def test_table_pads_to_power_of_two_and_roundtrips():
    ks = _ks()
    vals = np.arange(50, dtype=np.int64)
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(0))
    assert t.n_rows == 50 and t.n_padded == 64
    assert t.valid.sum() == 50
    np.testing.assert_array_equal(t.decrypt_column(ks, "v"), vals)
    # pad rows are genuine encryptions of 0
    full = t.decrypt_column(ks, "v", include_padding=True)
    assert (full[50:] == 0).all()


def test_table_rejects_ragged_columns():
    ks = _ks()
    with pytest.raises(ValueError):
        db.Table.from_arrays(ks, "t", {"a": np.arange(4), "b": np.arange(5)},
                             jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# executor: fused linear scan
# ---------------------------------------------------------------------------

def test_multi_predicate_and_or_matches_plaintext():
    ks = _ks()
    rng = np.random.default_rng(1)
    vals = rng.integers(0, 200, 60)
    score = rng.integers(0, 200, 60)
    t = db.Table.from_arrays(ks, "t", {"v": vals, "s": score},
                             jax.random.PRNGKey(1))
    q = db.Or(db.And(db.Range("v", _enc(ks, 40, 0), _enc(ks, 120, 1)),
                     db.Range("s", _enc(ks, 0, 2), _enc(ks, 100, 3))),
              db.Not(db.Range("v", _enc(ks, 0, 4), _enc(ks, 150, 5))))
    res = db.execute(ks, t, q)
    want = (((vals >= 40) & (vals <= 120) & (score <= 100))
            | ~((vals >= 0) & (vals <= 150)))
    np.testing.assert_array_equal(res.mask, want)
    # the whole 3-leaf predicate tree ran as ONE fused Eval
    assert res.stats.eval_calls == 1
    assert res.stats.scan_leaves == 3


@pytest.mark.parametrize("name", DATASETS)
def test_end_to_end_query_matches_plaintext(name):
    """And(Range, Eq) + TopK — exact on a slice of each paper dataset."""
    ks = _ks()
    vals = _dataset_rows(name, 96)
    rng = np.random.default_rng(2)
    aux = rng.integers(0, 250, len(vals))
    t = db.Table.from_arrays(ks, name, {"v": vals, "aux": aux},
                             jax.random.PRNGKey(2))
    lo, hi = int(np.percentile(vals, 20)), int(np.percentile(vals, 80))
    eq_v = int(aux[0])
    q = db.Query(
        where=db.And(db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                     db.Eq("aux", _enc(ks, eq_v, 2))),
        top_k=db.TopK("v", 3), select=("v",))
    res = db.execute(ks, t, q)
    want_mask = (vals >= lo) & (vals <= hi) & (aux == eq_v)
    np.testing.assert_array_equal(res.mask, want_mask)
    want_top = sorted(vals[want_mask].tolist(), reverse=True)[:3]
    assert vals[res.row_ids].tolist() == want_top
    # projected ciphertexts decrypt to the selected rows
    got = np.asarray(E.decrypt(ks, res.columns["v"]))
    assert got.tolist() == want_top


def test_order_by_and_limit():
    ks = _ks()
    vals = np.asarray([40, 10, 99, 3, 77, 23, 55], np.int64)
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(4))
    q = db.Query(where=db.Range("v", _enc(ks, 5, 0), _enc(ks, 90, 1)),
                 order_by=db.OrderBy("v", descending=True),
                 limit=db.Limit(3))
    res = db.execute(ks, t, q)
    want = sorted(vals[(vals >= 5) & (vals <= 90)].tolist(), reverse=True)[:3]
    assert vals[res.row_ids].tolist() == want


# ---------------------------------------------------------------------------
# sorted index
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", DATASETS)
def test_indexed_equals_linear_range_query(name):
    ks = _ks()
    vals = _dataset_rows(name, 80)
    t = db.Table.from_arrays(ks, name, {"v": vals}, jax.random.PRNGKey(5))
    idx = db.SortedIndex.build(ks, t, "v")
    np.testing.assert_array_equal(vals[idx.perm], np.sort(vals))
    rng = np.random.default_rng(6)
    for i in range(3):
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        q = db.Range("v", _enc(ks, lo, 10 + i), _enc(ks, hi, 20 + i))
        lin = db.execute(ks, t, q)
        ind = db.execute(ks, t, q, indexes={"v": idx})
        np.testing.assert_array_equal(lin.mask, ind.mask)
        np.testing.assert_array_equal(
            ind.mask, (vals >= lo) & (vals <= hi))
        assert ind.stats.eval_calls == 0          # no linear scan at all
        assert ind.stats.index_compares <= 2 * (int(np.ceil(
            np.log2(len(vals)))) + 1)


def test_index_point_lookup_duplicates():
    ks = _ks()
    vals = np.asarray([7, 3, 7, 1, 9, 7, 3, 2, 8], np.int64)
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(7))
    idx = db.SortedIndex.build(ks, t, "v")
    rows = idx.point_lookup(ks, _enc(ks, 7, 0))
    assert sorted(rows.tolist()) == [0, 2, 5]
    assert idx.point_lookup(ks, _enc(ks, 4, 1)).size == 0


# ---------------------------------------------------------------------------
# batched multi-query serving
# ---------------------------------------------------------------------------

def test_query_server_fuses_batch_into_one_eval():
    ks = _ks()
    rng = np.random.default_rng(8)
    vals = rng.integers(0, 200, 70)
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(8))
    server = db.QueryServer(ks, t, batch=4)
    truth = {}
    for i in range(4):
        lo, hi = sorted(rng.integers(0, 200, 2).tolist())
        qid = server.submit(db.Range("v", _enc(ks, lo, 100 + i),
                                     _enc(ks, hi, 200 + i)))
        truth[qid] = (vals >= lo) & (vals <= hi)
    results = server.run()
    assert len(server.batch_log) == 1
    # 4 queries, 8 atoms — ONE fused Eval for the whole batch
    assert server.batch_log[0].eval_calls == 1
    for qid, want in truth.items():
        np.testing.assert_array_equal(results[qid].mask, want)


def test_query_server_indexed_lanes():
    ks = _ks()
    rng = np.random.default_rng(9)
    vals = rng.integers(0, 200, 64)
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(9))
    idx = db.SortedIndex.build(ks, t, "v")
    server = db.QueryServer(ks, t, indexes={"v": idx}, batch=3)
    truth = {}
    for i in range(3):
        lo, hi = sorted(rng.integers(0, 200, 2).tolist())
        qid = server.submit(db.Range("v", _enc(ks, lo, 300 + i),
                                     _enc(ks, hi, 400 + i)))
        truth[qid] = (vals >= lo) & (vals <= hi)
    results = server.run()
    assert server.batch_log[0].eval_calls == 0     # all lanes via the index
    assert server.batch_log[0].index_compares > 0
    for qid, want in truth.items():
        np.testing.assert_array_equal(results[qid].mask, want)


def test_query_server_mixed_columns_and_topk():
    ks = _ks()
    rng = np.random.default_rng(10)
    vals = rng.integers(0, 200, 40)
    score = rng.integers(0, 200, 40)
    t = db.Table.from_arrays(ks, "t", {"v": vals, "s": score},
                             jax.random.PRNGKey(10))
    idx = db.SortedIndex.build(ks, t, "v")
    server = db.QueryServer(ks, t, indexes={"v": idx}, batch=2)
    q1 = db.Query(where=db.And(db.Range("v", _enc(ks, 30, 0), _enc(ks, 170, 1)),
                               db.Range("s", _enc(ks, 0, 2), _enc(ks, 120, 3))),
                  top_k=db.TopK("s", 4))
    q2 = db.Query(where=db.Eq("v", _enc(ks, int(vals[5]), 4)))
    id1, id2 = server.submit(q1), server.submit(q2)
    results = server.run()
    m1 = (vals >= 30) & (vals <= 170) & (score <= 120)
    np.testing.assert_array_equal(results[id1].mask, m1)
    want_top = sorted(score[m1].tolist(), reverse=True)[:4]
    assert score[results[id1].row_ids].tolist() == want_top
    np.testing.assert_array_equal(results[id2].mask, vals == vals[5])
