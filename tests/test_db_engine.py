"""repro.db engine: plan IR, fused executor, sorted index, batched serving.

Cross-scheme test matrix: every plan-equivalence test runs over BOTH the
bfv (integer, exact) and ckks (float, ε-tolerant) profiles via the
session-cached `scheme_ks` fixture from conftest.py.  On BFV the engine
must be *exact*; on CKKS the test data lives on a coarse value grid
(GRID) whose spacing dwarfs the profile's equality tolerance, so every
comparison decision is unambiguous and the expected masks are still
exact — approximate arithmetic with deterministic answers.  Range bounds
are placed off-grid (± GRID/2) so inclusivity at a bound is never
decided by noise.  ε-band equality gets its own tests with ε chosen so
band membership also has grid-sized margins.

Dataset slices keep CI time bounded; the full-row runs live in
benchmarks/db_engine.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import db
from repro.core import encrypt as E
from repro.core.ckks import equality_tolerance
from repro.data import DATASETS, load_dataset

GRID = 0.25        # ckks float grid (>> test-ckks equality tolerance ~0.016)
EPS_BAND = 0.3     # ε-band that captures exactly the ±1-grid-step neighbors


def _is_ckks(ks) -> bool:
    return ks.params.profile.scheme == "ckks"


def _vals(ks, ints) -> np.ndarray:
    """Scheme-native column values from an integer lattice."""
    ints = np.asarray(ints)
    if _is_ckks(ks):
        return ints.astype(np.float64) * GRID
    return ints.astype(np.int64)


def _enc(ks, v, seed):
    v = float(v) if _is_ckks(ks) else int(v)
    return E.encrypt(ks, jnp.asarray(v), jax.random.PRNGKey(seed))


def _bound(ks, v, side):
    """Range bound: off-grid under ckks so inclusivity is unambiguous."""
    return float(v) + side * GRID / 2 if _is_ckks(ks) else int(v)


def _dataset_rows(ks, name, n_rows):
    # ckks profiles have t=0 (no plaintext modulus); reduce the integer
    # lattice mod the full 65537 so the float leg sees a realistic spread
    t = ks.params.t or 65537
    vals = load_dataset(name, scheme="bfv", t=t)[:n_rows]
    return _vals(ks, vals)


def _decrypt_close(ks, got, want):
    got = np.asarray(got)
    if _is_ckks(ks):
        # bound decrypt error by the profile's precision claim
        return np.allclose(got, np.asarray(want, np.float64),
                           atol=equality_tolerance(ks.params))
    return got.tolist() == list(want)


# ---------------------------------------------------------------------------
# plan construction / compilation (scheme-independent — one profile)
# ---------------------------------------------------------------------------

def test_plan_compile_structure(bfv_engine_ks):
    ks = bfv_engine_ks
    r = db.Range("v", _enc(ks, 10, 0), _enc(ks, 20, 1))
    e = db.Eq("s", _enc(ks, 5, 2))
    plan = db.compile_plan(db.Query(where=db.And(r, e)))
    assert plan.num_leaves == 2
    assert plan.tree == ("and", [("leaf", 0), ("leaf", 1)])
    # Range lowers to 2 scan atoms, Eq to 1
    assert [a.op for a in plan.scan_atoms(0)] == [">=", "<="]
    assert [a.op for a in plan.scan_atoms(1)] == ["=="]


def test_plan_compile_dedups_repeated_leaves(bfv_engine_ks):
    ks = bfv_engine_ks
    r = db.Range("v", _enc(ks, 10, 0), _enc(ks, 20, 1))
    e1 = db.Eq("s", _enc(ks, 5, 2))
    e2 = db.Eq("s", _enc(ks, 6, 3))
    # r appears twice but compiles to ONE leaf
    plan = db.compile_plan(db.Or(db.And(r, e1), db.And(r, e2)))
    assert plan.num_leaves == 3
    assert plan.tree == ("or", [("and", [("leaf", 0), ("leaf", 1)]),
                                ("and", [("leaf", 0), ("leaf", 2)])])


def test_plan_eps_is_part_of_leaf_identity(bfv_engine_ks):
    ks = bfv_engine_ks
    ct = _enc(ks, 5, 0)
    # same trapdoor, different ε -> different predicates, no dedup
    plan = db.compile_plan(db.Or(db.Eq("v", ct, eps=0.1),
                                 db.Eq("v", ct, eps=0.2)))
    assert plan.num_leaves == 2
    # identical ε (and identical None) still dedups
    plan2 = db.compile_plan(db.Or(db.Eq("v", ct), db.Eq("v", ct)))
    assert plan2.num_leaves == 1
    # ε rides the lowered atoms
    assert plan.scan_atoms(0)[0].eps == 0.1


def test_predicate_operator_sugar(bfv_engine_ks):
    ks = bfv_engine_ks
    r = db.Range("v", _enc(ks, 10, 0), _enc(ks, 20, 1))
    e = db.Eq("v", _enc(ks, 5, 2))
    assert isinstance(r & e, db.And)
    assert isinstance(r | e, db.Or)
    assert isinstance(~r, db.Not)


def test_bare_predicate_compiles_to_query(bfv_engine_ks):
    plan = db.compile_plan(db.Eq("v", _enc(bfv_engine_ks, 5, 0)))
    assert plan.num_leaves == 1 and plan.tree == ("leaf", 0)
    assert plan.query.where is not None


# ---------------------------------------------------------------------------
# table
# ---------------------------------------------------------------------------

def test_table_pads_to_power_of_two_and_roundtrips(scheme_ks):
    ks = scheme_ks
    vals = _vals(ks, np.arange(50))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(0))
    assert t.n_rows == 50 and t.n_padded == 64
    assert t.valid.sum() == 50
    tol = equality_tolerance(ks.params)
    got = t.decrypt_column(ks, "v")
    if _is_ckks(ks):
        np.testing.assert_allclose(got, vals, atol=tol)
    else:
        np.testing.assert_array_equal(got, vals)
    # pad rows are genuine encryptions of 0
    full = t.decrypt_column(ks, "v", include_padding=True)
    assert np.all(np.abs(full[50:]) <= tol)


def test_table_rejects_ragged_columns(bfv_engine_ks):
    with pytest.raises(ValueError):
        db.Table.from_arrays(bfv_engine_ks, "t",
                             {"a": np.arange(4), "b": np.arange(5)},
                             jax.random.PRNGKey(0))


def test_table_rejects_fractional_floats_under_bfv(bfv_engine_ks):
    with pytest.raises(ValueError, match="ckks profile"):
        db.Table.from_arrays(bfv_engine_ks, "t",
                             {"a": np.asarray([1.0, 2.5, 3.0])},
                             jax.random.PRNGKey(0))
    # integral-valued floats are fine (no silent truncation possible)
    t = db.Table.from_arrays(bfv_engine_ks, "t",
                             {"a": np.asarray([1.0, 2.0, 3.0])},
                             jax.random.PRNGKey(0))
    assert t.n_rows == 3


# ---------------------------------------------------------------------------
# executor: fused linear scan (cross-scheme)
# ---------------------------------------------------------------------------

def test_multi_predicate_and_or_matches_plaintext(scheme_ks, rng):
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 200, 60))
    score = _vals(ks, rng.integers(0, 200, 60))
    t = db.Table.from_arrays(ks, "t", {"v": vals, "s": score},
                             jax.random.PRNGKey(1))
    b = lambda v, s: _bound(ks, _vals(ks, np.asarray(v)), s)  # noqa: E731
    q = db.Or(db.And(db.Range("v", _enc(ks, b(40, -1), 0),
                              _enc(ks, b(120, +1), 1)),
                     db.Range("s", _enc(ks, b(0, -1), 2),
                              _enc(ks, b(100, +1), 3))),
              db.Not(db.Range("v", _enc(ks, b(0, -1), 4),
                              _enc(ks, b(150, +1), 5))))
    res = db.execute(ks, t, q)
    lo40, hi120 = _vals(ks, 40), _vals(ks, 120)
    hi100, hi150, lo0 = _vals(ks, 100), _vals(ks, 150), _vals(ks, 0)
    want = (((vals >= lo40) & (vals <= hi120) & (score <= hi100))
            | ~((vals >= lo0) & (vals <= hi150)))
    np.testing.assert_array_equal(res.mask, want)
    # the whole 3-leaf predicate tree ran as ONE fused Eval
    assert res.stats.eval_calls == 1
    assert res.stats.scan_leaves == 3


@pytest.mark.parametrize("name", DATASETS)
def test_end_to_end_query_matches_plaintext(scheme_ks, rng, name):
    """And(Range, Eq) + TopK — plan answers match the plaintext reference
    on a slice of each paper dataset, on both schemes (acceptance: the
    ckks float path agrees within ε; grid data makes 'within ε' exact)."""
    ks = scheme_ks
    vals = _dataset_rows(ks, name, 96)
    aux = _vals(ks, rng.integers(0, 250, len(vals)))
    t = db.Table.from_arrays(ks, name, {"v": vals, "aux": aux},
                             jax.random.PRNGKey(2))
    lo = _bound(ks, np.percentile(vals, 20), -1)
    hi = _bound(ks, np.percentile(vals, 80), +1)
    if not _is_ckks(ks):
        lo, hi = int(lo), int(hi)
    eq_v = aux[0]
    q = db.Query(
        where=db.And(db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                     db.Eq("aux", _enc(ks, eq_v, 2))),
        top_k=db.TopK("v", 3), select=("v",))
    res = db.execute(ks, t, q)
    want_mask = (vals >= lo) & (vals <= hi) & (aux == eq_v)
    np.testing.assert_array_equal(res.mask, want_mask)
    want_top = sorted(vals[want_mask].tolist(), reverse=True)[:3]
    assert vals[res.row_ids].tolist() == want_top
    # projected ciphertexts decrypt to the selected rows
    assert _decrypt_close(ks, E.decrypt(ks, res.columns["v"]), want_top)


def test_indexed_and_linear_plans_agree_with_topk(scheme_ks, rng):
    """And(Range, Eq) + TopK: the indexed and linear execution paths must
    return the same mask and the same top-k value multiset (acceptance
    criterion for the ckks float path; ties may permute row ids)."""
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 400, 72))
    aux = _vals(ks, rng.integers(0, 8, 72))      # duplicate-heavy
    t = db.Table.from_arrays(ks, "t", {"v": vals, "aux": aux},
                             jax.random.PRNGKey(3))
    idx = db.SortedIndex.build(ks, t, "v")
    lo = _bound(ks, np.percentile(vals, 15), -1)
    hi = _bound(ks, np.percentile(vals, 85), +1)
    if not _is_ckks(ks):
        lo, hi = int(lo), int(hi)
    q = db.Query(
        where=db.And(db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                     db.Eq("aux", _enc(ks, aux[3], 2))),
        top_k=db.TopK("v", 4), select=("v",))
    lin = db.execute(ks, t, q)
    ind = db.execute(ks, t, q, indexes={"v": idx})
    want_mask = (vals >= lo) & (vals <= hi) & (aux == aux[3])
    np.testing.assert_array_equal(lin.mask, want_mask)
    np.testing.assert_array_equal(ind.mask, want_mask)
    want_top = sorted(vals[want_mask].tolist(), reverse=True)[:4]
    assert vals[lin.row_ids].tolist() == want_top
    assert vals[ind.row_ids].tolist() == want_top
    # the indexed path resolved Range via binary search, scanned only Eq
    assert ind.stats.indexed_leaves == 1 and ind.stats.scan_leaves == 1


def test_order_by_and_limit(scheme_ks):
    ks = scheme_ks
    vals = _vals(ks, np.asarray([40, 10, 99, 3, 77, 23, 55]))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(4))
    lo, hi = _bound(ks, _vals(ks, 5), -1), _bound(ks, _vals(ks, 90), +1)
    q = db.Query(where=db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                 order_by=db.OrderBy("v", descending=True),
                 limit=db.Limit(3))
    res = db.execute(ks, t, q)
    want = sorted(vals[(vals >= lo) & (vals <= hi)].tolist(),
                  reverse=True)[:3]
    assert vals[res.row_ids].tolist() == want


# ---------------------------------------------------------------------------
# ε-band equality (ckks float semantics)
# ---------------------------------------------------------------------------

def test_eps_band_eq_linear_and_indexed(scheme_ks, rng):
    """Eq(col, v, ε) selects |col - v| <= ε; the linear scan and the
    ε-aware index binary search agree with the plaintext band."""
    ks = scheme_ks
    if not _is_ckks(ks):
        pytest.skip("ε-band equality is a float-column (ckks) feature")
    vals = _vals(ks, rng.integers(0, 60, 48))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(5))
    idx = db.SortedIndex.build(ks, t, "v")
    target = vals[7]
    q = db.Eq("v", _enc(ks, target, 0), eps=EPS_BAND)
    lin = db.execute(ks, t, q)
    ind = db.execute(ks, t, q, indexes={"v": idx})
    want = np.abs(vals - target) <= EPS_BAND
    np.testing.assert_array_equal(lin.mask, want)
    np.testing.assert_array_equal(ind.mask, want)
    assert ind.stats.eval_calls == 0           # resolved entirely via index
    # the band is strictly wider than native equality
    native = db.execute(ks, t, db.Eq("v", _enc(ks, target, 0)))
    assert native.mask.sum() <= lin.mask.sum()
    np.testing.assert_array_equal(native.mask, vals == target)


def test_eps_inclusive_range_bounds(scheme_ks):
    """Range(lo, hi, ε) pulls in rows within ε outside the bounds."""
    ks = scheme_ks
    if not _is_ckks(ks):
        pytest.skip("ε-inclusive bounds are a float-column (ckks) feature")
    vals = np.asarray([0.0, 1.0, 1.2, 1.25, 2.0, 3.0, 3.05, 3.25, 4.0])
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(6))
    lo, hi = 1.25, 3.0
    exact = db.execute(ks, t, db.Range("v", _enc(ks, lo, 0),
                                       _enc(ks, hi, 1)))
    band = db.execute(ks, t, db.Range("v", _enc(ks, lo, 0),
                                      _enc(ks, hi, 1), eps=0.1))
    np.testing.assert_array_equal(exact.mask, (vals >= lo) & (vals <= hi))
    np.testing.assert_array_equal(
        band.mask, (vals >= lo - 0.1) & (vals <= hi + 0.1))


def test_eps_below_noise_floor_clamps_to_native_tau(scheme_ks):
    """An ε under the profile's equality tolerance cannot be resolved —
    it degrades to the native τ (documented contract of eps_to_tau)."""
    ks = scheme_ks
    tol = equality_tolerance(ks.params)
    assert db.eps_to_tau(ks.params, tol / 10) == ks.params.tau
    assert db.eps_to_tau(ks.params, 0.0) == ks.params.tau
    big = db.eps_to_tau(ks.params, tol * 8)
    assert big > ks.params.tau
    with pytest.raises(ValueError):
        db.eps_to_tau(ks.params, -1.0)


# ---------------------------------------------------------------------------
# sorted index (cross-scheme)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", DATASETS)
def test_indexed_equals_linear_range_query(scheme_ks, rng, name):
    ks = scheme_ks
    vals = _dataset_rows(ks, name, 80)
    t = db.Table.from_arrays(ks, name, {"v": vals}, jax.random.PRNGKey(5))
    idx = db.SortedIndex.build(ks, t, "v")
    np.testing.assert_array_equal(vals[idx.perm], np.sort(vals))
    for i in range(3):
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        lo, hi = _bound(ks, lo, -1), _bound(ks, hi, +1)
        if not _is_ckks(ks):
            lo, hi = int(lo), int(hi)
        q = db.Range("v", _enc(ks, lo, 10 + i), _enc(ks, hi, 20 + i))
        lin = db.execute(ks, t, q)
        ind = db.execute(ks, t, q, indexes={"v": idx})
        np.testing.assert_array_equal(lin.mask, ind.mask)
        np.testing.assert_array_equal(
            ind.mask, (vals >= lo) & (vals <= hi))
        assert ind.stats.eval_calls == 0          # no linear scan at all
        assert ind.stats.index_compares <= 2 * (int(np.ceil(
            np.log2(len(vals)))) + 1)


def test_index_point_lookup_duplicates(scheme_ks):
    ks = scheme_ks
    vals = _vals(ks, np.asarray([7, 3, 7, 1, 9, 7, 3, 2, 8]))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(7))
    idx = db.SortedIndex.build(ks, t, "v")
    rows = idx.point_lookup(ks, _enc(ks, _vals(ks, 7), 0))
    assert sorted(rows.tolist()) == [0, 2, 5]
    assert idx.point_lookup(ks, _enc(ks, _vals(ks, 4), 1)).size == 0


# ---------------------------------------------------------------------------
# batched multi-query serving (cross-scheme)
# ---------------------------------------------------------------------------

def test_query_server_fuses_batch_into_one_eval(scheme_ks, rng):
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 200, 70))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(8))
    server = db.QueryServer(ks, t, batch=4)
    truth = {}
    for i in range(4):
        a, b = sorted(rng.integers(0, 200, 2).tolist())
        lo = _bound(ks, _vals(ks, a), -1)
        hi = _bound(ks, _vals(ks, b), +1)
        qid = server.submit(db.Range("v", _enc(ks, lo, 100 + i),
                                     _enc(ks, hi, 200 + i)))
        truth[qid] = (vals >= lo) & (vals <= hi)
    results = server.run()
    assert len(server.batch_log) == 1
    # 4 queries, 8 atoms — ONE fused Eval for the whole batch
    assert server.batch_log[0].eval_calls == 1
    for qid, want in truth.items():
        np.testing.assert_array_equal(results[qid].mask, want)


def test_query_server_indexed_lanes(scheme_ks, rng):
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 200, 64))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(9))
    idx = db.SortedIndex.build(ks, t, "v")
    server = db.QueryServer(ks, t, indexes={"v": idx}, batch=3)
    truth = {}
    for i in range(3):
        a, b = sorted(rng.integers(0, 200, 2).tolist())
        lo = _bound(ks, _vals(ks, a), -1)
        hi = _bound(ks, _vals(ks, b), +1)
        qid = server.submit(db.Range("v", _enc(ks, lo, 300 + i),
                                     _enc(ks, hi, 400 + i)))
        truth[qid] = (vals >= lo) & (vals <= hi)
    results = server.run()
    assert server.batch_log[0].eval_calls == 0     # all lanes via the index
    assert server.batch_log[0].index_compares > 0
    for qid, want in truth.items():
        np.testing.assert_array_equal(results[qid].mask, want)


def test_query_server_counters_reconcile(scheme_ks, rng):
    """Per-query compare lanes sum exactly to the batch totals, on a
    batch mixing indexed lanes and fused-scan atoms (eval_calls is a
    per-query SHARE of the one launch, deliberately not summable)."""
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 200, 48))
    aux = _vals(ks, rng.integers(0, 200, 48))
    t = db.Table.from_arrays(ks, "t", {"v": vals, "a": aux},
                             jax.random.PRNGKey(21))
    idx = db.SortedIndex.build(ks, t, "v")
    server = db.QueryServer(ks, t, indexes={"v": idx}, batch=3)
    qids = []
    for i in range(2):
        a, b = sorted(rng.integers(0, 200, 2).tolist())
        lo = _bound(ks, _vals(ks, a), -1)
        hi = _bound(ks, _vals(ks, b), +1)
        qids.append(server.submit(db.Range("v", _enc(ks, lo, 700 + i),
                                           _enc(ks, hi, 800 + i))))
    qids.append(server.submit(db.Eq("a", _enc(ks, aux[3], 900))))
    results = server.run()
    b = server.batch_log[-1]
    assert sum(results[q].stats.index_compares
               for q in qids) == b.index_compares
    assert sum(results[q].stats.scan_compares
               for q in qids) == b.scan_compares
    assert b.index_compares > 0 and b.scan_compares > 0


def test_query_server_mixed_columns_and_topk(scheme_ks, rng):
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 200, 40))
    score = _vals(ks, rng.integers(0, 200, 40))
    t = db.Table.from_arrays(ks, "t", {"v": vals, "s": score},
                             jax.random.PRNGKey(10))
    idx = db.SortedIndex.build(ks, t, "v")
    server = db.QueryServer(ks, t, indexes={"v": idx}, batch=2)
    lo30, hi170 = _bound(ks, _vals(ks, 30), -1), _bound(ks, _vals(ks, 170), +1)
    lo0, hi120 = _bound(ks, _vals(ks, 0), -1), _bound(ks, _vals(ks, 120), +1)
    q1 = db.Query(where=db.And(db.Range("v", _enc(ks, lo30, 0),
                                        _enc(ks, hi170, 1)),
                               db.Range("s", _enc(ks, lo0, 2),
                                        _enc(ks, hi120, 3))),
                  top_k=db.TopK("s", 4))
    q2 = db.Query(where=db.Eq("v", _enc(ks, vals[5], 4)))
    id1, id2 = server.submit(q1), server.submit(q2)
    results = server.run()
    m1 = (vals >= lo30) & (vals <= hi170) & (score <= hi120)
    np.testing.assert_array_equal(results[id1].mask, m1)
    want_top = sorted(score[m1].tolist(), reverse=True)[:4]
    assert score[results[id1].row_ids].tolist() == want_top
    np.testing.assert_array_equal(results[id2].mask, vals == vals[5])


def test_query_server_eps_band_lanes(scheme_ks, rng):
    """A batch mixing an ε-band Eq lane with an exact Range lane: both
    ride one lane-batched search, each lane under its own τ."""
    ks = scheme_ks
    if not _is_ckks(ks):
        pytest.skip("ε-band lanes are a float-column (ckks) feature")
    vals = _vals(ks, rng.integers(0, 50, 56))    # duplicate-heavy grid
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(11))
    idx = db.SortedIndex.build(ks, t, "v")
    server = db.QueryServer(ks, t, indexes={"v": idx}, batch=2)
    target = vals[9]
    lo = _bound(ks, np.percentile(vals, 30), -1)
    hi = _bound(ks, np.percentile(vals, 70), +1)
    id1 = server.submit(db.Eq("v", _enc(ks, target, 0), eps=EPS_BAND))
    id2 = server.submit(db.Range("v", _enc(ks, lo, 1), _enc(ks, hi, 2)))
    results = server.run()
    assert server.batch_log[0].eval_calls == 0     # all lanes via the index
    np.testing.assert_array_equal(results[id1].mask,
                                  np.abs(vals - target) <= EPS_BAND)
    np.testing.assert_array_equal(results[id2].mask,
                                  (vals >= lo) & (vals <= hi))
