"""The DESIGN.md §1.1 finding, quantified: paper-mode correctness vs
e_cek density (the correctness/security tension of a 1-poly CEK)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params


def _error_rate(weight, n_pairs=48):
    params = make_params("test-bfv", mode="paper")
    ks = keygen(params, jax.random.PRNGKey(0), paper_ecek_weight=weight)
    a = jnp.arange(n_pairs, dtype=jnp.int64)
    b = a + 5
    ct_a = E.encrypt(ks, a, jax.random.PRNGKey(1))
    ct_b = E.encrypt(ks, b, jax.random.PRNGKey(2))
    out = np.asarray(C.compare(ks, ct_a, ct_b))
    return float((out != -1).mean())


def test_error_rate_grows_with_ecek_density():
    r0 = _error_rate(0)
    r_full = _error_rate(None if False else 256)   # full density (n=256)
    assert r0 == 0.0
    assert r_full > 0.3, r_full


def test_single_nonzero_coefficient_already_hurts():
    """Even ONE noise coefficient makes <e_cek, ctΔ1> wrap mod q —
    the precondition effectively forces e_cek = 0."""
    r1 = _error_rate(1)
    assert r1 > 0.2, r1


def test_gadget_mode_correct_at_full_noise():
    """The beyond-paper gadget CEK: full-strength noise AND correct."""
    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(0))
    a = jnp.arange(48, dtype=jnp.int64)
    b = a + 5
    ct_a = E.encrypt(ks, a, jax.random.PRNGKey(1))
    ct_b = E.encrypt(ks, b, jax.random.PRNGKey(2))
    out = np.asarray(C.compare(ks, ct_a, ct_b))
    assert (out == -1).all()
