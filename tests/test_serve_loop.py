"""The always-on serving loop: admission, scheduling, ordering, faults.

Covers the `repro.db.serve_loop.ServeLoop` contract, on the plain AND
sharded servers (the multi-device CI job re-runs this file on 8 host
devices):

  * admission control — per-tenant + total queue caps and tenant ACLs
    produce explicit REJECTED responses, never unbounded queuing;
  * two-class deadline-aware scheduling — point batches draft before
    bulk, bulk never starves, expired requests SHED at batch formation,
    late completions flagged `deadline_missed`;
  * pow2 bucketing + fair-share drafting — batch sizes are powers of
    two, chatty tenants capped, per-tenant FIFO preserved;
  * ordering — mutations are admission-order barriers: every query
    sees exactly the writes admitted before it;
  * answers byte-identical to plain `QueryServer.submit`/`run`;
  * fault isolation — a poisoned plan or transient device error fails
    only its own request; everyone else is answered and obs counters
    stay reconciled;
  * per-tenant counter reconciliation — per-tenant `server.queries` /
    `server.compares` / `serve.*` sums equal loop totals (extends the
    PR 7 reconciliation suite to the loop);
  * jit-cache stability — steady-state `jit.retraces` delta is 0 once
    the pow2 buckets are warm.

Property tests (hypothesis when available, seeded deterministic sweep
otherwise — collection and tier-1 must survive without hypothesis)
drive random arrival sequences through the loop and assert the
no-starvation / FIFO / byte-identical / read-your-admitted-writes
invariants.
"""
import threading
import time

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # collection must survive without hypothesis
    HAVE_HYPOTHESIS = False

from repro import db, obs
from repro.core import encrypt as E
from repro.db import plan as P
from repro.db.serve_loop import (
    BULK, FAILED, OK, PENDING, POINT, REJECTED, SHED, WRITE,
    AdmissionPolicy, Response, ServeLoop,
)

VALS = np.array([3, 14, 15, 9, 26, 5, 35, 8, 97, 93, 23, 84], np.int64)


def _enc(ks, v, seed):
    return E.encrypt(ks, np.int64(int(v)), jax.random.PRNGKey(seed))


def _table(ks, vals=VALS, name="t"):
    return db.Table.from_arrays(ks, name, {"v": np.asarray(vals, np.int64)},
                                jax.random.PRNGKey(2))


# read-only (table, indexes, ciphertext pool) shared across tests — one
# encrypted sort + a handful of encryptions per keyset, not per test
_ENV = {}


def _env(ks):
    if id(ks) not in _ENV:
        table = _table(ks, name="t_loop")
        indexes = {"v": db.SortedIndex.build(ks, table, "v")}
        pool = {int(v): _enc(ks, int(v), 7000 + i)
                for i, v in enumerate(VALS)}
        _ENV[id(ks)] = (table, indexes, pool)
    return _ENV[id(ks)]


def _mk_loop(ks, *, index=True, policy=None, batch=8, clock=time.monotonic,
             **kw):
    table, indexes, pool = _env(ks)
    server = db.QueryServer(ks, table, indexes=indexes if index else {},
                            batch=batch)
    loop = ServeLoop(policy=policy, batch=batch, clock=clock, **kw)
    loop.register("t", server)
    return loop, server, table, pool


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_tenant_queue_cap_rejects_explicitly(bfv_engine_ks):
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(
        ks, policy=AdmissionPolicy(tenant_queue_cap=2))
    t1 = loop.submit("alice", "t", db.Eq("v", pool[15]))
    t2 = loop.submit("alice", "t", db.Eq("v", pool[26]))
    t3 = loop.submit("alice", "t", db.Eq("v", pool[35]))
    assert loop.response(t1).status == PENDING
    assert loop.response(t2).status == PENDING
    r3 = loop.response(t3)
    assert r3.status == REJECTED and r3.done
    assert "queue full" in r3.error
    assert loop.stats.rejected == 1 and loop.stats.admitted == 2
    assert loop.queue_depth("alice") == 2      # the reject never queued
    res = loop.run_until_idle()
    assert res[t1].status == OK and res[t2].status == OK
    assert res[t3].status == REJECTED          # terminal states persist


def test_total_queue_cap_rejects_across_tenants(bfv_engine_ks):
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(
        ks, policy=AdmissionPolicy(total_queue_cap=2))
    loop.submit("alice", "t", db.Eq("v", pool[15]))
    loop.submit("bob", "t", db.Eq("v", pool[26]))
    t3 = loop.submit("carol", "t", db.Eq("v", pool[35]))
    r3 = loop.response(t3)
    assert r3.status == REJECTED and "loop queue full" in r3.error


def test_tenant_acl_gates_per_tenant_tables(bfv_engine_ks):
    """Per-tenant KeySets ride per-tenant registrations: a table ACLed
    to alice rejects bob at admission, before any ciphertext touches
    bob's request."""
    ks = bfv_engine_ks
    table, indexes, pool = _env(ks)
    loop = ServeLoop()
    loop.register("alice_t", db.QueryServer(ks, table, indexes=indexes),
                  tenants=("alice",))
    ta = loop.submit("alice", "alice_t", db.Eq("v", pool[15]))
    tb = loop.submit("bob", "alice_t", db.Eq("v", pool[15]))
    rb = loop.response(tb)
    assert rb.status == REJECTED and "not authorized" in rb.error
    res = loop.run_until_idle()
    assert res[ta].status == OK
    assert len(res[ta].result.row_ids) == 1


def test_unknown_table_raises(bfv_engine_ks):
    loop = ServeLoop()
    with pytest.raises(KeyError):
        loop.submit("alice", "nope", db.Eq("v", None))


def test_join_on_sharded_server_rejected_explicitly(bfv_engine_ks):
    ks = bfv_engine_ks
    table, _, pool = _env(ks)
    stable = db.ShardedTable.from_table(ks, table,
                                        spec=db.ShardSpec.create(2))
    loop = ServeLoop()
    loop.register("sh", db.ShardedQueryServer(ks, stable))
    t = loop.submit_join("alice", "sh", db.Join(None, None, on="v"), table)
    r = loop.response(t)
    assert r.status == REJECTED and "does not support joins" in r.error
    assert loop.queue_depth() == 0 and loop.stats.admitted == 0
    # the rejection is atomic at admission: never enqueued, never
    # drafted, terminal counters reconcile (no double counting)
    assert loop.stats.submitted == loop.stats.rejected == 1
    assert loop.stats.failed == 0 and loop.batch_shapes == []


def test_unknown_klass_override_raises(bfv_engine_ks):
    """A klass outside {point, bulk} would pend forever (no pump drafts
    it) — submit() refuses it up front, admitting nothing."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    with pytest.raises(ValueError, match="klass"):
        loop.submit("a", "t", db.Eq("v", pool[15]), klass="interactive")
    assert loop.stats.submitted == 0 and loop.queue_depth() == 0
    loop.submit("a", "t", db.Eq("v", pool[15]), klass=BULK)  # valid override
    assert all(r.status == OK
               for r in loop.run_until_idle().values())


# ---------------------------------------------------------------------------
# classification + scheduling
# ---------------------------------------------------------------------------

def test_classification_point_vs_bulk(bfv_engine_ks):
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    tp = loop.submit("a", "t", db.Eq("v", pool[15]))
    tr = loop.submit("a", "t", P.Query(where=db.Range("v", pool[3],
                                                      pool[26]),
                                       top_k=db.TopK("v", 2)))
    ts = loop.submit("a", "t", P.Query())                   # select-all scan
    to = loop.submit("a", "t", db.Eq("v", pool[15]), klass=BULK)
    assert loop.response(tp).klass == POINT
    assert loop.response(tr).klass == BULK     # top-k pays a sort network
    assert loop.response(ts).klass == BULK     # select-all = full scan
    assert loop.response(to).klass == BULK     # explicit override wins
    loop.run_until_idle()


def test_unindexed_leaf_classifies_bulk(bfv_engine_ks):
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks, index=False)
    t = loop.submit("a", "t", db.Eq("v", pool[15]))
    assert loop.response(t).klass == BULK


def test_point_batch_drafts_before_bulk(bfv_engine_ks):
    """The deadline-sensitive class never waits behind a scan: even
    when the bulk request was submitted FIRST, the pump runs the point
    batch first."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    tb = loop.submit("a", "t", db.Range("v", pool[3], pool[97]),
                     klass=BULK)
    tp = loop.submit("a", "t", db.Eq("v", pool[15]))
    res = loop.run_until_idle()
    assert res[tb].status == OK and res[tp].status == OK
    klasses = [k for (_, k, _) in loop.batch_shapes]
    assert klasses == [POINT, BULK]
    assert res[tp].start_t <= res[tb].start_t


def test_bulk_is_not_starved_by_point_traffic(bfv_engine_ks):
    """Every pump drafts one bulk batch too — a scan admitted behind a
    pile of point lookups completes within the first pump."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks, batch=4)
    for i in range(8):
        loop.submit("a", "t", db.Eq("v", pool[int(VALS[i % 12])]))
    tb = loop.submit("a", "t", db.Range("v", pool[3], pool[97]),
                     klass=BULK)
    loop.pump()
    assert loop.response(tb).status == OK
    res = loop.run_until_idle()
    assert all(r.status == OK for r in res.values())


def test_pow2_bucketing_of_batch_sizes(bfv_engine_ks):
    """7 pending requests draft as 4 + 2 + 1 — every launch shape comes
    from the closed pow2 set, so the jit cache stays hot."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks, batch=8)
    for i in range(7):
        loop.submit("a", "t", db.Eq("v", pool[int(VALS[i])]))
    res = loop.run_until_idle()
    assert [s for (_, _, s) in loop.batch_shapes] == [4, 2, 1]
    assert all(r.status == OK for r in res.values())


def test_fair_share_caps_chatty_tenant(bfv_engine_ks):
    """fair_share=2: a tenant with 6 pending gets at most 2 slots of a
    contended batch, so the quiet tenant's 2 requests ride the FIRST
    batch instead of queuing behind all 6."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(
        ks, policy=AdmissionPolicy(fair_share=2), batch=8)
    chatty = [loop.submit("a", "t", db.Eq("v", pool[int(VALS[i])]))
              for i in range(6)]
    quiet = [loop.submit("b", "t", db.Eq("v", pool[97])),
             loop.submit("b", "t", db.Eq("v", pool[93]))]
    loop.pump()
    assert all(loop.response(t).status == OK for t in quiet)
    assert sum(loop.response(t).status == OK for t in chatty) == 2
    res = loop.run_until_idle()
    assert all(r.status == OK for r in res.values())


def test_deadline_shed_before_execution(bfv_engine_ks):
    """A request whose deadline passed while queued is SHED at batch
    formation — the engine never runs it."""
    ks = bfv_engine_ks
    clock = FakeClock()
    loop, server, _, pool = _mk_loop(ks, clock=clock)
    t = loop.submit("a", "t", db.Eq("v", pool[15]), deadline=5.0)
    clock.advance(6.0)
    loop.pump()
    r = loop.response(t)
    assert r.status == SHED and r.done and "deadline" in r.error
    assert loop.stats.shed == 1 and loop.stats.served == 0
    assert server.batch_log == []              # nothing reached the engine


def test_deadline_miss_flagged_on_late_completion(bfv_engine_ks):
    """A request drafted in time but finished late is answered, with
    `deadline_missed=True` and a per-tenant deadline-miss count."""
    ks = bfv_engine_ks
    clock = FakeClock()
    loop, server, _, pool = _mk_loop(ks, clock=clock)
    orig = server.run

    def slow_run():
        clock.advance(10.0)
        return orig()

    server.run = slow_run
    t = loop.submit("a", "t", db.Eq("v", pool[15]), deadline=5.0)
    with obs.tracing():
        loop.pump()
    r = loop.response(t)
    assert r.status == OK and r.deadline_missed
    assert len(r.result.row_ids) == 1          # still a real answer
    assert loop.stats.deadline_miss == 1
    assert obs.REGISTRY.value("serve.deadline_miss", tenant="a") == 1


def test_writes_are_never_shed(bfv_engine_ks):
    """Shedding an admitted write would break read-your-admitted-writes
    for every later query, so deadlines do not shed the write class."""
    ks = bfv_engine_ks
    clock = FakeClock()
    table = _table(ks, name="t_ws")
    loop = ServeLoop(clock=clock)
    loop.register("t", db.QueryServer(ks, table))
    t = loop.submit_insert("a", "t", {"v": np.array([41], np.int64)},
                           jax.random.PRNGKey(9), deadline=1.0)
    clock.advance(5.0)
    res = loop.run_until_idle()
    assert res[t].status == OK and res[t].result.kind == "insert"
    assert loop.stats.shed == 0


# ---------------------------------------------------------------------------
# ordering: mutations are admission-order barriers
# ---------------------------------------------------------------------------

def test_query_sees_exactly_the_writes_admitted_before_it(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, name="t_rw")
    loop = ServeLoop()
    loop.register("t", db.QueryServer(ks, table))
    ct = _enc(ks, 41, 901)
    q_before = loop.submit("a", "t", db.Eq("v", ct))
    loop.submit_insert("a", "t", {"v": np.array([41], np.int64)},
                       jax.random.PRNGKey(10))
    q_after = loop.submit("a", "t", db.Eq("v", ct))
    res = loop.run_until_idle()
    assert len(res[q_before].result.row_ids) == 0
    assert len(res[q_after].result.row_ids) == 1


def test_write_barrier_splits_batches(bfv_engine_ks):
    """query, write, query admitted in order run as three separate
    drains — the two-class reordering never crosses a barrier."""
    ks = bfv_engine_ks
    table = _table(ks, name="t_bar")
    loop = ServeLoop()
    loop.register("t", db.QueryServer(ks, table))
    loop.submit("a", "t", db.Eq("v", _enc(ks, 15, 902)))
    loop.submit_insert("a", "t", {"v": np.array([55], np.int64)},
                       jax.random.PRNGKey(11))
    loop.submit("a", "t", db.Eq("v", _enc(ks, 55, 903)))
    res = loop.run_until_idle()
    assert [(k, s) for (_, k, s) in loop.batch_shapes] == \
        [(BULK, 1), (WRITE, 1), (BULK, 1)]
    assert all(r.status == OK for r in res.values())


def test_fifo_within_tenant_class(bfv_engine_ks):
    """Within one (tenant, class) the engine receives requests in
    submit order, across multiple drafted batches."""
    ks = bfv_engine_ks
    loop, server, _, pool = _mk_loop(ks, batch=2)
    received = []
    orig = server.submit

    def recording_submit(query, *, tenant=None):
        received.append(id(query))
        return orig(query, tenant=tenant)

    server.submit = recording_submit
    submitted = []
    for i in range(5):
        q = P.Query(where=db.Eq("v", pool[int(VALS[i])]))
        submitted.append(id(q))
        loop.submit("a", "t", q)
    res = loop.run_until_idle()
    assert received == submitted
    assert all(r.status == OK for r in res.values())


# ---------------------------------------------------------------------------
# answers byte-identical to the plain server
# ---------------------------------------------------------------------------

def test_answers_match_plain_query_server(bfv_engine_ks):
    ks = bfv_engine_ks
    table, indexes, pool = _env(ks)
    plans = [P.Query(where=db.Eq("v", pool[15])),
             P.Query(where=db.Range("v", pool[5], pool[35])),
             P.Query(where=db.Or(db.Eq("v", pool[97]),
                                 db.Range("v", pool[3], pool[9])))]
    loop, _, _, _ = _mk_loop(ks)
    tickets = [loop.submit("a", "t", q) for q in plans]
    res = loop.run_until_idle()
    plain = db.QueryServer(ks, table, indexes=indexes, batch=len(plans))
    qids = [plain.submit(q) for q in plans]
    want = plain.run()
    for t, q in zip(tickets, qids):
        np.testing.assert_array_equal(res[t].result.row_ids,
                                      want[q].row_ids)
        np.testing.assert_array_equal(res[t].result.mask, want[q].mask)


def test_join_through_loop_matches_execute_join(bfv_engine_ks):
    ks = bfv_engine_ks
    table, _, pool = _env(ks)
    right = db.Table.from_arrays(
        ks, "t_r", {"v": VALS[:6]}, jax.random.PRNGKey(3))
    j = db.Join(None, None, on="v")
    loop, _, _, _ = _mk_loop(ks)
    t = loop.submit_join("a", "t", j, right, strategy="nested")
    res = loop.run_until_idle()
    want = db.execute_join(ks, table, right, j, strategy="nested")
    np.testing.assert_array_equal(res[t].result.pairs, want.pairs)
    assert res[t].klass == BULK


def test_sharded_loop_matches_plain(bfv_engine_ks):
    """The loop over a ShardedQueryServer answers exactly like the
    plain server over the same rows (runs at 1 and 8 devices)."""
    ks = bfv_engine_ks
    table, indexes, pool = _env(ks)
    stable = db.ShardedTable.from_table(ks, table,
                                        spec=db.ShardSpec.create(2))
    sidx = {"v": db.ShardedIndex.build(ks, stable, "v")}
    loop = ServeLoop()
    loop.register("sh", db.ShardedQueryServer(ks, stable, indexes=sidx))
    plans = [P.Query(where=db.Eq("v", pool[15])),
             P.Query(where=db.Range("v", pool[5], pool[35]))]
    tickets = [loop.submit("a", "sh", q) for q in plans]
    res = loop.run_until_idle()
    plain = db.QueryServer(ks, table, indexes=indexes, batch=2)
    qids = [plain.submit(q) for q in plans]
    want = plain.run()
    for t, q in zip(tickets, qids):
        got_rows = np.sort(np.asarray(res[t].result.row_ids))
        np.testing.assert_array_equal(got_rows,
                                      np.sort(want[q].row_ids))
    assert all(loop.response(t).klass == POINT for t in tickets)


# ---------------------------------------------------------------------------
# fault isolation
# ---------------------------------------------------------------------------

def test_poisoned_plan_fails_alone(bfv_engine_ks):
    """A plan naming a nonexistent column fails ITS request; the other
    requests in the same drafted batch are still answered and the loop
    keeps serving afterwards."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    good1 = loop.submit("a", "t", db.Range("v", pool[3], pool[97]),
                        klass=BULK)
    bad = loop.submit("b", "t", db.Eq("nope", pool[15]))
    good2 = loop.submit("a", "t", db.Range("v", pool[5], pool[35]),
                        klass=BULK)
    res = loop.run_until_idle()
    assert res[bad].status == FAILED and "nope" in res[bad].error
    assert res[good1].status == OK and res[good2].status == OK
    assert len(res[good1].result.row_ids) == len(VALS)
    assert loop.stats.failed == 1 and loop.stats.served == 2
    later = loop.submit("b", "t", db.Eq("v", pool[26]))
    assert loop.run_until_idle()[later].status == OK


def test_transient_device_error_recovers_everyone(bfv_engine_ks):
    """A device error that poisons one collective drain but not the
    per-request retries loses NO requests."""
    ks = bfv_engine_ks
    from repro.db import executor as X
    loop, _, _, pool = _mk_loop(ks, index=False)
    tickets = [loop.submit("a", "t", db.Eq("v", pool[int(VALS[i])]))
               for i in range(4)]
    orig, boom = X.fused_eval, {"left": 1}

    def flaky(*args, **kw):
        if boom["left"]:
            boom["left"] -= 1
            raise RuntimeError("XLA device lost (injected)")
        return orig(*args, **kw)

    X.fused_eval = flaky
    try:
        res = loop.run_until_idle()
    finally:
        X.fused_eval = orig
    assert all(res[t].status == OK for t in tickets)
    assert loop.stats.failed == 0 and loop.stats.served == 4


def test_persistent_fault_isolates_and_counters_reconcile(bfv_engine_ks):
    """With obs live, a batch where one request keeps failing bills
    exactly the served requests: per-tenant server.queries sums equal
    loop served totals, serve.failed equals loop failed totals."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    ga = loop.submit("alice", "t", db.Range("v", pool[3], pool[97]),
                     klass=BULK)
    bb = loop.submit("bob", "t", db.Eq("nope", pool[15]))
    gb = loop.submit("bob", "t", db.Range("v", pool[5], pool[35]),
                     klass=BULK)
    with obs.tracing():
        res = loop.run_until_idle()
        reg = obs.REGISTRY
        billed = (reg.value("server.queries", tenant="alice")
                  + reg.value("server.queries", tenant="bob"))
        assert billed == loop.stats.served == 2
        assert reg.value("serve.failed", tenant="bob") == \
            loop.stats.failed == 1
    assert res[ga].status == OK and res[gb].status == OK
    assert res[bb].status == FAILED


def test_failed_write_does_not_poison_loop(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, name="t_fw")
    loop = ServeLoop()
    loop.register("t", db.QueryServer(ks, table))
    bad = loop.submit_insert("a", "t", {"wrong_col": np.array([1])},
                             jax.random.PRNGKey(12))
    good = loop.submit("a", "t", db.Eq("v", _enc(ks, 15, 904)))
    res = loop.run_until_idle()
    assert res[bad].status == FAILED and res[bad].error
    assert res[good].status == OK


# ---------------------------------------------------------------------------
# per-tenant counter reconciliation under the loop (extends PR 7 suite)
# ---------------------------------------------------------------------------

def _reconcile(loop, res, tenants):
    """Per-tenant registry counters must sum to loop totals."""
    reg = obs.REGISTRY
    served_reads = sum(1 for r in res.values()
                       if r.status == OK and r.klass != WRITE)
    assert sum(reg.value("server.queries", tenant=t)
               for t in tenants) == served_reads
    for t in tenants:
        want = sum(r.result.stats.filter_compares for r in res.values()
                   if r.tenant == t and r.status == OK
                   and r.klass != WRITE)
        assert reg.value("server.compares", tenant=t) == want
    assert sum(reg.value("serve.shed", tenant=t)
               for t in tenants) == loop.stats.shed
    assert sum(reg.value("serve.deadline_miss", tenant=t)
               for t in tenants) == loop.stats.deadline_miss


def test_per_tenant_reconciliation_plain_server(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, name="t_rec")
    indexes = {"v": db.SortedIndex.build(ks, table, "v")}
    loop = ServeLoop()
    loop.register("t", db.QueryServer(ks, table, indexes=indexes))
    with obs.tracing():
        loop.submit("alice", "t", db.Eq("v", _enc(ks, 15, 905)))
        loop.submit("bob", "t", db.Range("v", _enc(ks, 3, 906),
                                         _enc(ks, 97, 907)), klass=BULK)
        loop.submit_insert("alice", "t", {"v": np.array([60], np.int64)},
                           jax.random.PRNGKey(13))
        loop.submit("bob", "t", db.Eq("v", _enc(ks, 60, 908)))
        res = loop.run_until_idle()
        _reconcile(loop, res, ("alice", "bob"))
    assert all(r.status == OK for r in res.values())


def test_per_tenant_reconciliation_sharded_server(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, name="t_recs")
    stable = db.ShardedTable.from_table(ks, table,
                                        spec=db.ShardSpec.create(2))
    sidx = {"v": db.ShardedIndex.build(ks, stable, "v")}
    loop = ServeLoop()
    loop.register("sh", db.ShardedQueryServer(ks, stable, indexes=sidx))
    with obs.tracing():
        loop.submit("alice", "sh", db.Eq("v", _enc(ks, 15, 909)))
        loop.submit("bob", "sh", db.Range("v", _enc(ks, 3, 910),
                                          _enc(ks, 97, 911)), klass=BULK)
        loop.submit("alice", "sh", db.Eq("v", _enc(ks, 26, 912)))
        res = loop.run_until_idle()
        _reconcile(loop, res, ("alice", "bob"))
    assert all(r.status == OK for r in res.values())


def test_shed_and_miss_reconcile_per_tenant(bfv_engine_ks):
    ks = bfv_engine_ks
    clock = FakeClock()
    loop, _, _, pool = _mk_loop(ks, clock=clock)
    with obs.tracing():
        loop.submit("alice", "t", db.Eq("v", pool[15]), deadline=1.0)
        loop.submit("bob", "t", db.Eq("v", pool[26]))
        clock.advance(2.0)
        res = loop.run_until_idle()
        _reconcile(loop, res, ("alice", "bob"))
    assert loop.stats.shed == 1


# ---------------------------------------------------------------------------
# obs integration + jit-cache stability
# ---------------------------------------------------------------------------

def test_queue_depth_wait_and_spans_observed(bfv_engine_ks):
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    with obs.tracing():
        loop.submit("a", "t", db.Eq("v", pool[15]))
        loop.submit("a", "t", db.Range("v", pool[3], pool[97]),
                    klass=BULK)
        loop.run_until_idle()
        dump = obs.metrics_dump()["metrics"]
        assert any(k.startswith("serve.queue_depth") for k in dump)
        assert any(k.startswith("serve.queue_wait_s") for k in dump)
        spans = {s.name for s in obs.TRACER.spans}
        assert "serve.pump" in spans and "serve.batch" in spans
        batch_spans = [s for s in obs.TRACER.spans
                       if s.name == "serve.batch"]
        assert {s.args["klass"] for s in batch_spans} == {POINT, BULK}
        assert obs.validate_chrome_trace(obs.chrome_trace()) == []


def test_jit_retraces_zero_in_steady_state(bfv_engine_ks):
    """Once a warmup wave has visited every pow2 bucket, an identical
    steady-state wave adds ZERO jit retraces — the bucketing's whole
    point."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks, batch=4)

    def wave():
        for i in range(7):
            loop.submit("a", "t", db.Eq("v", pool[int(VALS[i])]))
        loop.run_until_idle()

    with obs.tracing():
        wave()                                     # warm 4/2/1 buckets
        mark = obs.REGISTRY.value("jit.retraces")
        wave()                                     # steady state
        assert obs.REGISTRY.value("jit.retraces") == mark


def test_background_thread_serves_and_stops(bfv_engine_ks):
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    loop.start(interval_s=0.001)
    try:
        tickets = [loop.submit("a", "t", db.Eq("v", pool[int(VALS[i])]))
                   for i in range(3)]
        deadline = time.monotonic() + 120.0
        while (any(not loop.response(t).done for t in tickets)
               and time.monotonic() < deadline):
            time.sleep(0.01)
    finally:
        loop.stop()
    assert all(loop.response(t).status == OK for t in tickets)
    assert loop._thread is None                   # stop() joined it


def test_run_until_idle_resolves_everything(bfv_engine_ks):
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks, batch=2)
    for i in range(5):
        loop.submit("t%d" % (i % 3), "t", db.Eq("v", pool[int(VALS[i])]))
    res = loop.run_until_idle()
    assert loop.queue_depth() == 0
    assert all(r.done for r in res.values())
    assert loop.stats.served == 5


# ---------------------------------------------------------------------------
# bounded response retention (the always-on mode must not leak)
# ---------------------------------------------------------------------------

def test_terminal_responses_bounded_by_max_responses(bfv_engine_ks):
    """Only the `max_responses` most recent TERMINAL responses stay
    readable — older ones evict oldest-first, so a continuous stream
    cannot grow loop memory without bound.  Stats still count every
    request."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks, max_responses=2)
    tks = [loop.submit("a", "t", db.Eq("v", pool[int(VALS[i])]))
           for i in range(4)]
    res = loop.run_until_idle()
    assert set(res) == set(tks[2:])            # evicted oldest-first
    for t in tks[:2]:
        with pytest.raises(KeyError):
            loop.response(t)
    assert all(res[t].status == OK for t in tks[2:])
    assert loop.stats.served == 4
    assert len(loop.batch_shapes) <= 2         # shapes bounded too


def test_forget_releases_terminal_responses(bfv_engine_ks):
    """Continuous-stream clients ack results as they consume them:
    forget() releases a terminal response eagerly, refuses PENDING
    tickets, and is a no-op on unknown/already-released ones."""
    ks = bfv_engine_ks
    loop, _, _, pool = _mk_loop(ks)
    t1 = loop.submit("a", "t", db.Eq("v", pool[15]))
    loop.run_until_idle()
    t2 = loop.submit("a", "t", db.Eq("v", pool[26]))
    with pytest.raises(ValueError):
        loop.forget(t2)                        # still PENDING
    loop.run_until_idle()
    r = loop.forget(t1)
    assert r.status == OK
    assert loop.forget(t1) is None             # already released
    with pytest.raises(KeyError):
        loop.response(t1)
    assert loop.response(t2).status == OK      # unacked ticket retained


# ---------------------------------------------------------------------------
# property tests: random arrival sequences (hypothesis / seeded sweep)
# ---------------------------------------------------------------------------

def _check_stream_invariants(ks, arrivals):
    """Drive one random arrival sequence; assert no starvation, FIFO
    within (tenant, class), and answers identical to the plain server.

    `arrivals` is a list of (tenant#, value#) pairs; value# indexes the
    shared VALS lattice and odd value#s submit as explicit bulk so both
    classes interleave."""
    table, indexes, pool = _env(ks)
    server = db.QueryServer(ks, table, indexes=indexes)
    loop = ServeLoop(batch=4)
    loop.register("t", server)
    received = []
    orig = server.submit

    def recording(query, *, tenant=None):
        received.append((tenant, id(query)))
        return orig(query, tenant=tenant)

    server.submit = recording
    plain = db.QueryServer(ks, table, indexes=indexes, batch=4)
    order = {}
    tickets = []
    for tn, vi in arrivals:
        tenant = "t%d" % tn
        v = int(VALS[vi % len(VALS)])
        q = P.Query(where=db.Eq("v", pool[v]))
        klass = BULK if vi % 2 else None
        tk = loop.submit(tenant, "t", q, klass=klass)
        key = (tenant, loop.response(tk).klass)
        order.setdefault(key, []).append(id(q))
        tickets.append((tk, plain.submit(q)))
    res = loop.run_until_idle()
    # no starvation: every admitted request reached a terminal answer
    assert all(r.done for r in res.values())
    assert loop.stats.served == len(arrivals)
    # FIFO within (tenant, class): the engine received each pair's
    # requests in submit order
    for (tenant, klass), ids in order.items():
        got = [qid for (tn2, qid) in received
               if tn2 == tenant and qid in set(ids)]
        assert got == ids
    # byte-identical to the plain server
    want = plain.run()
    for tk, qid in tickets:
        np.testing.assert_array_equal(res[tk].result.row_ids,
                                      want[qid].row_ids)
        np.testing.assert_array_equal(res[tk].result.mask,
                                      want[qid].mask)


def _check_writes_see_model(ks, script, seed):
    """Random query/insert interleave on a FRESH table: every query's
    match count equals a plaintext model applied in admission order."""
    base = [3, 14, 15, 9]
    table = db.Table.from_arrays(
        ks, "t_prop", {"v": np.asarray(base, np.int64)},
        jax.random.PRNGKey(seed % (1 << 30)))
    loop = ServeLoop(batch=4)
    loop.register("t", db.QueryServer(ks, table))
    model = list(base)
    probe = 41
    ct = _enc(ks, probe, seed % (1 << 30) + 1)
    expect = {}
    for i, op in enumerate(script):
        if op:                    # insert one more matching row
            loop.submit_insert("a", "t",
                               {"v": np.array([probe], np.int64)},
                               jax.random.PRNGKey(seed + i + 2))
            model.append(probe)
        else:
            tk = loop.submit("a", "t", db.Eq("v", ct))
            expect[tk] = sum(1 for v in model if v == probe)
    res = loop.run_until_idle()
    for tk, want in expect.items():
        assert len(res[tk].result.row_ids) == want


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(arrivals=st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 11)),
        min_size=1, max_size=12))
    def test_stream_invariants_property(bfv_engine_ks, arrivals):
        _check_stream_invariants(bfv_engine_ks, arrivals)

    @settings(max_examples=6, deadline=None)
    @given(script=st.lists(st.booleans(), min_size=1, max_size=5),
           seed=st.integers(0, 2**20))
    def test_queries_see_admitted_writes_property(bfv_engine_ks, script,
                                                  seed):
        _check_writes_see_model(bfv_engine_ks, script, seed)
else:
    # deterministic fallback sweep: same checkers, seeded rng fixture —
    # failures replay from the test name alone (see conftest.rng)
    def test_stream_invariants_property(bfv_engine_ks, rng):
        for _ in range(4):
            n = int(rng.integers(1, 13))
            arrivals = [(int(rng.integers(0, 3)), int(rng.integers(0, 12)))
                        for _ in range(n)]
            _check_stream_invariants(bfv_engine_ks, arrivals)

    def test_queries_see_admitted_writes_property(bfv_engine_ks, rng):
        for _ in range(3):
            n = int(rng.integers(1, 6))
            script = [bool(rng.integers(0, 2)) for _ in range(n)]
            _check_writes_see_model(bfv_engine_ks, script,
                                    int(rng.integers(1 << 20)))


# ---------------------------------------------------------------------------
# public fault-recovery API on the servers (the loop uses no internals)
# ---------------------------------------------------------------------------

def test_clear_queue_and_batch_size_public_api(bfv_engine_ks):
    """The loop's fault recovery rides public server API: clear_queue()
    drops queued requests, batch_size() restores the configured size
    even when the drain raises — on BOTH server flavors."""
    ks = bfv_engine_ks
    table, indexes, pool = _env(ks)
    server = db.QueryServer(ks, table, indexes=indexes, batch=3)
    server.submit(db.Eq("v", pool[15]))
    server.submit(db.Eq("v", pool[26]))
    assert server.clear_queue() == 2
    assert server.run() == {}                  # nothing left to drain
    with server.batch_size(8):
        assert server.batch == 8
    assert server.batch == 3
    with pytest.raises(RuntimeError, match="boom"):
        with server.batch_size(5):
            raise RuntimeError("boom")
    assert server.batch == 3                   # restored on failure too

    stable = db.ShardedTable.from_table(ks, table,
                                        spec=db.ShardSpec.create(2))
    sserver = db.ShardedQueryServer(ks, stable, batch=3)
    sserver.submit(db.Eq("v", pool[15]))
    assert sserver.clear_queue() == 1 and sserver.run() == {}
    with sserver.batch_size(4):
        assert sserver.batch == 4
    assert sserver.batch == 3


# ---------------------------------------------------------------------------
# satellite fix: server-scope sort-merge run cache
# ---------------------------------------------------------------------------

def test_sorted_run_cache_survives_batches_until_mutation(bfv_engine_ks):
    """Two consecutive batches sort-merge-joining on the same
    un-indexed column build the O(n log² n) run ONCE; a mutation bumps
    the table version and invalidates the cache."""
    ks = bfv_engine_ks
    table = _table(ks, VALS[:8], name="t_rc")
    lidx = {"v": db.SortedIndex.build(ks, table, "v")}
    right = db.Table.from_arrays(ks, "t_rc_r", {"v": VALS[:6]},
                                 jax.random.PRNGKey(4))
    server = db.QueryServer(ks, table, indexes=lidx, batch=1)
    j = db.Join(None, None, on="v")
    # batch 1: right side has no index -> run built on the fly
    q1 = server.submit_join(j, right, strategy="sort_merge")
    r1 = server.run()[q1]
    assert r1.stats.build_compares > 0
    # batch 2: same (table, column) -> cached run, zero build compares
    q2 = server.submit_join(j, right, strategy="sort_merge")
    r2 = server.run()[q2]
    assert r2.stats.build_compares == 0
    np.testing.assert_array_equal(r1.pairs, r2.pairs)
    # a mutation on the right table invalidates ITS cache entry
    right.insert(ks, {"v": np.array([3], np.int64)},
                 jax.random.PRNGKey(5))
    from repro.db.delta import compact as _compact
    _compact(ks, right, {})                # joins refuse pending deltas
    q3 = server.submit_join(j, right, strategy="sort_merge")
    r3 = server.run()[q3]
    assert r3.stats.build_compares > 0
    assert len(r3.pairs) > len(r2.pairs)   # the new row joined


def test_run_cache_recycled_table_id_cannot_alias(bfv_engine_ks):
    """A dead transient table's memoized run must never serve a fresh
    table that recycled its id(): fresh tables all start at version 0,
    so the version check alone would pass — the weakref identity guard
    refuses the hit and the run is rebuilt for the right rows."""
    ks = bfv_engine_ks
    table = _table(ks, VALS[:8], name="t_alias")
    lidx = {"v": db.SortedIndex.build(ks, table, "v")}
    server = db.QueryServer(ks, table, indexes=lidx, batch=1)
    j = db.Join(None, None, on="v")
    decoy = db.Table.from_arrays(          # rows that match NOTHING
        ks, "t_alias_d", {"v": np.full(6, 61, np.int64)},
        jax.random.PRNGKey(6))
    server.submit_join(j, decoy, strategy="sort_merge")
    server.run()
    stale = server._run_cache[(id(decoy), "v")]
    right = db.Table.from_arrays(ks, "t_alias_r", {"v": VALS[:6]},
                                 jax.random.PRNGKey(7))
    assert right.version == decoy.version == 0
    # simulate CPython id reuse: plant the decoy's entry under the
    # fresh table's id — only the weakref referent tells them apart
    server._run_cache[(id(right), "v")] = stale
    q = server.submit_join(j, right, strategy="sort_merge")
    r = server.run()[q]
    assert r.stats.build_compares > 0      # rebuilt, not aliased
    clean = db.QueryServer(ks, table, indexes=lidx, batch=1)
    qc = clean.submit_join(j, right, strategy="sort_merge")
    want = clean.run()[qc]
    np.testing.assert_array_equal(r.pairs, want.pairs)
    assert len(r.pairs) > 0                # the decoy's run had 0 matches


def test_run_cache_releases_dead_tables(bfv_engine_ks):
    """When a transient right table dies, the weakref callback evicts
    its entry — the server-scope cache cannot accumulate dead runs
    under an always-on request stream."""
    import gc
    ks = bfv_engine_ks
    table = _table(ks, VALS[:8], name="t_gcrc")
    lidx = {"v": db.SortedIndex.build(ks, table, "v")}
    server = db.QueryServer(ks, table, indexes=lidx, batch=1)
    j = db.Join(None, None, on="v")
    right = db.Table.from_arrays(ks, "t_gcrc_r", {"v": VALS[:6]},
                                 jax.random.PRNGKey(8))
    key = (id(right), "v")
    server.submit_join(j, right, strategy="sort_merge")
    server.run()
    assert key in server._run_cache
    del right
    gc.collect()
    assert key not in server._run_cache
