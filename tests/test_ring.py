"""Ring arithmetic + NTT correctness (the kernels' mathematical ground)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # collection must survive without hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import ring as R
from repro.core import sampling
from repro.core.params import (make_params, ntt_primes, negacyclic_root,
                               is_prime, PROFILES)


@pytest.fixture(scope="module")
def ring(bfv_params):
    return R.make_ring(bfv_params)


def test_ntt_primes_properties():
    for n in (256, 1024, 4096):
        for q in ntt_primes(n, 2):
            assert is_prime(q)
            assert q % (2 * n) == 1
            assert q < 2**31
            psi = negacyclic_root(q, n)
            assert pow(psi, n, q) == q - 1
            assert pow(psi, 2 * n, q) == 1


def test_ntt_roundtrip(bfv_params, ring):
    a = sampling.uniform_poly(bfv_params, jax.random.PRNGKey(0), (3,))
    assert jnp.array_equal(R.intt(ring, R.ntt(ring, a)), a)


def test_ntt_mul_matches_naive(bfv_params, ring):
    a = sampling.uniform_poly(bfv_params, jax.random.PRNGKey(1))
    b = sampling.uniform_poly(bfv_params, jax.random.PRNGKey(2))
    fast = R.negacyclic_mul(ring, a, b)
    slow = R.naive_negacyclic_mul(ring, a, b)
    assert jnp.array_equal(fast, slow)


def test_negacyclic_wraparound(bfv_params, ring):
    """x^(n-1) * x = x^n = -1 in R_q."""
    n, K = bfv_params.n, bfv_params.num_towers
    a = jnp.zeros((K, n), jnp.int64).at[:, n - 1].set(1)
    b = jnp.zeros((K, n), jnp.int64).at[:, 1].set(1)
    out = R.negacyclic_mul(ring, a, b)
    qs = np.asarray(bfv_params.qs)
    expect = jnp.zeros((K, n), jnp.int64).at[:, 0].set(
        jnp.asarray(qs - 1))
    assert jnp.array_equal(out, expect)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(-2**40, 2**40))
    def test_crt_centered_roundtrip(v):
        params = make_params("test-ckks", mode="gadget")   # 2 towers
        res = jnp.asarray([[v % q for q in params.qs]], jnp.int64)
        got = int(R.crt_centered(params, res)[0])
        assert got == v, (got, v)
else:
    def test_crt_centered_roundtrip():
        pytest.importorskip("hypothesis")


def test_const_poly_embedding(bfv_params):
    vals = jnp.asarray([0, 1, -1, 1000], jnp.int64)
    p = R.const_poly(bfv_params, vals)
    assert p.shape == (4, bfv_params.num_towers, bfv_params.n)
    got = R.crt_centered(bfv_params, p[..., :, 0])
    assert jnp.array_equal(got, vals)
    assert int(jnp.sum(jnp.abs(p[..., 1:]))) == 0


def test_ring_add_sub_inverse(bfv_params, ring):
    a = sampling.uniform_poly(bfv_params, jax.random.PRNGKey(3))
    b = sampling.uniform_poly(bfv_params, jax.random.PRNGKey(4))
    assert jnp.array_equal(R.sub(ring, R.add(ring, a, b), b), a)


def test_all_profiles_constructible():
    for name in PROFILES:
        p = make_params(name)
        assert p.max_operand > 0, name
        assert p.tau > 0
