"""Fault tolerance: crash/restart bit-equivalence, elastic fleet logic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import elastic as EL
from repro.train import checkpoint as CKPT
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_lib as TL


def _tiny():
    import dataclasses
    cfg = dataclasses.replace(
        configs.get_reduced("smollm_360m"), num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128)
    tcfg = TL.TrainConfig(opt=OPT.OptimizerConfig(
        peak_lr=1e-2, warmup_steps=2, total_steps=20))
    dcfg = DATA.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=4)
    return cfg, tcfg, dcfg


def _run(cfg, tcfg, dcfg, steps, state, start=0):
    step = jax.jit(TL.make_train_step(cfg, tcfg))
    losses = []
    for i, batch in zip(range(steps), DATA.batches(dcfg, start_index=start)):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


def test_crash_resume_matches_uninterrupted(tmp_path):
    """Train 10 steps straight vs 5 + checkpoint + crash + resume 5:
    the counter-based pipeline + checkpoint must reproduce the SAME loss
    trajectory (this is the restart guarantee)."""
    cfg, tcfg, dcfg = _tiny()
    s0 = TL.init_state(cfg, tcfg, jax.random.PRNGKey(0))

    _, straight = _run(cfg, tcfg, dcfg, 10, s0)

    s1 = TL.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    s1, first = _run(cfg, tcfg, dcfg, 5, s1)
    CKPT.save(str(tmp_path), 5, s1)
    # "crash"; restart from the checkpoint
    like = TL.init_state(cfg, tcfg, jax.random.PRNGKey(99))  # fresh proc
    s2 = CKPT.restore(str(tmp_path), 5, like)
    _, second = _run(cfg, tcfg, dcfg, 5, s2, start=5)

    np.testing.assert_allclose(straight, first + second, rtol=2e-4)


def test_injected_failure_cli(tmp_path):
    """launch/train.py --fail-at-step crashes, then --resume auto
    completes the run."""
    from repro.launch import train as TD
    argv = ["--arch", "smollm-360m", "--steps", "8", "--batch", "2",
            "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "2", "--log-every", "100"]
    with pytest.raises(RuntimeError, match="injected failure"):
        TD.main(argv + ["--fail-at-step", "5"])
    assert CKPT.latest_step(str(tmp_path)) >= 2
    result = TD.main(argv + ["--resume", "auto"])
    assert result["steps_run"] >= 1


def test_fleet_monitor_dead_host():
    cfg = EL.ElasticConfig(beat_interval_s=1.0, dead_after=3)
    mon = EL.FleetMonitor(cfg, [0, 1, 2, 3], now=0.0)
    for t in (1.0, 2.0, 3.0, 4.0):
        for h in (0, 1, 2):
            mon.heartbeat(h, now=t)
    assert mon.dead_hosts(now=4.0) == [3]
    mon.evict([3])
    assert mon.surviving() == [0, 1, 2]


def test_fleet_monitor_straggler_strikes():
    cfg = EL.ElasticConfig(straggler_factor=3.0, straggler_strikes=2)
    mon = EL.FleetMonitor(cfg, [0, 1, 2, 3])
    for _ in range(2):
        for h in (0, 1, 2):
            mon.heartbeat(h, step_time=1.0)
        mon.heartbeat(3, step_time=10.0)       # persistent straggler
        out = mon.stragglers()
    assert out == [3]


def test_plan_mesh_downscale():
    assert EL.plan_mesh(512, 16) == ((32, 16), ("data", "model"))
    assert EL.plan_mesh(496, 16) == ((31, 16), ("data", "model"))  # -1 host
    assert EL.plan_mesh(8, 16) == ((1, 8), ("data", "model"))
    assert EL.plan_mesh(1, 16) == ((1, 1), ("data", "model"))


def test_resume_plan(tmp_path):
    assert EL.resume_plan(str(tmp_path)) is None
    CKPT.save(str(tmp_path), 7, {"w": jnp.zeros((2,))})
    plan = EL.resume_plan(str(tmp_path))
    assert plan == {"restore_step": 7, "next_batch_index": 7}


# ---------------------------------------------------------------------------
# Serving-loop fault tolerance: a fault stays inside one request, and the
# loop's pump heartbeats the same FleetMonitor the training fleet uses.
# ---------------------------------------------------------------------------

def _loop_world(ks):
    """A tiny registered ServeLoop: (loop, server, probe ciphertexts)."""
    from repro import db
    from repro.core import encrypt as E
    from repro.db.serve_loop import ServeLoop

    vals = np.array([3, 1, 4, 1, 5, 9, 2, 6], dtype=np.int64)
    table = db.Table.from_arrays(ks, "t", {"v": vals},
                                 jax.random.PRNGKey(0))
    server = db.QueryServer(
        ks, table, indexes={"v": db.SortedIndex.build(ks, table, "v")},
        batch=8)
    loop = ServeLoop(batch=8)
    loop.register("t", server)
    probes = [E.encrypt(ks, np.int64(int(v)), jax.random.PRNGKey(10 + i))
              for i, v in enumerate(vals[:4])]
    return loop, server, probes


def test_serve_loop_poisoned_request_does_not_stop_service(bfv_engine_ks):
    """A plan referencing a missing column fails ONLY its own request:
    the batch-mates answer, the loop stays serviceable for later
    submissions, and the failure is an explicit FAILED response — the
    serving analogue of the fleet's evict-and-continue contract."""
    from repro import db
    from repro.db.serve_loop import FAILED, OK

    loop, _, probes = _loop_world(bfv_engine_ks)
    good1 = loop.submit("a", "t", db.Eq("v", probes[0]))
    bad = loop.submit("a", "t", db.Eq("no_such_column", probes[1]))
    good2 = loop.submit("a", "t", db.Eq("v", probes[2]))
    res = loop.run_until_idle()
    assert res[bad].status == FAILED and res[bad].error
    assert res[good1].status == OK and res[good2].status == OK

    after = loop.submit("a", "t", db.Eq("v", probes[3]))
    res = loop.run_until_idle()
    assert res[after].status == OK
    assert loop.stats.failed == 1 and loop.stats.served == 3


def test_serve_loop_heartbeats_fleet_monitor(bfv_engine_ks):
    """Each pump heartbeats the loop's host into FleetMonitor with the
    pump wall time as its step time — a stalled serving host goes dead
    by the SAME liveness rule as a stalled training host."""
    from repro import db
    from repro.db.serve_loop import ServeLoop

    cfg = EL.ElasticConfig(beat_interval_s=1.0, dead_after=3)
    mon = EL.FleetMonitor(cfg, [0, 1], now=0.0)
    loop, server, probes = _loop_world(bfv_engine_ks)
    loop2 = ServeLoop(batch=8, monitor=mon, monitor_host=0)
    loop2.register("t", server)
    loop2.submit("a", "t", db.Eq("v", probes[0]))
    loop2.run_until_idle()
    assert mon.hosts[0].step_times          # pump wall time recorded
    # just after host 0's pump beat, only the never-beating host 1
    # (last_beat frozen at the t=0 construction) is past the limit
    assert mon.dead_hosts(now=mon.hosts[0].last_beat + 1.0) == [1]
