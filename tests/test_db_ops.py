"""Encrypted database operations: range query, bitonic sort, top-k."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # collection must survive without hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params

_CACHE = {}


def _ks():
    if "ks" not in _CACHE:
        _CACHE["ks"] = keygen(make_params("test-bfv", mode="gadget"),
                              jax.random.PRNGKey(1))
    return _CACHE["ks"]


def test_range_query_matches_plaintext():
    ks = _ks()
    vals = jnp.asarray([5, 17, 3, 99, 42, 8, 77, 23], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(2))
    lo = E.encrypt(ks, jnp.asarray(8), jax.random.PRNGKey(3))
    hi = E.encrypt(ks, jnp.asarray(77), jax.random.PRNGKey(4))
    mask = C.range_query(ks, col, lo, hi)
    assert jnp.array_equal(mask, (vals >= 8) & (vals <= 77))


def test_encrypted_sort_exact():
    ks = _ks()
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(5))
    _, perm = C.encrypted_sort(ks, col)
    assert jnp.array_equal(vals[perm], jnp.sort(vals))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(0, 1000), min_size=8, max_size=8,
                    unique=True))
    def test_encrypted_sort_property(values):
        ks = _ks()
        vals = jnp.asarray(values, jnp.int64)
        col = E.encrypt(ks, vals, jax.random.PRNGKey(sum(values) % 1000))
        _, perm = C.encrypted_sort(ks, col)
        assert jnp.array_equal(vals[perm], jnp.sort(vals))
        # perm is a permutation
        assert jnp.array_equal(jnp.sort(perm), jnp.arange(8))
else:
    def test_encrypted_sort_property():
        pytest.importorskip("hypothesis")


def test_encrypted_topk():
    ks = _ks()
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(6))
    _, idx = C.encrypted_topk(ks, col, 3)
    assert set(np.asarray(vals[idx]).tolist()) == {14, 9, 8}


def test_topk_matches_sort_based_answer():
    """The partial bitonic top-k network must agree with full-sort+slice."""
    ks = _ks()
    rng = np.random.default_rng(7)
    for n, k in [(16, 4), (13, 5), (32, 3), (24, 8)]:
        vals = jnp.asarray(rng.choice(2000, size=n, replace=False), jnp.int64)
        col = E.encrypt(ks, vals, jax.random.PRNGKey(1000 + n + k))
        _, idx = C.encrypted_topk(ks, col, k)
        sorted_ct, perm = C.encrypted_sort(ks, col)
        sort_based = np.asarray(vals)[np.asarray(perm)][::-1][:k]
        got = np.asarray(vals)[np.asarray(idx)]
        assert got.tolist() == sort_based.tolist(), (n, k, got, sort_based)


def test_topk_returns_descending_rows():
    ks = _ks()
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5, 11], jnp.int64)  # non-pow2
    col = E.encrypt(ks, vals, jax.random.PRNGKey(8))
    top, idx = C.encrypted_topk(ks, col, 4)
    dec = np.asarray(E.decrypt(ks, top))
    assert dec.tolist() == [14, 11, 9, 8]
    assert np.asarray(vals)[np.asarray(idx)].tolist() == dec.tolist()


def test_sort_pads_non_power_of_two():
    """Non-2^k columns are padded with encrypted sentinels and the
    sentinels stripped: output length == input length, exact order."""
    ks = _ks()
    for n in (3, 5, 12):
        vals = jnp.asarray(np.arange(n)[::-1].copy() * 3 + 1, jnp.int64)
        col = E.encrypt(ks, vals, jax.random.PRNGKey(40 + n))
        sorted_ct, perm = C.encrypted_sort(ks, col)
        assert perm.shape == (n,)
        assert sorted_ct.c0.shape[0] == n
        assert jnp.array_equal(vals[perm], jnp.sort(vals))
        # returned ciphertexts really are the sorted rows
        assert jnp.array_equal(E.decrypt(ks, sorted_ct), jnp.sort(vals))


def test_sort_with_duplicates_is_stable_order():
    """Duplicates (FAE coin flips) still yield a valid sorted sequence."""
    ks = _ks()
    vals = jnp.asarray([5, 5, 2, 9, 2, 5, 9, 1], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(8))
    _, perm = C.encrypted_sort(ks, col)
    assert jnp.array_equal(vals[perm], jnp.sort(vals))
