"""Encrypted database operations: range query, bitonic sort, top-k."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params

_CACHE = {}


def _ks():
    if "ks" not in _CACHE:
        _CACHE["ks"] = keygen(make_params("test-bfv", mode="gadget"),
                              jax.random.PRNGKey(1))
    return _CACHE["ks"]


def test_range_query_matches_plaintext():
    ks = _ks()
    vals = jnp.asarray([5, 17, 3, 99, 42, 8, 77, 23], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(2))
    lo = E.encrypt(ks, jnp.asarray(8), jax.random.PRNGKey(3))
    hi = E.encrypt(ks, jnp.asarray(77), jax.random.PRNGKey(4))
    mask = C.range_query(ks, col, lo, hi)
    assert jnp.array_equal(mask, (vals >= 8) & (vals <= 77))


def test_encrypted_sort_exact():
    ks = _ks()
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(5))
    _, perm = C.encrypted_sort(ks, col)
    assert jnp.array_equal(vals[perm], jnp.sort(vals))


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=8, max_size=8,
                unique=True))
def test_encrypted_sort_property(values):
    ks = _ks()
    vals = jnp.asarray(values, jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(sum(values) % 1000))
    _, perm = C.encrypted_sort(ks, col)
    assert jnp.array_equal(vals[perm], jnp.sort(vals))
    # perm is a permutation
    assert jnp.array_equal(jnp.sort(perm), jnp.arange(8))


def test_encrypted_topk():
    ks = _ks()
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(6))
    _, idx = C.encrypted_topk(ks, col, 3)
    assert set(np.asarray(vals[idx]).tolist()) == {14, 9, 8}


def test_sort_requires_power_of_two():
    ks = _ks()
    vals = jnp.asarray([3, 1, 2], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(7))
    with pytest.raises(AssertionError):
        C.encrypted_sort(ks, col)


def test_sort_with_duplicates_is_stable_order():
    """Duplicates (FAE coin flips) still yield a valid sorted sequence."""
    ks = _ks()
    vals = jnp.asarray([5, 5, 2, 9, 2, 5, 9, 1], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(8))
    _, perm = C.encrypted_sort(ks, col)
    assert jnp.array_equal(vals[perm], jnp.sort(vals))
