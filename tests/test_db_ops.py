"""Encrypted database operations: range query, bitonic sort, top-k.

Property tests (hypothesis when available, seeded deterministic sweep
otherwise — collection and tier-1 must survive without hypothesis) cover
the two places approximate/trapdoor comparison is most fragile:

  * `encrypted_sort` sentinel padding: arbitrary non-power-of-two
    lengths must round-trip — pad rows appended, stripped by permutation
    id (never by value), output exactly the input multiset, sorted;
  * `encrypted_topk` tie handling: duplicate-heavy columns make FAE
    compare outcomes coin flips on equal pairs — the returned VALUE
    multiset must still equal the plaintext top-k (row ids may permute
    within a tie class), including when a real row ties the sentinel.

Both properties run on bfv (exact ints) AND ckks (grid floats whose
spacing dwarfs the profile tolerance) via the shared `scheme_ks` cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # collection must survive without hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import compare as C
from repro.core import encrypt as E
from repro.db.executor import jitted_comparator

# value lattice for property cases: tiny alphabet -> duplicate-heavy
# columns; ckks maps lattice point i to i*GRID (0.25 >> tolerance ~0.016)
GRID = 0.25
MAX_N = 9            # padded sizes 2/4/8/16 — shapes repeat, jit caches

_JENC = {}           # id(ks) -> jitted encrypt (shapes specialize per n)


def _jenc(ks):
    if id(ks) not in _JENC:
        _JENC[id(ks)] = jax.jit(lambda m, k: E.encrypt(ks, m, k))
    return _JENC[id(ks)]


def _lattice_vals(ks, ints):
    ints = np.asarray(ints)
    if ks.params.profile.scheme == "ckks":
        return ints.astype(np.float64) * GRID
    return ints.astype(np.int64)


def _decrypt_matches(ks, ct, want) -> bool:
    got = np.asarray(E.decrypt(ks, ct))
    if ks.params.profile.scheme == "ckks":
        # decrypt is approximate: bound the error by the profile's own
        # precision claim (equality_tolerance), not an arbitrary atol
        from repro.core.ckks import equality_tolerance
        return np.allclose(got, np.asarray(want, np.float64),
                           atol=equality_tolerance(ks.params))
    return got.tolist() == list(want)


def _check_sort_case(ks, lattice, seed):
    """encrypted_sort on an arbitrary-length duplicate-heavy column: the
    permuted input must BE the plaintext sort (multiset equality + order),
    perm a valid permutation, and the returned ciphertext rows must
    decrypt to the sorted values (sentinel pad rows fully stripped)."""
    vals = _lattice_vals(ks, lattice)
    n = len(vals)
    col = _jenc(ks)(jnp.asarray(vals), jax.random.PRNGKey(seed))
    sorted_ct, perm = C.encrypted_sort(ks, col, jitted_comparator(ks))
    perm = np.asarray(perm)
    assert perm.shape == (n,) and sorted_ct.c0.shape[0] == n
    assert np.array_equal(np.sort(perm), np.arange(n))      # permutation
    np.testing.assert_array_equal(vals[perm], np.sort(vals))
    assert _decrypt_matches(ks, sorted_ct, np.sort(vals))


def _check_topk_case(ks, lattice, k, seed):
    """encrypted_topk under heavy ties: value multiset equals the
    plaintext top-k, ids are distinct real rows, rows come back
    descending.  (Row *ids* may permute within a tie class — FAE coin
    flips — so the assertion is on values, the tie-robust contract.)"""
    vals = _lattice_vals(ks, lattice)
    n = len(vals)
    col = _jenc(ks)(jnp.asarray(vals), jax.random.PRNGKey(seed))
    top_ct, idx = C.encrypted_topk(ks, col, k, jitted_comparator(ks))
    idx = np.asarray(idx)
    assert idx.shape == (k,)
    assert len(set(idx.tolist())) == k                       # distinct rows
    assert np.all((0 <= idx) & (idx < n))                    # never a pad row
    got = vals[idx]
    want = np.sort(vals)[::-1][:k]
    np.testing.assert_array_equal(np.sort(got), np.sort(want))
    np.testing.assert_array_equal(got, np.sort(got)[::-1])   # descending
    assert _decrypt_matches(ks, top_ct, got)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(lattice=st.lists(st.integers(0, 7), min_size=2, max_size=MAX_N),
           seed=st.integers(0, 2**31 - 1))
    def test_sort_padding_and_ties_property(scheme_ks, lattice, seed):
        _check_sort_case(scheme_ks, lattice, seed)

    @settings(max_examples=200, deadline=None)
    @given(data=st.data())
    def test_topk_tie_handling_property(scheme_ks, data):
        lattice = data.draw(st.lists(st.integers(0, 7),
                                     min_size=2, max_size=MAX_N))
        k = data.draw(st.integers(1, len(lattice)))
        seed = data.draw(st.integers(0, 2**31 - 1))
        _check_topk_case(scheme_ks, lattice, k, seed)
else:
    # deterministic fallback sweep: same checkers, seeded rng fixture —
    # failures replay from the test name alone (see conftest.rng)
    def test_sort_padding_and_ties_property(scheme_ks, rng):
        for length in list(range(2, MAX_N + 1)) * 2:
            lattice = rng.integers(0, 8, length).tolist()
            _check_sort_case(scheme_ks, lattice,
                             int(rng.integers(1 << 30)))

    def test_topk_tie_handling_property(scheme_ks, rng):
        for length in list(range(2, MAX_N + 1)) * 2:
            lattice = rng.integers(0, 8, length).tolist()
            k = int(rng.integers(1, length + 1))
            _check_topk_case(scheme_ks, lattice, k,
                             int(rng.integers(1 << 30)))


# ---------------------------------------------------------------------------
# directed cases (original coverage, now on the shared keyset cache)
# ---------------------------------------------------------------------------

def test_range_query_matches_plaintext(bfv_engine_ks):
    ks = bfv_engine_ks
    vals = jnp.asarray([5, 17, 3, 99, 42, 8, 77, 23], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(2))
    lo = E.encrypt(ks, jnp.asarray(8), jax.random.PRNGKey(3))
    hi = E.encrypt(ks, jnp.asarray(77), jax.random.PRNGKey(4))
    mask = C.range_query(ks, col, lo, hi)
    assert jnp.array_equal(mask, (vals >= 8) & (vals <= 77))


def test_encrypted_sort_exact(bfv_engine_ks):
    ks = bfv_engine_ks
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(5))
    _, perm = C.encrypted_sort(ks, col)
    assert jnp.array_equal(vals[perm], jnp.sort(vals))


def test_encrypted_topk(bfv_engine_ks):
    ks = bfv_engine_ks
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(6))
    _, idx = C.encrypted_topk(ks, col, 3)
    assert set(np.asarray(vals[idx]).tolist()) == {14, 9, 8}


def test_topk_matches_sort_based_answer(bfv_engine_ks):
    """The partial bitonic top-k network must agree with full-sort+slice."""
    ks = bfv_engine_ks
    rng = np.random.default_rng(7)
    for n, k in [(16, 4), (13, 5), (32, 3), (24, 8)]:
        vals = jnp.asarray(rng.choice(2000, size=n, replace=False), jnp.int64)
        col = E.encrypt(ks, vals, jax.random.PRNGKey(1000 + n + k))
        _, idx = C.encrypted_topk(ks, col, k)
        sorted_ct, perm = C.encrypted_sort(ks, col)
        sort_based = np.asarray(vals)[np.asarray(perm)][::-1][:k]
        got = np.asarray(vals)[np.asarray(idx)]
        assert got.tolist() == sort_based.tolist(), (n, k, got, sort_based)


def test_topk_returns_descending_rows(bfv_engine_ks):
    ks = bfv_engine_ks
    vals = jnp.asarray([9, 2, 7, 1, 14, 3, 8, 5, 11], jnp.int64)  # non-pow2
    col = E.encrypt(ks, vals, jax.random.PRNGKey(8))
    top, idx = C.encrypted_topk(ks, col, 4)
    dec = np.asarray(E.decrypt(ks, top))
    assert dec.tolist() == [14, 11, 9, 8]
    assert np.asarray(vals)[np.asarray(idx)].tolist() == dec.tolist()


def test_sort_pads_non_power_of_two(bfv_engine_ks):
    """Non-2^k columns are padded with encrypted sentinels and the
    sentinels stripped: output length == input length, exact order."""
    ks = bfv_engine_ks
    for n in (3, 5, 12):
        vals = jnp.asarray(np.arange(n)[::-1].copy() * 3 + 1, jnp.int64)
        col = E.encrypt(ks, vals, jax.random.PRNGKey(40 + n))
        sorted_ct, perm = C.encrypted_sort(ks, col)
        assert perm.shape == (n,)
        assert sorted_ct.c0.shape[0] == n
        assert jnp.array_equal(vals[perm], jnp.sort(vals))
        # returned ciphertexts really are the sorted rows
        assert jnp.array_equal(E.decrypt(ks, sorted_ct), jnp.sort(vals))


def test_sort_with_duplicates_is_stable_order(bfv_engine_ks):
    """Duplicates (FAE coin flips) still yield a valid sorted sequence."""
    ks = bfv_engine_ks
    vals = jnp.asarray([5, 5, 2, 9, 2, 5, 9, 1], jnp.int64)
    col = E.encrypt(ks, vals, jax.random.PRNGKey(8))
    _, perm = C.encrypted_sort(ks, col)
    assert jnp.array_equal(vals[perm], jnp.sort(vals))
