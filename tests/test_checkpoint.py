"""Checkpointing: atomic commit, roundtrip, retention, async writer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as CKPT


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,), jnp.float32)},
            "step": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    CKPT.save(str(tmp_path), 3, tree)
    assert CKPT.latest_step(str(tmp_path)) == 3
    got = CKPT.restore(str(tmp_path), 3, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    tree = _tree()
    t = CKPT.save(str(tmp_path), 5, tree, async_=True)
    t.join()
    assert CKPT.latest_step(str(tmp_path)) == 5


def test_incomplete_checkpoint_ignored_and_cleaned(tmp_path):
    tree = _tree()
    CKPT.save(str(tmp_path), 1, tree)
    # simulate a crash mid-write: a .tmp dir without manifest commit
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert CKPT.latest_step(str(tmp_path)) == 1
    assert CKPT.clean_incomplete(str(tmp_path)) == 1
    assert not (tmp_path / "step_00000002.tmp").exists()


def test_keep_last(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4):
        CKPT.save(str(tmp_path), s, tree)
    CKPT.keep_last(str(tmp_path), 2)
    assert CKPT.latest_step(str(tmp_path)) == 4
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2


def test_restore_shape_mismatch_raises(tmp_path):
    CKPT.save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(AssertionError):
        CKPT.restore(str(tmp_path), 1, {"w": jnp.zeros((5,))})


def test_mesh_agnostic_dtype_cast(tmp_path):
    """Restore casts to the target leaf dtype (elastic re-shard path)."""
    CKPT.save(str(tmp_path), 1, {"w": jnp.ones((4,), jnp.float32)})
    got = CKPT.restore(str(tmp_path), 1, {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert got["w"].dtype == jnp.bfloat16
