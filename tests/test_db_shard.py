"""repro.db.shard: shard invariance, merge networks, fan-out indexes.

THE contract under test: for every plan, the decrypted answer — filter
mask, ordered value sequence, projected ciphertext values — is
IDENTICAL for 1, 2, and 4 shards (and a non-power-of-two 3), on both
the bfv and ckks profiles, regardless of how unevenly the shards pad.
`ShardedTable.from_table` re-partitions the SAME ciphertext rows, so
filter masks must match the single-device executor byte for byte (same
eval values, same thresholds); order stages guarantee the value
sequence (tie ids may permute — the FAE coin-flip contract).

Works at any device count: on a single CPU device the sharded executor
falls back to one fused launch over the stacked [S, A, N_sp] batch; the
CI multi-device job re-runs this file under
XLA_FLAGS=--xla_force_host_platform_device_count=8, where 2- and
4-shard tables place on a real mesh and the fused filter runs under
shard_map — the assertions are placement-independent on purpose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import db
from repro.core import encrypt as E
from repro.db.shard.table import partition_offsets

GRID = 0.25        # ckks float grid (>> test-ckks equality tolerance)
EPS_BAND = 0.3
SHARD_COUNTS = (1, 2, 4)


def _is_ckks(ks) -> bool:
    return ks.params.profile.scheme == "ckks"


def _vals(ks, ints) -> np.ndarray:
    ints = np.asarray(ints)
    if _is_ckks(ks):
        return ints.astype(np.float64) * GRID
    return ints.astype(np.int64)


def _enc(ks, v, seed):
    v = float(v) if _is_ckks(ks) else int(v)
    return E.encrypt(ks, jnp.asarray(v), jax.random.PRNGKey(seed))


def _bound(ks, v, side):
    return float(v) + side * GRID / 2 if _is_ckks(ks) else int(v)


def _sharded(ks, table, n_shards):
    return db.ShardedTable.from_table(
        ks, table, spec=db.ShardSpec.create(n_shards))


# ---------------------------------------------------------------------------
# partition / table geometry
# ---------------------------------------------------------------------------

def test_partition_offsets_balanced_and_contiguous():
    off = partition_offsets(50, 4)
    assert off.tolist() == [0, 13, 26, 38, 50]
    assert partition_offsets(8, 1).tolist() == [0, 8]
    with pytest.raises(ValueError):
        partition_offsets(3, 4)          # more shards than rows


def test_sharded_table_uneven_padding_roundtrip(scheme_ks):
    """50 rows over 4 shards: chunks 13/13/12/12 all pad to ONE 16-row
    block (uneven validity, uniform geometry) and decrypt losslessly."""
    ks = scheme_ks
    vals = _vals(ks, np.arange(50))
    st = db.ShardedTable.from_arrays(ks, "t", {"v": vals},
                                     jax.random.PRNGKey(0),
                                     spec=db.ShardSpec.create(4))
    assert st.n_padded_per_shard == 16
    assert st.shard_rows.tolist() == [13, 13, 12, 12]
    got = st.decrypt_column(ks, "v")
    if _is_ckks(ks):
        from repro.core.ckks import equality_tolerance
        np.testing.assert_allclose(got, vals,
                                   atol=equality_tolerance(ks.params))
    else:
        np.testing.assert_array_equal(got, vals)


def test_from_table_reuses_ciphertext_rows(bfv_engine_ks):
    """Re-partitioning moves the SAME ciphertexts — no re-encryption."""
    ks = bfv_engine_ks
    vals = np.arange(10, 31)             # 21 rows (non-pow2)
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(1))
    st = _sharded(ks, t, 2)
    s_idx, slot = st.locate([0, 10, 11, 20])
    assert s_idx.tolist() == [0, 0, 1, 1] and slot.tolist() == [0, 10, 0, 9]
    for gid in (0, 10, 11, 20):
        s, sl = st.locate([gid])
        got = st.gather("v", int(s[0]), [int(sl[0])])
        np.testing.assert_array_equal(np.asarray(got.c0[0]),
                                      np.asarray(t.columns["v"].c0[gid]))


def test_shard_spec_decouples_logical_from_devices():
    spec = db.ShardSpec.create(4)
    assert spec.num_shards == 4
    assert spec.num_shards % spec.mesh_devices == 0     # always placeable
    meshless = db.ShardSpec.create(3, use_mesh=False)
    assert meshless.mesh_devices == 1 and not meshless.shard_map_ok
    with pytest.raises(ValueError):
        db.ShardSpec(num_shards=0)


# ---------------------------------------------------------------------------
# shard invariance: filters (byte-identical masks vs the single-device path)
# ---------------------------------------------------------------------------

def test_filter_masks_invariant_across_shard_counts(scheme_ks, rng):
    """Eq / Range / And / Or / Not produce byte-identical masks for
    every shard count — from_table shares rows with the reference table,
    so even the raw eval values must agree."""
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 200, 53))
    score = _vals(ks, rng.integers(0, 200, 53))
    t = db.Table.from_arrays(ks, "t", {"v": vals, "s": score},
                             jax.random.PRNGKey(2))
    b = lambda v, s: _bound(ks, _vals(ks, np.asarray(v)), s)  # noqa: E731
    queries = [
        db.Eq("v", _enc(ks, vals[5], 0)),
        db.Range("v", _enc(ks, b(40, -1), 1), _enc(ks, b(150, +1), 2)),
        db.And(db.Range("v", _enc(ks, b(20, -1), 3),
                        _enc(ks, b(170, +1), 4)),
               db.Range("s", _enc(ks, b(0, -1), 5),
                        _enc(ks, b(110, +1), 6))),
        db.Or(db.Eq("s", _enc(ks, score[7], 7)),
              db.Not(db.Range("v", _enc(ks, b(0, -1), 8),
                              _enc(ks, b(120, +1), 9)))),
    ]
    for qi, q in enumerate(queries):
        ref = db.execute(ks, t, q)
        for n_shards in SHARD_COUNTS:
            st = _sharded(ks, t, n_shards)
            res = db.execute(ks, st, q)
            assert isinstance(res.stats, db.ShardedExecStats)
            np.testing.assert_array_equal(
                res.mask, ref.mask,
                err_msg=f"query {qi} mask differs at S={n_shards}")
            np.testing.assert_array_equal(res.row_ids, ref.row_ids)
            # whole predicate still ONE fused launch, per-shard slice 1/S
            assert res.stats.eval_calls == 1
            assert (res.stats.per_shard_scan_compares
                    == res.stats.scan_compares // n_shards)


def test_order_by_invariant_with_duplicates(scheme_ks, rng):
    """OrderBy through per-shard sorts + cross-shard merge returns the
    exact sorted value sequence (duplicates included) for every shard
    count, ascending and descending."""
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 30, 41))     # heavy duplicates
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(3))
    lo = _bound(ks, _vals(ks, 3), -1)
    hi = _bound(ks, _vals(ks, 27), +1)
    for desc in (False, True):
        q = db.Query(where=db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                     order_by=db.OrderBy("v", descending=desc))
        want = sorted(vals[(vals >= lo) & (vals <= hi)].tolist(),
                      reverse=desc)
        for n_shards in SHARD_COUNTS:
            st = _sharded(ks, t, n_shards)
            res = db.execute(ks, st, q)
            assert vals[res.row_ids].tolist() == want, (desc, n_shards)
            if n_shards > 1:
                assert res.stats.merge_compares > 0


def test_topk_invariant_with_ties(scheme_ks, rng):
    """TopK with tie values straddling the cut: the returned value
    multiset is identical for every shard count (tie ids may permute —
    the FAE coin-flip contract)."""
    ks = scheme_ks
    ints = rng.integers(0, 12, 45)               # many ties at the cut
    vals = _vals(ks, ints)
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(4))
    q = db.Query(top_k=db.TopK("v", 6), select=("v",))
    want = sorted(vals.tolist(), reverse=True)[:6]
    for n_shards in SHARD_COUNTS:
        st = _sharded(ks, t, n_shards)
        res = db.execute(ks, st, q)
        got = vals[res.row_ids].tolist()
        assert got == want, (n_shards, got, want)
        # projected ciphertexts decrypt to the same values
        dec = np.asarray(E.decrypt(ks, res.columns["v"]))
        if _is_ckks(ks):
            from repro.core.ckks import equality_tolerance
            np.testing.assert_allclose(dec, want,
                                       atol=equality_tolerance(ks.params))
        else:
            np.testing.assert_array_equal(dec, want)


def test_non_power_of_two_shard_count(scheme_ks, rng):
    """S=3 (padded to 4 merge blocks with sentinel blocks) answers
    exactly like S=1."""
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 100, 38))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(5))
    lo = _bound(ks, _vals(ks, 10), -1)
    hi = _bound(ks, _vals(ks, 80), +1)
    q = db.Query(where=db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                 top_k=db.TopK("v", 4))
    ref = db.execute(ks, t, q)
    res = db.execute(ks, _sharded(ks, t, 3), q)
    np.testing.assert_array_equal(res.mask, ref.mask)
    assert vals[res.row_ids].tolist() == vals[ref.row_ids].tolist()


# ---------------------------------------------------------------------------
# ε-band lanes (ckks float semantics) through the sharded paths
# ---------------------------------------------------------------------------

def test_eps_band_lanes_sharded(scheme_ks, rng):
    ks = scheme_ks
    if not _is_ckks(ks):
        pytest.skip("ε-band equality is a float-column (ckks) feature")
    vals = _vals(ks, rng.integers(0, 50, 44))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(6))
    target = vals[11]
    q = db.Eq("v", _enc(ks, target, 0), eps=EPS_BAND)
    want = np.abs(vals - target) <= EPS_BAND
    for n_shards in SHARD_COUNTS:
        st = _sharded(ks, t, n_shards)
        res = db.execute(ks, st, q)
        np.testing.assert_array_equal(res.mask, want)
        idx = db.ShardedIndex.build(ks, st, "v")
        res_i = db.execute(ks, st, q, indexes={"v": idx})
        np.testing.assert_array_equal(res_i.mask, want)
        assert res_i.stats.eval_calls == 0     # resolved via fan-out probes


# ---------------------------------------------------------------------------
# sharded index: fan-out probing
# ---------------------------------------------------------------------------

def test_sharded_index_matches_linear_and_single(scheme_ks, rng):
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 300, 61))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(7))
    for n_shards in SHARD_COUNTS:
        st = _sharded(ks, t, n_shards)
        idx = db.ShardedIndex.build(ks, st, "v")
        # every shard's slice is correctly sorted (id-stripped)
        for s, ix in enumerate(idx.shards):
            lo_g, hi_g = int(st.offsets[s]), int(st.offsets[s + 1])
            chunk = vals[lo_g:hi_g]
            np.testing.assert_array_equal(chunk[ix.perm], np.sort(chunk))
        for i in range(2):
            a, b = np.sort(rng.choice(vals, 2, replace=False))
            lo, hi = _bound(ks, a, -1), _bound(ks, b, +1)
            q = db.Range("v", _enc(ks, lo, 10 + i), _enc(ks, hi, 20 + i))
            lin = db.execute(ks, st, q)
            ind = db.execute(ks, st, q, indexes={"v": idx})
            np.testing.assert_array_equal(lin.mask, ind.mask)
            np.testing.assert_array_equal(ind.mask,
                                          (vals >= lo) & (vals <= hi))
            assert ind.stats.eval_calls == 0
        # fan-out cost: ~2 lanes x log2(shard size) per shard
        per_shard = int(np.ceil(np.log2(max(2, int(st.shard_rows.max())))))
        assert idx.search_compares <= 2 * 2 * n_shards * (per_shard + 1) * 2


def test_sharded_index_point_lookup_duplicates(scheme_ks):
    ks = scheme_ks
    vals = _vals(ks, np.asarray([7, 3, 7, 1, 9, 7, 3, 2, 8, 7, 0]))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(8))
    st = _sharded(ks, t, 4)
    idx = db.ShardedIndex.build(ks, st, "v")
    res = db.execute(ks, st, db.Eq("v", _enc(ks, _vals(ks, 7), 0)),
                     indexes={"v": idx})
    assert sorted(res.row_ids.tolist()) == [0, 2, 5, 9]
    miss = db.execute(ks, st, db.Eq("v", _enc(ks, _vals(ks, 4), 1)),
                      indexes={"v": idx})
    assert len(miss) == 0


# ---------------------------------------------------------------------------
# sharded query server
# ---------------------------------------------------------------------------

def test_sharded_server_one_launch_per_batch(scheme_ks, rng):
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 200, 57))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(9))
    st = _sharded(ks, t, 4)
    server = db.ShardedQueryServer(ks, st, batch=4)
    truth = {}
    for i in range(4):
        a, b = sorted(rng.integers(0, 200, 2).tolist())
        lo = _bound(ks, _vals(ks, a), -1)
        hi = _bound(ks, _vals(ks, b), +1)
        qid = server.submit(db.Range("v", _enc(ks, lo, 100 + i),
                                     _enc(ks, hi, 200 + i)))
        truth[qid] = (vals >= lo) & (vals <= hi)
    results = server.run()
    assert len(server.batch_log) == 1
    # 4 queries x 4 shards: still ONE fused launch
    assert server.batch_log[0].eval_calls == 1
    assert server.batch_log[0].shards == 4
    for qid, want in truth.items():
        np.testing.assert_array_equal(results[qid].mask, want)


def test_sharded_server_indexed_lanes_and_topk(scheme_ks, rng):
    ks = scheme_ks
    vals = _vals(ks, rng.integers(0, 150, 48))
    t = db.Table.from_arrays(ks, "t", {"v": vals}, jax.random.PRNGKey(10))
    st = _sharded(ks, t, 2)
    idx = db.ShardedIndex.build(ks, st, "v")
    server = db.ShardedQueryServer(ks, st, indexes={"v": idx}, batch=2)
    lo = _bound(ks, _vals(ks, 20), -1)
    hi = _bound(ks, _vals(ks, 120), +1)
    q1 = db.Query(where=db.Range("v", _enc(ks, lo, 0), _enc(ks, hi, 1)),
                  top_k=db.TopK("v", 5))
    q2 = db.Query(where=db.Eq("v", _enc(ks, vals[3], 2)))
    id1, id2 = server.submit(q1), server.submit(q2)
    results = server.run()
    assert server.batch_log[0].eval_calls == 0   # all lanes via fan-out
    m1 = (vals >= lo) & (vals <= hi)
    np.testing.assert_array_equal(results[id1].mask, m1)
    want_top = sorted(vals[m1].tolist(), reverse=True)[:5]
    assert vals[results[id1].row_ids].tolist() == want_top
    np.testing.assert_array_equal(results[id2].mask, vals == vals[3])


# ---------------------------------------------------------------------------
# cost model: the merge networks do what the README claims
# ---------------------------------------------------------------------------

def test_merge_overhead_is_k_s_scale(bfv_engine_ks, rng):
    """Cross-shard top-k merge compares are O(kp·S·log kp) — independent
    of the row count n."""
    ks = bfv_engine_ks
    for n_rows in (64, 256):
        vals = rng.integers(0, 10_000, n_rows).astype(np.int64)
        t = db.Table.from_arrays(ks, "t", {"v": vals},
                                 jax.random.PRNGKey(n_rows))
        st = _sharded(ks, t, 4)
        q = db.Query(top_k=db.TopK("v", 4))
        res = db.execute(ks, st, q)
        want = sorted(vals.tolist(), reverse=True)[:4]
        assert vals[res.row_ids].tolist() == want
        kp, S = 4, 4
        bound = (S - 1) * (kp + (kp // 2) * int(np.log2(kp)))
        assert 0 < res.stats.merge_compares <= bound
        # per-shard phase scales with n, merge does not
        assert res.stats.per_shard_order_compares > res.stats.merge_compares
