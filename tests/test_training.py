"""Training substrate: optimization makes progress; microbatching is
equivalent; gradient compression round-trips within tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.train import compress as GC
from repro.train import data as DATA
from repro.train import optimizer as OPT
from repro.train import train_lib as TL


def _tiny_cfg():
    import dataclasses
    return dataclasses.replace(
        configs.get_reduced("smollm_360m"), num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128)


def test_loss_decreases():
    cfg = _tiny_cfg()
    tcfg = TL.TrainConfig(opt=OPT.OptimizerConfig(
        peak_lr=1e-2, warmup_steps=5, total_steps=40))
    state = TL.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(TL.make_train_step(cfg, tcfg))
    dcfg = DATA.DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                           global_batch=4)
    losses = []
    for i, batch in zip(range(40), DATA.batches(dcfg)):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_microbatch_equivalence():
    """mb=1 and mb=4 produce (nearly) the same update."""
    cfg = _tiny_cfg()
    batch = DATA.synthetic_batch(
        DATA.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                        global_batch=8), 0)
    outs = {}
    for mb in (1, 4):
        tcfg = TL.TrainConfig(microbatches=mb)
        state = TL.init_state(cfg, tcfg, jax.random.PRNGKey(0))
        step = TL.make_train_step(cfg, tcfg)
        new_state, metrics = step(state, batch)
        outs[mb] = (metrics["loss"],
                    jax.tree.leaves(new_state.params)[0])
    assert abs(float(outs[1][0]) - float(outs[4][0])) < 1e-3
    np.testing.assert_allclose(np.asarray(outs[1][1], np.float32),
                               np.asarray(outs[4][1], np.float32),
                               atol=2e-4)


def test_schedule_shape():
    ocfg = OPT.OptimizerConfig(peak_lr=1e-3, warmup_steps=10,
                               total_steps=100, min_lr_ratio=0.1)
    lrs = [float(OPT.schedule(ocfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_grad_clipping():
    ocfg = OPT.OptimizerConfig(clip_norm=1.0)
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0)}
    state = OPT.init_state(params)
    _, _, metrics = OPT.apply_updates(ocfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_compression_error_feedback():
    """int8 EF compressor: per-round error bounded; residual carries."""
    grads = {"a": jnp.asarray(np.random.default_rng(0).normal(
        0, 1, (64,)).astype(np.float32))}
    st = GC.init_state(grads)
    vals, scales, st = GC.compress(st, grads)
    assert jax.tree.leaves(vals)[0].dtype == jnp.int8
    deco = GC.decompress(vals, scales)
    err = float(jnp.max(jnp.abs(deco["a"] - grads["a"])))
    assert err <= float(scales["a"]) * 0.5 + 1e-7
    # residual equals the quantization error (error feedback invariant)
    np.testing.assert_allclose(np.asarray(st.residual["a"]),
                               np.asarray(grads["a"] - deco["a"]),
                               atol=1e-7)


def test_compressed_training_still_learns():
    cfg = _tiny_cfg()
    tcfg = TL.TrainConfig(opt=OPT.OptimizerConfig(
        peak_lr=1e-2, warmup_steps=5, total_steps=30),
        compress_grads=True)
    state = TL.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(TL.make_train_step(cfg, tcfg))
    dcfg = DATA.DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                           global_batch=4)
    losses = []
    for i, batch in zip(range(30), DATA.batches(dcfg)):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
