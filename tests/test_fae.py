"""FA-Extension properties (paper §5): equality obfuscation + order
preservation + minimal overhead structure."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compare as C
from repro.core import encrypt as E


def test_fae_equal_plaintexts_give_distinct_ciphertexts(bfv_keys):
    m = jnp.full((16,), 77, jnp.int64)
    ct1 = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(0))
    ct2 = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(1))
    # §5.5: ct_{m_a} != ct_{m_b} even when m_a == m_b
    assert not jnp.array_equal(ct1.c0, ct2.c0)
    assert not jnp.array_equal(ct1.c1, ct2.c1)


def test_fae_equality_obfuscation_is_coinflip(bfv_keys):
    """Querying a>b on equal FAE plaintexts must look random (paper §5.1):
    neither all-True nor all-False, and a!=b probes stay correct."""
    n = 64
    m = jnp.full((n,), 500, jnp.int64)
    ct1 = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(2))
    ct2 = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(3))
    out = np.asarray(C.compare_fae(bfv_keys, ct1, ct2))
    frac = out.mean()
    assert 0.15 < frac < 0.85, f"equality leak: frac True = {frac}"


def test_fae_no_bidirectional_equality_probe(bfv_keys):
    """CmpFAE(a,b) and CmpFAE(b,a) must not jointly reveal a==b:
    for equal plaintexts the two probes are CONSISTENT (same perturbed
    order), which is exactly what a!=b pairs produce too."""
    n = 32
    m = jnp.full((n,), 123, jnp.int64)
    ct1 = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(4))
    ct2 = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(5))
    ab = np.asarray(C.compare_fae(bfv_keys, ct1, ct2))
    ba = np.asarray(C.compare_fae(bfv_keys, ct2, ct1))
    # perturbed plaintexts usually have a definite order: probes disagree
    # in direction (a>b XOR b>a) except when the rounded perturbations
    # collide (p ~ 1/(2*eps*Delta_enc)); either way there is no
    # deterministic both-True/both-False "equal" signature.
    assert np.mean(ab != ba) > 0.7


def test_fae_preserves_order_for_distinct_values(bfv_keys):
    """|m_a - m_b| >> ε => comparison correctness (paper §5.3)."""
    a = jnp.asarray([10, 200, -50, 1000], jnp.int64)
    b = jnp.asarray([5, 300, -40, -1000], jnp.int64)
    ct_a = E.encrypt_fae(bfv_keys, a, jax.random.PRNGKey(6))
    ct_b = E.encrypt_fae(bfv_keys, b, jax.random.PRNGKey(7))
    out = C.compare_fae(bfv_keys, ct_a, ct_b)
    assert jnp.array_equal(out, a > b)


def test_fae_perturbation_bounded(bfv_params, bfv_keys):
    """Perturbation ε ≪ 1 plaintext unit: FAE decrypt rounds to m."""
    m = jnp.asarray([3, -9, 250], jnp.int64)
    ct = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(8))
    assert jnp.array_equal(E.decrypt(bfv_keys, ct), m)
    # and the perturbation is actually there (raw phase differs from Δ*m)
    raw = E.decrypt_raw(bfv_keys, ct)
    assert int(jnp.max(jnp.abs(raw - m * bfv_params.delta_enc))) > 0


def test_fae_same_ciphertext_shape(bfv_keys):
    """FAE adds zero ciphertext expansion (paper Table 1 row HADES FAE)."""
    m = jnp.asarray([1], jnp.int64)
    basic = E.encrypt(bfv_keys, m, jax.random.PRNGKey(9))
    fae = E.encrypt_fae(bfv_keys, m, jax.random.PRNGKey(10))
    assert basic.c0.shape == fae.c0.shape
    assert basic.c1.shape == fae.c1.shape
