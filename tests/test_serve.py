"""Serving-path equivalence: prefill == forward, decode == forward(S+1)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import serve as SV
from repro.models import transformer as T
from tests.test_models import _batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_and_decode_match_forward(arch):
    cfg = configs.get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, B=B, S=S)
    toks = batch["tokens"]

    full = T.forward(cfg, params, batch)[:, -1]
    logits_pf, cache = SV.prefill(cfg, params, batch, T_max=32)
    assert float(jnp.max(jnp.abs(full - logits_pf))) < 2e-3

    tok_next = jax.random.randint(jax.random.PRNGKey(3), (B,), 0,
                                  cfg.vocab_size)
    batch2 = dict(batch,
                  tokens=jnp.concatenate([toks, tok_next[:, None]], 1))
    full2 = T.forward(cfg, params, batch2)[:, -1]
    logits_dec, cache = SV.decode_step(cfg, params, cache, tok_next)
    assert float(jnp.max(jnp.abs(full2 - logits_dec))) < 2e-2
    assert int(cache["pos"]) == S + 1


def test_multi_token_greedy_decode_consistency():
    """Decoding 4 tokens equals running forward on the grown sequence."""
    cfg = configs.get_reduced("smollm_360m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, gen = 2, 16, 4
    batch = _batch(cfg, B=B, S=S)
    logits, cache = SV.prefill(cfg, params, batch, T_max=S + gen)
    toks = batch["tokens"]
    for _ in range(gen):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
        logits, cache = SV.decode_step(cfg, params, cache, nxt)
    ref_logits = T.forward(cfg, params, {"tokens": toks})[:, -1]
    assert float(jnp.max(jnp.abs(ref_logits - logits))) < 2e-2


def test_local_ring_buffer_beyond_window():
    """recurrentgemma decode far past the window stays finite + bounded
    state (the long_500k eligibility mechanics)."""
    cfg = configs.get_reduced("recurrentgemma_9b")   # window 16
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B = 1
    batch = _batch(cfg, B=B, S=24)                   # S > window
    logits, cache = SV.prefill(cfg, params, batch, T_max=24)
    for i in range(20):                              # decode past window
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = SV.decode_step(cfg, params, cache, tok)
        assert not bool(jnp.any(jnp.isnan(logits)))
    # cache never grew: k is [G, B, W, KV, hd]
    k = cache["blocks"]["b2"]["k"]
    assert k.shape[2] == cfg.window


def test_cache_shapes_constant_under_decode():
    cfg = configs.get_reduced("xlstm_125m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=2, S=8)
    _, cache = SV.prefill(cfg, params, batch, T_max=8)
    shapes0 = jax.tree.map(lambda x: x.shape, cache["blocks"])
    tok = jnp.zeros((2,), jnp.int32)
    _, cache2 = SV.decode_step(cfg, params, cache, tok)
    shapes1 = jax.tree.map(lambda x: x.shape, cache2["blocks"])
    assert shapes0 == shapes1
