"""Lane-tiled, deduped fused scans: byte-identity + accounting.

The bandwidth fix (column dedup + lane-budgeted tiles) must be
invisible to every consumer of `fused_eval`: the raw eval values are
exact integer ring arithmetic, so tiling the row axis and gathering
deduped columns inside the program must reproduce the untiled launch
BYTE FOR BYTE — across schemes (bfv + ckks), engines (jnp + kernel),
tile sizes (including a ragged tail when the union scan width is not a
multiple of the pow2 tile), delta-widened scans (base ∪ delta), and
the S ∈ {1..4} shard placements.

The accounting side is load-bearing too: `bytes.moved` must reflect the
DEDUPED stack (U unique columns, not A atom copies), `eval.lanes` must
still sum to exactly `scan_compares` across tiles, and `eval.tiles`
must count the launches the budget implies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import db, obs
from repro.core import encrypt as E
from repro.db import executor as X
from repro.db import plan as P
from repro.kernels import ops as KO

GRID = 0.25          # ckks value lattice (>> test-ckks tolerance ~0.016)


@pytest.fixture(autouse=True)
def _fresh_obs():
    """`obs.tracing(fresh=True)` clears state on ENTRY, not exit — drop
    this file's spans/counters so later files see a pristine tracer
    (test_obs asserts the disabled-state buffers are empty)."""
    yield
    obs.TRACER.clear()
    obs.REGISTRY.reset()

BASE_INTS = np.array([5, 1, 9, 3, 7, 2, 8, 4, 6, 0, 11, 13], np.int64)
DELTA_INTS = np.array([10, 3, 12], np.int64)


def _vals(ks, ints):
    if ks.params.profile.scheme == "ckks":
        return np.asarray(ints, np.float64) * GRID
    return np.asarray(ints, np.int64)


def _bound(ks, v):
    if ks.params.profile.scheme == "ckks":
        return float(v) * GRID
    return int(v)


def _enc(ks, v, seed):
    return E.encrypt(ks, jnp.asarray(v), jax.random.PRNGKey(seed))


def _table(ks, ints, name="tiling"):
    return db.Table.from_arrays(ks, name, {"v": _vals(ks, ints)},
                                jax.random.PRNGKey(2))


def _range_query(ks, lo, hi, seed=100):
    return db.Range("v", _enc(ks, _bound(ks, lo), seed),
                    _enc(ks, _bound(ks, hi), seed + 1))


def _scan_atoms(query):
    plan = P.compile_plan(query)
    atoms = []
    for i in range(plan.num_leaves):
        atoms.extend(plan.scan_atoms(i))
    return atoms


# ---------------------------------------------------------------------------
# the lane-budget policy itself
# ---------------------------------------------------------------------------

def test_lane_tile_formula():
    # largest pow2 T with T·lanes_per_row <= budget, clamped to [1, n]
    assert KO.lane_tile(64, 4, 32) == 8
    assert KO.lane_tile(64, 4, 33) == 8          # rounds DOWN to pow2
    assert KO.lane_tile(64, 4, 63) == 8
    assert KO.lane_tile(64, 4, 64) == 16
    assert KO.lane_tile(8, 4, 1 << 20) == 8      # clamped to n_rows
    assert KO.lane_tile(64, 1000, 4) == 1        # never below one row
    # matches the join grid's historical formula exactly
    from repro.db.join import _grid_tile
    for budget in (1 << 10, 1 << 14, 12345):
        for n_l, n_r in ((64, 32), (128, 100), (16, 1 << 12)):
            assert KO.lane_tile(n_l, n_r, budget) == \
                _grid_tile(budget, n_l, n_r)


def test_lane_budget_resolution_order(monkeypatch):
    monkeypatch.delenv("REPRO_LANE_BUDGET", raising=False)
    assert KO.resolve_lane_budget() == KO.DEFAULT_LANE_BUDGET
    assert KO.resolve_lane_budget(default=123) == 123
    monkeypatch.setenv("REPRO_LANE_BUDGET", "4096")
    assert KO.resolve_lane_budget() == 4096      # env beats default
    prev = KO.set_lane_budget(512)
    try:
        assert KO.resolve_lane_budget() == 512   # override beats env
        assert KO.resolve_lane_budget(64) == 64  # explicit beats all
    finally:
        KO.set_lane_budget(prev)
    assert KO.resolve_lane_budget() == 4096


# ---------------------------------------------------------------------------
# byte-identity: tiled/deduped vs the one-shot launch
# ---------------------------------------------------------------------------

def test_fused_eval_tiled_identical_across_schemes(scheme_ks):
    ks = scheme_ks
    table = _table(ks, BASE_INTS)
    # And(Range, Range) on ONE column: 4 atoms, U=1 — the dedup shape
    q = db.And(_range_query(ks, 3, 8, 100), _range_query(ks, 2, 11, 200))
    atoms = _scan_atoms(q)
    assert len(atoms) == 4
    ref = X.fused_eval(ks, table, atoms, lane_budget=1 << 20)  # one tile
    for budget in (4, 16, 31):     # T = 1, 4, and a non-pow2 budget
        out = X.fused_eval(ks, table, atoms, lane_budget=budget)
        np.testing.assert_array_equal(out, ref)
    # and the decoded masks agree with plaintext
    vals = _vals(ks, BASE_INTS)
    want = ((vals >= _bound(ks, 3)) & (vals <= _bound(ks, 8))
            & (vals >= _bound(ks, 2)) & (vals <= _bound(ks, 11)))
    res = db.execute(ks, table, q, lane_budget=16)
    np.testing.assert_array_equal(res.mask, want)


def test_fused_eval_kernel_engine_tiled_identical(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, BASE_INTS)
    atoms = _scan_atoms(_range_query(ks, 3, 8))
    ref = X.fused_eval(ks, table, atoms, engine="jnp")
    for budget in (8, 1 << 20):
        out = X.fused_eval(ks, table, atoms, engine="kernel",
                           lane_budget=budget)
        np.testing.assert_array_equal(out, ref)


def test_ragged_tail_tile_on_delta_widened_scan(scheme_ks):
    ks = scheme_ks
    table = _table(ks, BASE_INTS, name="tiling_delta")
    table.insert(ks, {"v": _vals(ks, DELTA_INTS)}, jax.random.PRNGKey(9))
    assert table.scan_width == 20          # 16-pad base + 4-pad delta
    q = _range_query(ks, 3, 10)
    atoms = _scan_atoms(q)                 # A=2
    ref = X.fused_eval(ks, table, atoms, lane_budget=1 << 20)
    with obs.tracing():
        out = X.fused_eval(ks, table, atoms, lane_budget=16)  # T=8: 8+8+4
        assert obs.REGISTRY.value("eval.tiles") == 3
        assert obs.REGISTRY.value("eval.launches") == 3
        assert obs.REGISTRY.value("eval.lanes") == 2 * 20
    np.testing.assert_array_equal(out, ref)
    # end-to-end over base ∪ delta, tiled, matches plaintext
    allv = np.concatenate([_vals(ks, BASE_INTS), _vals(ks, DELTA_INTS)])
    want = (allv >= _bound(ks, 3)) & (allv <= _bound(ks, 10))
    res = db.execute(ks, table, q, lane_budget=16)
    np.testing.assert_array_equal(res.mask, want)


@pytest.mark.parametrize("shards", [1, 2, 3, 4])
def test_shard_invariance_with_nondefault_budget(bfv_engine_ks, shards):
    ks = bfv_engine_ks
    table = _table(ks, np.arange(40) % 17, name="tiling_shard")
    q = db.And(_range_query(ks, 3, 11, 300), _range_query(ks, 5, 16, 400))
    ref = db.execute(ks, table, q)
    st = db.ShardedTable.from_table(ks, table,
                                    spec=db.ShardSpec.create(shards))
    for budget in (None, 16):
        res = db.execute(ks, st, q, lane_budget=budget)
        np.testing.assert_array_equal(res.mask, ref.mask)
        np.testing.assert_array_equal(res.row_ids, ref.row_ids)


# ---------------------------------------------------------------------------
# accounting: deduped bytes, tiled launches, reconciled lanes
# ---------------------------------------------------------------------------

def test_dedup_bytes_and_lane_accounting(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, BASE_INTS)
    q = db.And(_range_query(ks, 3, 8, 100), _range_query(ks, 2, 11, 200))
    atoms = _scan_atoms(q)                 # A=4 atoms, U=1 unique column
    W = table.scan_width
    uniq, sel = X.dedup_atom_columns(table, atoms, table.scan_column)
    assert uniq.c0.shape[0] == 1 and sel.tolist() == [0, 0, 0, 0]
    bounds = X.stack_atom_bounds(atoms)
    with obs.tracing():
        vals = X.fused_eval(ks, table, atoms)
        # bytes moved are the UNIQUE stack + bounds (c0 and c1), not A
        # full column copies — the dedup invariant in numbers
        assert obs.REGISTRY.value("bytes.moved") == \
            2 * (uniq.c0.nbytes + bounds.c0.nbytes)
        assert obs.REGISTRY.value("eval.lanes") == len(atoms) * W
        assert obs.REGISTRY.value("eval.launches") == \
            obs.REGISTRY.value("eval.tiles") == 1
    assert vals.shape == (len(atoms), W)


def test_query_server_lane_budget_tiles_and_reconciles(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, BASE_INTS)
    vals = _vals(ks, BASE_INTS)
    server = db.QueryServer(ks, table, batch=4, lane_budget=8)
    bounds = [(3, 9), (5, 11), (2, 8)]
    qids = [server.submit(_range_query(ks, lo, hi, 500 + 10 * i))
            for i, (lo, hi) in enumerate(bounds)]
    with obs.tracing():
        res = server.run()
        # 3 queries × 2 atoms = 6 lanes/row, budget 8 -> T=1: 16 tiles,
        # all inside ONE fused_eval pass (eval_calls stays 1)
        names = [s.name for s in obs.TRACER.spans]
        assert names.count("executor.fused_eval") == 1
        n_tiles = names.count("executor.eval_tile")
        assert n_tiles == table.scan_width          # T=1 at budget 8
        assert obs.REGISTRY.value("eval.tiles") == n_tiles
        assert obs.REGISTRY.value("eval.lanes") == \
            server.batch_log[-1].scan_compares
    b = server.batch_log[-1]
    assert b.eval_calls == 1
    assert sum(res[q].stats.scan_compares for q in qids) == b.scan_compares
    for qid, (lo, hi) in zip(qids, bounds):
        want = (vals >= _bound(ks, lo)) & (vals <= _bound(ks, hi))
        np.testing.assert_array_equal(res[qid].mask, want)


def test_join_block_pairs_resolves_through_shared_policy(bfv_engine_ks):
    ks = bfv_engine_ks
    lk = np.arange(16, dtype=np.int64) % 4
    rk = np.arange(8, dtype=np.int64) % 4
    lt = db.Table.from_arrays(ks, "tl", {"k": lk}, jax.random.PRNGKey(30))
    rt = db.Table.from_arrays(ks, "tr", {"k": rk}, jax.random.PRNGKey(31))
    join = db.Join(None, None, on="k")
    want = np.argwhere(lk[:, None] == rk[None, :])
    ref = db.execute_join(ks, lt, rt, join, strategy="nested")
    np.testing.assert_array_equal(ref.pairs, want)
    # a process-wide budget override shrinks the grid tiles (more eval
    # calls), identical pairs — one knob governing scans AND joins
    prev = KO.set_lane_budget(16)       # T = 16 // 8 = 2 left rows/tile
    try:
        res = db.execute_join(ks, lt, rt, join, strategy="nested")
    finally:
        KO.set_lane_budget(prev)
    np.testing.assert_array_equal(res.pairs, want)
    assert res.stats.eval_calls == 8 > ref.stats.eval_calls
