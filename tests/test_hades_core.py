"""HADES algorithm correctness: Alg. 1-2 contracts, noise budget, both CEK
modes, hypothesis property sign(compare) == sign(m0 - m1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # collection must survive without hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import compare as C
from repro.core import encrypt as E
from repro.core import noise
from repro.core.keys import keygen
from repro.core.params import make_params


def test_keygen_structure(bfv_params, bfv_keys):
    ks = bfv_keys
    K, n = bfv_params.num_towers, bfv_params.n
    assert ks.pk0.shape == (K, n) and ks.pk1.shape == (K, n)
    D = bfv_params.gadget_digits_per_tower
    assert ks.cek_gadget.shape == (K, D, K, n)
    # Alg.1 line 5: scale > max(2 B_e, ||sk||_inf)
    assert bfv_params.scale > 2 * bfv_params.noise_bound
    assert bfv_params.scale > 1


def test_encrypt_decrypt_roundtrip(bfv_params, bfv_keys):
    m = jnp.asarray([0, 1, -1, 50, -50, 100], jnp.int64)
    ct = E.encrypt(bfv_keys, m, jax.random.PRNGKey(0))
    assert jnp.array_equal(E.decrypt(bfv_keys, ct), m)


def test_fresh_noise_within_budget(bfv_params, bfv_keys):
    m = jnp.zeros((32,), jnp.int64)
    ct = E.encrypt(bfv_keys, m, jax.random.PRNGKey(1))
    mag = E.noise_magnitude(bfv_keys, ct, m)
    budget = noise.predict(bfv_params)
    assert float(jnp.max(mag)) < budget.fresh_worst
    assert float(jnp.max(mag)) < bfv_params.delta_enc / 2   # decrypt-exact


def test_compare_three_way(bfv_keys):
    a = jnp.asarray([5, 3, 7, 0, -10, 100], jnp.int64)
    b = jnp.asarray([3, 5, 7, 0, 50, -100], jnp.int64)
    ct_a = E.encrypt(bfv_keys, a, jax.random.PRNGKey(2))
    ct_b = E.encrypt(bfv_keys, b, jax.random.PRNGKey(3))
    out = C.compare(bfv_keys, ct_a, ct_b)
    assert jnp.array_equal(out, jnp.sign(a - b).astype(jnp.int32))


def test_compare_adjacent_values(bfv_keys):
    """|m0-m1| = 1 must still separate from equality (τ contract)."""
    a = jnp.arange(-8, 8, dtype=jnp.int64)
    ct_a = E.encrypt(bfv_keys, a, jax.random.PRNGKey(4))
    ct_b = E.encrypt(bfv_keys, a + 1, jax.random.PRNGKey(5))
    assert jnp.all(C.compare(bfv_keys, ct_a, ct_b) == -1)
    assert jnp.all(C.compare(bfv_keys, ct_b, ct_a) == 1)
    ct_c = E.encrypt(bfv_keys, a, jax.random.PRNGKey(6))
    assert jnp.all(C.compare(bfv_keys, ct_a, ct_c) == 0)


def test_paper_mode_with_precondition(paper_params, paper_keys):
    """Literal Alg. 1-2 with the Thm 4.1 noise precondition enforced."""
    a = jnp.asarray([5, 3, 7, 0], jnp.int64)
    b = jnp.asarray([3, 5, 7, -2], jnp.int64)
    ct_a = E.encrypt(paper_keys, a, jax.random.PRNGKey(2))
    ct_b = E.encrypt(paper_keys, b, jax.random.PRNGKey(3))
    out = C.compare(paper_keys, ct_a, ct_b)
    assert jnp.array_equal(out, jnp.sign(a - b).astype(jnp.int32))


def test_paper_mode_full_noise_breaks_correctness(paper_params):
    """The §1.1 finding: literal U(-B_e,B_e)^n e_cek wraps mod q and
    destroys the comparison — the paper's precondition is load-bearing."""
    ks = keygen(paper_params, jax.random.PRNGKey(42),
                paper_ecek_weight=None)      # full-density noise
    a = jnp.arange(0, 64, dtype=jnp.int64)
    b = a + 7
    ct_a = E.encrypt(ks, a, jax.random.PRNGKey(2))
    ct_b = E.encrypt(ks, b, jax.random.PRNGKey(3))
    out = C.compare(ks, ct_a, ct_b)
    errs = int(jnp.sum(out != -1))
    assert errs > 16, f"expected broken comparisons, errs={errs}"


def test_no_ciphertext_expansion(bfv_params, bfv_keys):
    """Paper §3.4: comparison uses the existing 2-component ciphertext."""
    m = jnp.asarray([1, 2], jnp.int64)
    ct = E.encrypt(bfv_keys, m, jax.random.PRNGKey(0))
    assert len(ct) == 2
    assert ct.c0.shape == ct.c1.shape == \
        (2, bfv_params.num_towers, bfv_params.n)


def test_ckks_float_compare(ckks_params, ckks_keys):
    a = jnp.asarray([1.5, 2.25, -3.75, 0.0])
    b = jnp.asarray([1.25, 2.5, -3.5, 0.0])
    ct_a = E.encrypt(ckks_keys, a, jax.random.PRNGKey(0))
    ct_b = E.encrypt(ckks_keys, b, jax.random.PRNGKey(1))
    out = C.compare(ckks_keys, ct_a, ct_b)
    assert jnp.array_equal(out, jnp.asarray([1, -1, -1, 0], jnp.int32))
    dec = E.decrypt(ckks_keys, ct_a)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(a), atol=1e-3)


def test_noise_model_predicts_soundness(bfv_params):
    assert noise.compare_is_sound(bfv_params)
    b = noise.predict(bfv_params)
    assert b.headroom_bits > 0


# hypothesis can't take function-scoped fixtures — lazily built module keys
_KEYS_H = {}


def _keys_h():
    if "ks" not in _KEYS_H:
        _KEYS_H["ks"] = keygen(make_params("test-bfv", mode="gadget"),
                               jax.random.PRNGKey(42))
    return _KEYS_H["ks"]


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.integers(-500, 500), min_size=2, max_size=6),
           st.integers(0, 2**30))
    def test_compare_sign_property(ms, seed):
        ks = _keys_h()
        a = jnp.asarray(ms, jnp.int64)
        b = jnp.roll(a, 1)
        ct_a = E.encrypt(ks, a, jax.random.PRNGKey(seed))
        ct_b = E.encrypt(ks, b, jax.random.PRNGKey(seed + 1))
        out = C.compare(ks, ct_a, ct_b)
        assert jnp.array_equal(out, jnp.sign(a - b).astype(jnp.int32))
else:
    def test_compare_sign_property():
        pytest.importorskip("hypothesis")


def test_compare_range_limit(bfv_params, bfv_keys):
    """Operands at the documented max_operand still compare correctly."""
    lim = bfv_params.max_operand
    a = jnp.asarray([lim, -lim], jnp.int64)
    b = jnp.asarray([0, 0], jnp.int64)
    ct_a = E.encrypt(bfv_keys, a, jax.random.PRNGKey(0))
    ct_b = E.encrypt(bfv_keys, b, jax.random.PRNGKey(1))
    assert jnp.array_equal(C.compare(bfv_keys, ct_a, ct_b),
                           jnp.asarray([1, -1], jnp.int32))
