"""End-to-end system tests: the paper's database workflow over the full
stack, and HADES x LM-serving integration (DESIGN.md §2.1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import load_dataset
from repro.models import serve as SV
from repro.models import transformer as T


def test_outsourced_database_workflow():
    """Client encrypts a column; server builds an encrypted index (sort),
    answers a range query, and never sees a plaintext."""
    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(0))
    col_plain = (load_dataset("covid19", scheme="bfv", t=params.t)[:32]
                 % (params.max_operand // 2)).astype(np.int64)
    column = E.encrypt(ks, jnp.asarray(col_plain), jax.random.PRNGKey(1))

    # index build = encrypted sort
    _, perm = C.encrypted_sort(ks, column)
    assert np.array_equal(col_plain[np.asarray(perm)], np.sort(col_plain))

    # range query
    lo_v = int(np.percentile(col_plain, 30))
    hi_v = int(np.percentile(col_plain, 70))
    mask = C.range_query(
        ks, column,
        E.encrypt(ks, jnp.asarray(lo_v), jax.random.PRNGKey(2)),
        E.encrypt(ks, jnp.asarray(hi_v), jax.random.PRNGKey(3)))
    want = (col_plain >= lo_v) & (col_plain <= hi_v)
    assert np.array_equal(np.asarray(mask), want)


def test_secure_topk_over_lm_scores():
    """serve_step logits -> CKKS-encrypt -> HADES top-k == plaintext top-k
    (up to the documented CKKS equality tolerance)."""
    cfg = configs.get_reduced("smollm_360m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 16),
                                          0, cfg.vocab_size)}
    logits, _ = SV.prefill(cfg, params, batch)
    cand = jnp.arange(0, 16) * 7
    scores = logits[0, cand].astype(jnp.float64)

    hp = make_params("test-ckks", mode="gadget")
    hks = keygen(hp, jax.random.PRNGKey(3))
    enc = E.encrypt(hks, scores, jax.random.PRNGKey(4))
    k = 4
    _, top_idx = C.encrypted_topk(hks, enc, k)
    got = set(np.asarray(cand)[np.asarray(top_idx)].tolist())
    want = set(np.asarray(cand)[np.argsort(np.asarray(scores))[-k:]].tolist())
    # allow 1 swap at the boundary if scores are within tolerance
    assert len(got & want) >= k - 1


def test_fae_protects_against_frequency_analysis_of_column():
    """Equality probing on an all-equal column, pinning Finding F2
    (EXPERIMENTS.md):

    * the FAE PROTOCOL comparator (Alg. 4, strict) outputs independent
      coin flips on ties — no equality signature (the paper's claim);
    * but a curious server running the Alg. 2 τ-decode on FAE ciphertexts
      STILL sees |EvalValue| < τ, because the paper's ε ∈ [1e-3, 1e-2]
      perturbation is ~100x smaller than the tie threshold (0.5 plaintext
      units).  FAE defeats the protocol-level probe, not a thresholding
      adversary — a real limitation of the paper's parameter choice.
    """
    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(0))
    col = jnp.full((32,), 7, jnp.int64)                       # all equal
    b1 = E.encrypt(ks, col, jax.random.PRNGKey(1))
    b2 = E.encrypt(ks, col, jax.random.PRNGKey(2))
    basic_zero_rate = float((np.asarray(C.compare(ks, b1, b2)) == 0).mean())
    assert basic_zero_rate == 1.0            # Basic: ties fully visible

    f1 = E.encrypt_fae(ks, col, jax.random.PRNGKey(3))
    f2 = E.encrypt_fae(ks, col, jax.random.PRNGKey(4))
    flips = np.asarray(C.compare_fae(ks, f1, f2))     # Alg. 4: coin flips
    assert 0.1 < flips.mean() < 0.9
    # Finding F2: τ-decode still detects the ties despite FAE
    tau_probe_rate = float((np.asarray(C.compare(ks, f1, f2)) == 0).mean())
    assert tau_probe_rate > 0.9, tau_probe_rate
