"""Pallas kernels vs pure-jnp oracles: exact equality over shape sweeps.

Modular integer arithmetic admits no tolerance — assert_array_equal, not
allclose.  interpret=True executes the kernel body on CPU.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import compare as C
from repro.core import encrypt as E
from repro.core import ring as R
from repro.core import sampling
from repro.core.keys import keygen
from repro.core.params import PROFILES, Profile, make_params
from repro.kernels import ops, ref

import dataclasses


def _params(n, towers, mode="gadget"):
    prof = dataclasses.replace(PROFILES["test-bfv"], n=n, num_towers=towers,
                               name=f"sweep-{n}-{towers}")
    return make_params(prof, mode=mode)


@pytest.mark.parametrize("n", [64, 256, 1024])
@pytest.mark.parametrize("towers", [1, 2])
@pytest.mark.parametrize("batch", [1, 5, 8])
def test_ntt_kernel_sweep(n, towers, batch):
    params = _params(n, towers)
    ring = R.make_ring(params)
    x = sampling.uniform_poly(params, jax.random.PRNGKey(n + batch), (batch,))
    got = ops.ntt(x, ring)
    want = ref.ntt_br(x, ring, fwd=True)
    assert jnp.array_equal(got, want)
    back = ops.intt(got, ring)
    assert jnp.array_equal(back, x)


@pytest.mark.parametrize("n,towers,batch", [(64, 1, 3), (256, 2, 8),
                                            (1024, 1, 2)])
def test_fused_mul_kernel_sweep(n, towers, batch):
    params = _params(n, towers)
    ring = R.make_ring(params)
    a = sampling.uniform_poly(params, jax.random.PRNGKey(1), (batch,))
    b = sampling.uniform_poly(params, jax.random.PRNGKey(2), (batch,))
    got = ops.negacyclic_mul(a, b, ring)
    want = ref.negacyclic_mul(a, b, ring)
    assert jnp.array_equal(got, want)


@pytest.mark.parametrize("mode", ["paper", "gadget"])
@pytest.mark.parametrize("batch", [2, 7])
def test_fused_compare_kernel(mode, batch):
    params = make_params("test-bfv", mode=mode)
    ks = keygen(params, jax.random.PRNGKey(42),
                paper_ecek_weight=0 if mode == "paper" else None)
    a = jnp.arange(batch, dtype=jnp.int64) * 3 - 4
    b = jnp.flip(a)
    ct_a = E.encrypt(ks, a, jax.random.PRNGKey(8))
    ct_b = E.encrypt(ks, b, jax.random.PRNGKey(9))
    want = C.compare(ks, ct_a, ct_b)
    got = ops.compare(ks, ct_a, ct_b)
    assert jnp.array_equal(got, want)
    assert jnp.array_equal(got, jnp.sign(a - b).astype(jnp.int32))


def test_kernel_block_padding():
    """Batches not divisible by block_b are padded and truncated."""
    params = _params(64, 1)
    ring = R.make_ring(params)
    for batch in (1, 3, 9, 17):
        x = sampling.uniform_poly(params, jax.random.PRNGKey(batch),
                                  (batch,))
        got = ops.ntt(x, ring, block_b=8)
        assert got.shape == x.shape
        assert jnp.array_equal(got, ref.ntt_br(x, ring, fwd=True))


def test_kernel_eval_matches_core_eval_value(bfv_params, bfv_keys):
    """The fused kernel's coeff0 decode equals core eval_value exactly."""
    from repro.core.compare import eval_value, ct_sub
    from repro.core.gadget import digit_decompose
    from repro.kernels import cmp_eval as CK
    a = jnp.asarray([4, -2], jnp.int64)
    b = jnp.asarray([1, 5], jnp.int64)
    ct_a = E.encrypt(bfv_keys, a, jax.random.PRNGKey(0))
    ct_b = E.encrypt(bfv_keys, b, jax.random.PRNGKey(1))
    want = eval_value(bfv_keys, ct_a, ct_b)
    d = ct_sub(bfv_keys.ring, ct_a, ct_b)
    digits = digit_decompose(bfv_params, d.c1)
    Bb = digits.shape[0]
    Eg = bfv_params.num_towers * bfv_params.gadget_digits_per_tower
    dig = jnp.broadcast_to(
        digits.reshape(Bb, Eg, 1, bfv_params.n),
        (Bb, Eg, bfv_params.num_towers, bfv_params.n))
    coeff0 = CK.eval_coeff0_gadget(
        jnp.pad(d.c0, ((0, 6), (0, 0), (0, 0))),
        jnp.pad(dig, ((0, 6), (0, 0), (0, 0), (0, 0))),
        CK.cek_gadget_to_br(bfv_keys), bfv_keys.ring, bfv_params.scale)
    got = R.crt_centered(bfv_params, coeff0[:2])
    assert jnp.array_equal(got, want)
