"""repro.obs: spans, metrics, launch accounting, and the counter
reconciliation contract between per-query ExecStats and BatchStats.

The reconciliation invariants (asserted here for scan, indexed, join,
and mutation batches, on both servers):

  * compare lanes ARE summable — sum of per-query scan_compares /
    index_compares over a drained batch equals the batch totals exactly
    (every lane belongs to exactly one query);
  * eval_calls are NOT summable — each query's share of the one fused
    launch is 1, the batch counts the launch once.

Span tests run with the tracer freshly enabled via `obs.tracing()`;
everything restores the prior disabled state on exit, so the rest of
the suite keeps the zero-overhead path.
"""
import json

import jax
import numpy as np
import pytest

from repro import db, obs
from repro.core import encrypt as E


def _enc(ks, v, seed):
    return E.encrypt(ks, np.int64(int(v)), jax.random.PRNGKey(seed))


def _table(ks, vals, name="t"):
    return db.Table.from_arrays(ks, name, {"v": np.asarray(vals, np.int64)},
                                jax.random.PRNGKey(2))


VALS = np.array([3, 14, 15, 9, 26, 5, 35, 8, 97, 93, 23, 84], np.int64)


# ---------------------------------------------------------------------------
# span / tracer primitives
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop_singleton():
    assert not obs.is_enabled()
    s1 = obs.span("a", x=1)
    s2 = obs.span("b")
    assert s1 is s2                      # no allocation on the hot path
    with s1 as sp:
        sp.set(y=2)                      # all no-ops
        assert sp.sync(123) == 123       # identity, never blocks
    assert len(obs.TRACER.spans) == 0


def test_disabled_counters_do_not_record():
    assert not obs.is_enabled()
    before = dict(obs.REGISTRY.snapshot())
    obs.count("eval.launches", 5)
    obs.observe("pad.waste", 2.0)
    obs.jit_launch("nowhere", np.zeros((2, 2)))
    assert obs.REGISTRY.snapshot() == before


def test_span_nesting_parent_ids_and_depth():
    with obs.tracing():
        with obs.span("root", k="v"):
            with obs.span("child"):
                with obs.span("grandchild"):
                    pass
            with obs.span("child2"):
                pass
    by_name = {s.name: s for s in obs.TRACER.spans}
    root = by_name["root"]
    assert root.parent_sid == -1 and root.depth == 0
    assert by_name["child"].parent_sid == root.sid
    assert by_name["child2"].parent_sid == root.sid
    assert by_name["grandchild"].parent_sid == by_name["child"].sid
    assert by_name["grandchild"].depth == 2
    for s in obs.TRACER.spans:
        assert s.t1 >= s.t0


def test_tracing_context_restores_disabled_state():
    assert not obs.is_enabled()
    with obs.tracing():
        assert obs.is_enabled()
    assert not obs.is_enabled()


def test_chrome_trace_shape_and_validation():
    with obs.tracing():
        with obs.span("outer", rows=4):
            with obs.span("inner"):
                pass
    doc = obs.chrome_trace()
    assert obs.validate_chrome_trace(doc) == []
    events = doc["traceEvents"]
    assert len(events) == 2
    for ev in events:
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], (int, float))
        assert "pid" in ev and "tid" in ev and "dur" in ev
    # validation catches a broken event
    bad = {"traceEvents": [{"name": "x"}]}
    assert obs.validate_chrome_trace(bad) != []
    assert obs.validate_chrome_trace(json.dumps(doc)) == []


def test_write_chrome_trace_roundtrip(tmp_path):
    with obs.tracing():
        with obs.span("only"):
            pass
        path = tmp_path / "trace.json"
        obs.write_chrome_trace(str(path))
    loaded = json.loads(path.read_text())
    assert obs.validate_chrome_trace(loaded) == []
    assert loaded["traceEvents"][0]["name"] == "only"


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counters_and_labels():
    reg = obs.Registry()
    reg.counter("q").inc()
    reg.counter("q", tenant="a").inc(3)
    reg.counter("q", tenant="b").inc(4)
    assert reg.value("q") == 1
    assert reg.value("q", tenant="a") == 3
    snap = reg.snapshot()
    assert snap["q{tenant=a}"] == 3 and snap["q{tenant=b}"] == 4


def test_histogram_percentiles_nearest_rank():
    reg = obs.Registry()
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["sum"] == 5050.0
    assert s["p50"] == 50.0 and s["p99"] == 99.0
    assert h.percentile(100) == 100.0


def test_registry_reset():
    reg = obs.Registry()
    reg.counter("x").inc(7)
    reg.reset()
    assert reg.snapshot() == {}


# ---------------------------------------------------------------------------
# jit-cache observer
# ---------------------------------------------------------------------------

def test_jitwatch_counts_signatures_and_retraces():
    with obs.tracing():
        a = np.zeros((4, 8), np.int64)
        obs.jit_launch("site.x", a)
        obs.jit_launch("site.x", a)              # same signature: no retrace
        assert obs.REGISTRY.value("jit.retraces") == 0
        obs.jit_launch("site.x", np.zeros((4, 16), np.int64))  # new shape
        assert obs.REGISTRY.value("jit.retraces") == 1
        assert obs.REGISTRY.value("jit.retraces", site="site.x") == 1
        assert obs.REGISTRY.value("launches", site="site.x") == 3
        sigs = obs.jit_signatures()
        assert len(sigs["site.x"]) == 2


def test_bench_fields_keys():
    with obs.tracing():
        obs.count("eval.launches")
        obs.count("eval.lanes", 64)
        f = obs.bench_fields()
    assert f == {"eval_launches": 1, "compare_lanes": 64, "jit_retraces": 0}


# ---------------------------------------------------------------------------
# traced engine paths: every launch appears as a span
# ---------------------------------------------------------------------------

def test_traced_scan_query_span_tree(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, VALS)
    q = db.Eq("v", _enc(ks, 15, 3))
    db.execute(ks, table, q)                     # warm outside the trace
    with obs.tracing():
        res = db.execute(ks, table, q)
    names = [s.name for s in obs.TRACER.spans]
    assert "executor.execute" in names
    assert names.count("executor.fused_eval") == res.stats.eval_calls == 1
    fe = next(s for s in obs.TRACER.spans if s.name == "executor.fused_eval")
    ex = next(s for s in obs.TRACER.spans if s.name == "executor.execute")
    assert fe.parent_sid == ex.sid               # launch nests in execute
    # counters absorbed the ExecStats and the launch accounting agrees
    assert obs.REGISTRY.value("eval.launches") == 1
    assert obs.REGISTRY.value("eval.lanes") == res.stats.scan_compares
    assert obs.REGISTRY.value("exec.scan_compares") == res.stats.scan_compares


def test_traced_indexed_query_has_probe_spans(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, VALS)
    idx = db.SortedIndex.build(ks, table, "v")
    q = db.Range("v", _enc(ks, 5, 4), _enc(ks, 30, 5))
    db.execute(ks, table, q, indexes={"v": idx})            # warm
    with obs.tracing():
        res = db.execute(ks, table, q, indexes={"v": idx})
    names = [s.name for s in obs.TRACER.spans]
    assert "index.search" in names
    search = next(s for s in obs.TRACER.spans if s.name == "index.search")
    assert search.args["probes"] == res.stats.index_compares
    assert obs.REGISTRY.value("index.probes") == res.stats.index_compares
    # one launch per binary-search step, all lanes accounted
    assert obs.REGISTRY.value("eval.launches") > 0
    assert obs.REGISTRY.value("eval.lanes") >= res.stats.index_compares


# ---------------------------------------------------------------------------
# counter reconciliation: per-query stats vs batch totals
# ---------------------------------------------------------------------------

def test_reconcile_scan_batch(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, VALS)
    server = db.QueryServer(ks, table, batch=4)
    qids = [server.submit(db.Range("v", _enc(ks, lo, 10 + lo),
                                   _enc(ks, hi, 50 + hi)))
            for lo, hi in [(3, 9), (5, 26), (8, 97)]]
    qids.append(server.submit(db.Eq("v", _enc(ks, 23, 99))))
    res = server.run()
    b = server.batch_log[-1]
    assert b.eval_calls == 1                      # one fused launch
    assert sum(res[q].stats.scan_compares for q in qids) == b.scan_compares
    assert sum(res[q].stats.index_compares for q in qids) == 0
    for q in qids:                                # share, not a sum term
        assert res[q].stats.eval_calls == 1


def test_reconcile_indexed_batch(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, VALS)
    idx = db.SortedIndex.build(ks, table, "v")
    server = db.QueryServer(ks, table, indexes={"v": idx}, batch=3)
    qids = [server.submit(db.Range("v", _enc(ks, lo, 10 + lo),
                                   _enc(ks, hi, 50 + hi)))
            for lo, hi in [(3, 9), (5, 26), (14, 93)]]
    with obs.tracing():
        res = server.run()
    b = server.batch_log[-1]
    assert b.scan_compares == 0
    assert sum(res[q].stats.index_compares for q in qids) == b.index_compares
    for q in qids:
        assert res[q].stats.index_compares > 0    # every query got its share
    # the metrics layer saw the same totals the stats objects carry
    assert obs.REGISTRY.value("index.probes") == b.index_compares
    assert obs.REGISTRY.value("server.batch_index_compares") == \
        b.index_compares


def test_reconcile_mutation_batch_bills_delta_probes(bfv_engine_ks):
    """After an insert the probe path is base ∪ delta: per-query stats
    must carry BOTH shares and still sum to the batch total."""
    ks = bfv_engine_ks
    table = _table(ks, VALS, name="t_mut")
    idx = db.SortedIndex.build(ks, table, "v")
    server = db.QueryServer(ks, table, indexes={"v": idx}, batch=4)
    server.submit_insert({"v": np.array([7, 50], np.int64)},
                         jax.random.PRNGKey(77))
    server.run()                                  # delta run materialized
    qids = [server.submit(db.Range("v", _enc(ks, 5, 301),
                                   _enc(ks, 60, 302))),
            server.submit(db.Eq("v", _enc(ks, 50, 303)))]
    res = server.run()
    b = server.batch_log[-1]
    assert table.n_delta > 0
    assert sum(res[q].stats.index_compares for q in qids) == b.index_compares
    # both paths billed: each query probed the base index AND the delta run
    base_depth = max(1, (table.n_rows - 1).bit_length())
    for q in qids:
        assert res[q].stats.index_compares > 2 * base_depth
    # answers stay exact across the union probe
    all_vals = np.concatenate([VALS, [7, 50]])
    assert np.array_equal(res[qids[0]].mask,
                          (all_vals >= 5) & (all_vals <= 60))


def test_reconcile_join_batch(bfv_engine_ks):
    ks = bfv_engine_ks
    lt = _table(ks, VALS % 8, name="jl")
    rt = db.Table.from_arrays(ks, "jr", {"k": (VALS[:6] % 8).astype(np.int64)},
                              jax.random.PRNGKey(3))
    left = db.Table.from_arrays(ks, "jl2", {"k": (VALS % 8).astype(np.int64)},
                                jax.random.PRNGKey(4))
    server = db.QueryServer(ks, left, batch=2)
    jid = server.submit_join(db.Join(None, None, on="k"), rt)
    res = server.run()
    b = server.batch_log[-1]
    js = res[jid].stats
    # join-side filter shares fold into stats.left/right; with no WHERE
    # they are zero and the batch only counted the deduped pair grid
    assert js.left.scan_compares + js.right.scan_compares == b.scan_compares
    assert b.pair_compares == js.pair_compares > 0
    want = np.argwhere((VALS % 8)[:, None] == (VALS[:6] % 8)[None, :])
    assert np.array_equal(res[jid].pairs, want)


def test_reconcile_sharded_batch_and_span_nesting(bfv_engine_ks):
    """Sharded server: scan + indexed lanes reconcile, and the shard
    launch spans nest under the batch span (the multi-device CI job
    runs this file on 8 host devices)."""
    ks = bfv_engine_ks
    table = _table(ks, VALS, name="t_sh")
    st = db.ShardedTable.from_table(ks, table, spec=db.ShardSpec.create(2))
    idx = db.ShardedIndex.build(ks, st, "v")
    server = db.ShardedQueryServer(ks, st, indexes={"v": idx}, batch=3)
    qids = [server.submit(db.Range("v", _enc(ks, 3, 401),
                                   _enc(ks, 26, 402))),
            server.submit(db.Eq("v", _enc(ks, 97, 403)))]
    with obs.tracing():
        res = server.run()
    b = server.batch_log[-1]
    assert sum(res[q].stats.index_compares for q in qids) == b.index_compares
    for q in qids:
        assert res[q].stats.index_compares > 0
    spans = obs.TRACER.spans
    batch = next(s for s in spans if s.name == "server.shard_batch")
    nested = [s for s in spans if s.name == "shard.index.search"]
    assert nested, "fan-out search must be traced"
    for s in nested:
        # walk up to the batch span: every shard search nests inside it
        cur = s
        while cur.parent_sid != -1:
            cur = next(p for p in spans if p.sid == cur.parent_sid)
        assert cur.sid == batch.sid
    assert obs.validate_chrome_trace(obs.chrome_trace()) == []


def test_sharded_index_last_probe_counts(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, VALS, name="t_pc")
    st = db.ShardedTable.from_table(ks, table, spec=db.ShardSpec.create(2))
    idx = db.ShardedIndex.build(ks, st, "v")
    from repro.db.index import _stack_cts
    lanes = _stack_cts([_enc(ks, 5, 1), _enc(ks, 26, 2)])
    before = idx.search_compares
    idx.search(ks, lanes, np.array([False, True]))
    assert idx.last_probe_counts.shape == (2,)
    assert int(idx.last_probe_counts.sum()) == idx.search_compares - before


def test_traced_compaction_has_merge_round_spans(bfv_engine_ks):
    """Folding a delta through the merge network traces every round."""
    ks = bfv_engine_ks
    table = _table(ks, VALS, name="t_cmp")
    indexes = {"v": db.SortedIndex.build(ks, table, "v")}
    table.insert(ks, {"v": np.array([7, 50, 2], np.int64)},
                 jax.random.PRNGKey(5))
    with obs.tracing():
        cstats = db.compact(ks, table, indexes)
    names = [s.name for s in obs.TRACER.spans]
    assert "compact" in names and "compact.merge_index" in names
    rounds = [s for s in obs.TRACER.spans if s.name == "merge.round"]
    assert len(rounds) == cstats.merge_rounds > 0
    assert obs.REGISTRY.value("compact.merge_compares") == \
        cstats.merge_compares
    # merge-round compare-swaps land in the launch accounting too
    assert obs.REGISTRY.value("eval.launches") > 0
    assert not table.has_delta


# ---------------------------------------------------------------------------
# tenants and exporters
# ---------------------------------------------------------------------------

def test_per_tenant_attribution(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, VALS)
    server = db.QueryServer(ks, table, batch=2)
    qa = server.submit(db.Eq("v", _enc(ks, 15, 501)), tenant="alice")
    qb = server.submit(db.Range("v", _enc(ks, 3, 502), _enc(ks, 97, 503)),
                       tenant="bob")
    with obs.tracing():
        res = server.run()
    reg = obs.REGISTRY
    assert reg.value("server.queries", tenant="alice") == 1
    assert reg.value("server.queries", tenant="bob") == 1
    assert reg.value("server.compares", tenant="alice") == \
        res[qa].stats.filter_compares
    assert reg.value("server.compares", tenant="bob") == \
        res[qb].stats.filter_compares


def test_metrics_dump_and_bench_fields_from_server(bfv_engine_ks):
    ks = bfv_engine_ks
    table = _table(ks, VALS)
    server = db.QueryServer(ks, table, batch=1)
    server.submit(db.Eq("v", _enc(ks, 15, 601)))
    with obs.tracing():
        server.run()
        dump = obs.metrics_dump()
        fields = obs.bench_fields()
    assert "metrics" in dump and "jit_signatures" in dump
    assert fields["eval_launches"] >= 1
    assert fields["compare_lanes"] >= table.n_padded
    assert dump["metrics"]["server.batches"] == 1
