"""Datasets + token pipeline determinism."""
import jax.numpy as jnp
import numpy as np

from repro.data import load_dataset, DATASETS
from repro.data.datasets import ROW_COUNTS
from repro.train import data as DATA


def test_dataset_row_counts_match_paper():
    assert ROW_COUNTS == {"bitcoin": 1085, "covid19": 340, "hg38": 34423}
    assert sum(ROW_COUNTS.values()) == 35848        # paper §1.2/§6.2.1
    for name in DATASETS:
        assert len(load_dataset(name)) == ROW_COUNTS[name]


def test_dataset_bfv_preprocessing():
    for name in DATASETS:
        v = load_dataset(name, scheme="bfv", t=65537)
        assert v.dtype == np.int64
        assert v.min() >= 0 and v.max() < 65537


def test_dataset_deterministic():
    a = load_dataset("bitcoin")
    b = load_dataset("bitcoin")
    np.testing.assert_array_equal(a, b)


def test_synthetic_batch_deterministic_and_replayable():
    cfg = DATA.DataConfig(vocab_size=1000, seq_len=64, global_batch=4)
    b1 = DATA.synthetic_batch(cfg, 7)
    b2 = DATA.synthetic_batch(cfg, 7)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    b3 = DATA.synthetic_batch(cfg, 8)
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert int(b1["tokens"].min()) >= 0
    assert int(b1["tokens"].max()) < 1000


def test_batches_iterator_start_index():
    cfg = DATA.DataConfig(vocab_size=1000, seq_len=16, global_batch=2)
    it = DATA.batches(cfg, start_index=5)
    first = next(it)
    assert jnp.array_equal(first["tokens"],
                           DATA.synthetic_batch(cfg, 5)["tokens"])


def test_file_dataset(tmp_path):
    arr = np.arange(1000, dtype=np.int32)
    path = tmp_path / "toks.npy"
    np.save(path, arr)
    cfg = DATA.DataConfig(vocab_size=1000, seq_len=10, global_batch=3,
                          path=str(path))
    ds = DATA.FileDataset(cfg)
    b = ds.batch(0)
    assert b["tokens"].shape == (3, 10)
    # windows are contiguous slices of the source
    row = np.asarray(b["tokens"][0])
    assert np.array_equal(row, np.arange(row[0], row[0] + 10))
