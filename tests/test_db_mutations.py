"""Encrypted write path: delta runs, tombstones, compaction.

Covers the mutation lifecycle end to end on both schemes:

  * pad geometry edge cases unlocked for the write path — `next_pow2(0)`
    is 1 (an empty column pads to ONE slot, not two), `Table.empty`,
    insert into an empty table;
  * union reads: scans and index probes answer over base ∪ delta with
    the delta run riding the SAME fused launch (scan) or a per-run
    binary search (index), including duplicate keys split across base
    and delta and ε-band predicates under ckks;
  * deletes as host-side tombstones (delete-all still answers),
    updates as tombstone + re-insert;
  * per-column key derivation by name (crc32), not dict insertion
    order — base and delta ingests agree regardless of column order;
  * compaction through the merge network: answers unchanged, global ids
    stable, merge compares strictly below the from-scratch rebuild at
    realistic sizes;
  * shard invariance S ∈ {1..4}: the mutated + compacted view decrypts
    identically to a from-scratch table holding the same rows;
  * the servers' mutation queues: FIFO visibility (a query sees exactly
    the writes submitted before it) and cooperative compaction under a
    live query load.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import db
from repro.core import encrypt as E
from repro.core.ckks import equality_tolerance
from repro.core.compare import bitonic_compare_count, next_pow2
from repro.db import plan as P
from repro.db.table import Table, column_key, pad_rows_pow2

GRID = 0.25        # ckks float grid (>> test-ckks equality tolerance)
EPS_BAND = 0.3     # ε-band capturing exactly the ±1-grid-step neighbors


def _is_ckks(ks) -> bool:
    return ks.params.profile.scheme == "ckks"


def _vals(ks, ints) -> np.ndarray:
    ints = np.asarray(ints)
    if _is_ckks(ks):
        return ints.astype(np.float64) * GRID
    return ints.astype(np.int64)


def _enc(ks, v, seed):
    v = float(v) if _is_ckks(ks) else int(v)
    return E.encrypt(ks, jnp.asarray(v), jax.random.PRNGKey(seed))


def _bound(ks, v, side):
    return float(v) + side * GRID / 2 if _is_ckks(ks) else int(v)


def _close(ks, got, want):
    """Decrypt comparison bounded by the profile's precision claim
    (exact on bfv)."""
    if _is_ckks(ks):
        return np.allclose(np.asarray(got), np.asarray(want, np.float64),
                           atol=equality_tolerance(ks.params))
    return (np.asarray(got) == np.asarray(want)).all()


def _range(ks, lo, hi, seed):
    return P.Range("v", _enc(ks, _bound(ks, _vals(ks, lo), -1), seed),
                   _enc(ks, _bound(ks, _vals(ks, hi), +1), seed + 1))


# ---------------------------------------------------------------------------
# pad geometry edge cases (the bugfixes that unblock empty/delta tables)
# ---------------------------------------------------------------------------

def test_next_pow2_edge_cases():
    # the n <= 1 cases are the write path's: an empty table and a
    # 1-row delta run must pad to ONE slot (the naive bit-length form
    # returns 2 for n=0)
    assert next_pow2(0) == 1
    assert next_pow2(1) == 1
    assert next_pow2(2) == 2
    assert next_pow2(3) == 4
    assert next_pow2(4) == 4
    assert next_pow2(5) == 8
    assert next_pow2(1023) == 1024
    assert next_pow2(1024) == 1024
    with pytest.raises((ValueError, TypeError)):
        next_pow2(-1)


def test_pad_rows_pow2_shares_next_pow2_geometry():
    for n in (0, 1, 2, 3, 5):
        padded = pad_rows_pow2(np.arange(n, dtype=np.int64))
        assert padded.shape == (next_pow2(n),)
        assert (padded[:n] == np.arange(n)).all()
        assert (padded[n:] == 0).all()
    # n_target must still be a pow2 >= max(n, 1)
    with pytest.raises(ValueError):
        pad_rows_pow2(np.arange(3, dtype=np.int64), n_target=2)
    with pytest.raises(ValueError):
        pad_rows_pow2(np.arange(2, dtype=np.int64), n_target=3)


def test_empty_table_and_insert_into_empty(scheme_ks):
    ks = scheme_ks
    t = Table.empty(ks, "t", ["v"], jax.random.PRNGKey(1))
    assert t.n_rows == 0 and t.n_padded == 1 and t.n_total == 0
    assert not t.valid.any()
    # a query against a fully-empty table answers (no crash, no rows)
    r = db.execute(ks, t, _range(ks, 0, 100, 10))
    assert len(r.row_ids) == 0
    ids = t.insert(ks, {"v": _vals(ks, [5, 9, 2])}, jax.random.PRNGKey(2))
    assert ids.tolist() == [0, 1, 2]
    got = t.decrypt_column(ks, "v")
    assert _close(ks, got, _vals(ks, [5, 9, 2]))
    r = db.execute(ks, t, _range(ks, 3, 9, 12))
    assert sorted(r.row_ids) == [0, 1]


def test_from_arrays_rejects_zero_padding_underflow():
    with pytest.raises(ValueError):
        pad_rows_pow2(np.arange(4, dtype=np.int64), n_target=1)


# ---------------------------------------------------------------------------
# per-column keys derive from the NAME (crc32), not dict insertion order
# ---------------------------------------------------------------------------

def test_column_keys_are_order_independent(bfv_engine_ks):
    ks = bfv_engine_ks
    key = jax.random.PRNGKey(7)
    a = np.array([1, 2, 3], np.int64)
    b = np.array([9, 8, 7], np.int64)
    t_ab = Table.from_arrays(ks, "t", {"a": a, "b": b}, key)
    t_ba = Table.from_arrays(ks, "t", {"b": b, "a": a}, key)
    for c in ("a", "b"):
        assert (np.asarray(t_ab.columns[c].c0)
                == np.asarray(t_ba.columns[c].c0)).all()
        assert (np.asarray(t_ab.columns[c].c1)
                == np.asarray(t_ba.columns[c].c1)).all()
    # distinct columns still get distinct keys
    assert not (np.asarray(column_key(key, "a"))
                == np.asarray(column_key(key, "b"))).all()


def test_base_and_delta_ingest_agree_on_column_keys(bfv_engine_ks):
    # a delta run ingested under the same key produces the same
    # ciphertext rows a base ingest of those rows would — the compat
    # contract that makes compaction's ciphertext append well-defined
    ks = bfv_engine_ks
    key = jax.random.PRNGKey(11)
    rows = {"a": np.array([4, 6], np.int64), "b": np.array([1, 0], np.int64)}
    base = Table.from_arrays(ks, "d", rows, key)
    t = Table.empty(ks, "d", ["a", "b"], jax.random.PRNGKey(0))
    t.insert(ks, rows, key)
    for c in ("a", "b"):
        assert (np.asarray(base.columns[c].c0)
                == np.asarray(t.delta.columns[c].c0)).all()


# ---------------------------------------------------------------------------
# union reads: base ∪ delta scans, index probes, tombstones
# ---------------------------------------------------------------------------

def test_insert_then_scan_and_index_agree(scheme_ks, rng):
    ks = scheme_ks
    base = rng.choice(np.arange(2, 60, 2), size=12, replace=False)
    extra = np.array([5, 31, 47])
    t = Table.from_arrays(ks, "t", {"v": _vals(ks, base)},
                          jax.random.PRNGKey(3))
    idx = db.SortedIndex.build(ks, t, "v")
    t.insert(ks, {"v": _vals(ks, extra)}, jax.random.PRNGKey(4))
    allv = np.concatenate([base, extra])
    lo, hi = 10, 48
    want = sorted(np.nonzero((allv >= lo) & (allv <= hi))[0])
    r_scan = db.execute(ks, t, _range(ks, lo, hi, 20))
    r_idx = db.execute(ks, t, _range(ks, lo, hi, 22), indexes={"v": idx})
    assert sorted(r_scan.row_ids) == want
    assert sorted(r_idx.row_ids) == want
    # the union probe costs the base fan-out + one per-run search:
    # <= 2·ceil(log2 n_base) + 2·ceil(log2 n_delta) per lane pair
    n_b, n_d = next_pow2(len(base)), next_pow2(len(extra))
    per_probe = 2 * (max(1, (n_b - 1).bit_length())
                     + max(1, (n_d - 1).bit_length()))
    assert r_idx.stats.index_compares <= 2 * per_probe  # 2 lanes (lo, hi)


def test_duplicate_keys_split_across_base_and_delta(scheme_ks):
    ks = scheme_ks
    t = Table.from_arrays(ks, "t", {"v": _vals(ks, [4, 9, 12])},
                          jax.random.PRNGKey(5))
    idx = db.SortedIndex.build(ks, t, "v")
    t.insert(ks, {"v": _vals(ks, [9, 9])}, jax.random.PRNGKey(6))
    q = P.Eq("v", _enc(ks, _vals(ks, 9), 30),
             eps=EPS_BAND if _is_ckks(ks) else None)
    for indexes in ({}, {"v": idx}):
        r = db.execute(ks, t, q, indexes=indexes)
        assert sorted(r.row_ids) == [1, 3, 4]


def test_delete_all_then_query(scheme_ks):
    ks = scheme_ks
    t = Table.from_arrays(ks, "t", {"v": _vals(ks, [3, 8, 15])},
                          jax.random.PRNGKey(7))
    idx = db.SortedIndex.build(ks, t, "v")
    assert t.delete([0, 1, 2]) == 3
    assert t.delete([1]) == 0          # idempotent tombstones
    assert not t.alive.any() and t.is_mutated
    for indexes in ({}, {"v": idx}):
        r = db.execute(ks, t, _range(ks, 0, 100, 32), indexes=indexes)
        assert len(r.row_ids) == 0
        assert not r.mask.any()
    with pytest.raises(IndexError):
        t.delete([3])


def test_update_is_tombstone_plus_reinsert(scheme_ks):
    ks = scheme_ks
    t = Table.from_arrays(ks, "t", {"v": _vals(ks, [3, 8, 15])},
                          jax.random.PRNGKey(8))
    new_ids = t.update(ks, [1], {"v": _vals(ks, [50])},
                       jax.random.PRNGKey(9))
    assert new_ids.tolist() == [3]
    assert not t.alive[1] and t.alive[3]
    r = db.execute(ks, t, _range(ks, 40, 60, 34))
    assert sorted(r.row_ids) == [3]
    r2 = db.execute(ks, t, _range(ks, 5, 10, 36))
    assert len(r2.row_ids) == 0       # the old version is dead


@pytest.mark.parametrize("use_index", [False, True], ids=["scan", "indexed"])
def test_eps_band_eq_spans_base_and_delta(ckks_keys, use_index):
    # ε-band equality must not care WHERE a row lives: neighbors within
    # the band sit in base and in the delta run
    ks = ckks_keys
    base = np.array([4, 8, 16], np.int64)    # 8·GRID = 2.0 is the target
    t = Table.from_arrays(ks, "t", {"v": _vals(ks, base)},
                          jax.random.PRNGKey(10))
    indexes = {"v": db.SortedIndex.build(ks, t, "v")} if use_index else {}
    t.insert(ks, {"v": _vals(ks, [9, 30])}, jax.random.PRNGKey(11))
    # band ±0.3 around 2.0 captures 8 (=2.0) and 9 (=2.25), not 16 or 30
    q = P.Eq("v", _enc(ks, _vals(ks, 8), 40), eps=EPS_BAND)
    r = db.execute(ks, t, q, indexes=indexes)
    assert sorted(r.row_ids) == [1, 3]


# ---------------------------------------------------------------------------
# compaction: merge network, id stability, no rebuild
# ---------------------------------------------------------------------------

def test_compaction_preserves_answers_and_ids(scheme_ks, rng):
    ks = scheme_ks
    base = rng.choice(np.arange(2, 200, 2), size=30, replace=False)
    extra = np.array([5, 101, 3, 177])
    t = Table.from_arrays(ks, "t", {"v": _vals(ks, base)},
                          jax.random.PRNGKey(12))
    indexes = {"v": db.SortedIndex.build(ks, t, "v")}
    t.insert(ks, {"v": _vals(ks, extra)}, jax.random.PRNGKey(13))
    t.delete([2])
    allv = np.concatenate([base, extra])
    want = sorted(i for i in np.nonzero((allv >= 50) & (allv <= 150))[0]
                  if i != 2)
    before = db.execute(ks, t, _range(ks, 50, 150, 50), indexes=indexes)
    stats = db.compact(ks, t, indexes)
    after = db.execute(ks, t, _range(ks, 50, 150, 52), indexes=indexes)
    assert sorted(before.row_ids) == want
    assert sorted(after.row_ids) == want          # global ids are STABLE
    assert not t.has_delta and t.n_rows == len(allv)
    assert not t.alive[2]                         # tombstones survive
    assert _close(ks, t.decrypt_column(ks, "v"), _vals(ks, allv))
    assert stats.merge_rounds == 1 and stats.indexes_merged == 1
    # the merge is a merge, not a rebuild
    assert 0 < stats.merge_compares < stats.rebuild_compares
    L = next_pow2(max(len(base), len(extra)))
    assert stats.merge_compares <= L * (1 + max(1, L.bit_length() - 1))
    assert stats.rebuild_compares == bitonic_compare_count(len(allv))
    # compacting again is a no-op
    again = db.compact(ks, t, indexes)
    assert again.merge_compares == 0 and again.n_delta == 0


def test_compaction_is_pure_ciphertext_append(bfv_engine_ks):
    # no base row is re-encrypted: the folded base's leading rows are
    # byte-identical to the pre-compaction base ciphertexts
    ks = bfv_engine_ks
    t = Table.from_arrays(ks, "t", {"v": np.array([7, 1, 5], np.int64)},
                          jax.random.PRNGKey(14))
    base_c0 = np.asarray(t.columns["v"].c0)[:3].copy()
    t.insert(ks, {"v": np.array([2, 9], np.int64)}, jax.random.PRNGKey(15))
    delta_c0 = np.asarray(t.delta.columns["v"].c0)[:2].copy()
    db.compact(ks, t)
    folded = np.asarray(t.columns["v"].c0)
    assert (folded[:3] == base_c0).all()
    assert (folded[3:5] == delta_c0).all()


# ---------------------------------------------------------------------------
# shard invariance of the mutated view
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 3, 4])
def test_shard_invariance_of_mutated_view(scheme_ks, shards):
    ks = scheme_ks
    base = np.arange(2, 2 + 2 * 11, 2)
    extra = np.array([5, 17, 3])
    allv = np.concatenate([base, extra])
    spec = db.ShardSpec.create(shards, use_mesh=False)
    st = db.ShardedTable.from_arrays(ks, "s", {"v": _vals(ks, base)},
                                     jax.random.PRNGKey(16), spec=spec)
    indexes = {"v": db.ShardedIndex.build(ks, st, "v")}
    st.insert(ks, {"v": _vals(ks, extra)}, jax.random.PRNGKey(17))
    st.delete([1])
    want = sorted(i for i in np.nonzero((allv >= 4) & (allv <= 18))[0]
                  if i != 1)
    r = db.execute(ks, st, _range(ks, 4, 18, 60), indexes=indexes)
    assert sorted(r.row_ids) == want
    # the decrypted global view is byte-identical to a from-scratch
    # single table over the same rows, for EVERY shard count
    ref = _vals(ks, allv)
    assert _close(ks, st.decrypt_column(ks, "v"), ref)
    stats = db.compact(ks, st, indexes)
    assert not st.has_delta
    assert stats.shards == shards
    assert _close(ks, st.decrypt_column(ks, "v"), ref)
    r2 = db.execute(ks, st, _range(ks, 4, 18, 62), indexes=indexes)
    assert sorted(r2.row_ids) == want
    # inserts after compaction (non-contiguous shard ownership) still
    # route, read, and decrypt in global id order
    st.insert(ks, {"v": _vals(ks, [4])}, jax.random.PRNGKey(18))
    assert _close(ks, st.decrypt_column(ks, "v"),
                  _vals(ks, np.concatenate([allv, [4]])))


# ---------------------------------------------------------------------------
# server mutation queues + compaction under load
# ---------------------------------------------------------------------------

def test_query_server_fifo_mutations(scheme_ks):
    ks = scheme_ks
    base = np.array([10, 3, 7, 14, 1, 8], np.int64)
    t = Table.from_arrays(ks, "t", {"v": _vals(ks, base)},
                          jax.random.PRNGKey(19))
    idx = db.SortedIndex.build(ks, t, "v")
    srv = db.QueryServer(ks, t, indexes={"v": idx}, batch=2)
    q1 = srv.submit(_range(ks, 5, 12, 70))
    mi = srv.submit_insert({"v": _vals(ks, [6, 12])}, jax.random.PRNGKey(20))
    q2 = srv.submit(_range(ks, 5, 12, 72))
    md = srv.submit_delete([0])
    q3 = srv.submit(_range(ks, 5, 12, 74))
    res = srv.run()
    allv = np.concatenate([base, [6, 12]])
    w1 = sorted(np.nonzero((base >= 5) & (base <= 12))[0])
    w2 = sorted(np.nonzero((allv >= 5) & (allv <= 12))[0])
    w3 = [i for i in w2 if i != 0]
    assert sorted(res[q1].row_ids) == w1      # pre-insert snapshot
    assert sorted(res[q2].row_ids) == w2      # sees the insert
    assert sorted(res[q3].row_ids) == w3      # sees the delete too
    assert isinstance(res[mi], db.MutationResult)
    assert res[mi].row_ids.tolist() == [6, 7]
    assert res[md].deleted == 1


def test_sharded_server_compaction_under_load(scheme_ks):
    # the CI compaction-under-load scenario: queries keep answering
    # correctly while threshold-triggered compactions land between
    # batches (queries before the compaction run over base ∪ delta,
    # queries after run over the folded base — same answers)
    ks = scheme_ks
    base = np.arange(1, 17)
    spec = db.ShardSpec.create(4, use_mesh=False)
    st = db.ShardedTable.from_arrays(ks, "s", {"v": _vals(ks, base)},
                                     jax.random.PRNGKey(21), spec=spec)
    indexes = {"v": db.ShardedIndex.build(ks, st, "v")}
    srv = db.ShardedQueryServer(ks, st, indexes=indexes, batch=2,
                                compact_threshold=3)
    live = list(base)
    truth = {}
    rng = np.random.default_rng(23)
    next_val = 100
    for step in range(3):
        lo, hi = sorted(rng.choice(np.arange(1, 120), 2, replace=False))
        qid = srv.submit(_range(ks, int(lo), int(hi), 80 + 4 * step))
        snapshot = np.array(live)
        truth[qid] = int(((snapshot >= lo) & (snapshot <= hi)).sum())
        ins = [next_val, next_val + 1, next_val + 2]
        next_val += 3
        srv.submit_insert({"v": _vals(ks, ins)},
                          jax.random.PRNGKey(30 + step))
        live.extend(ins)
        qid2 = srv.submit(_range(ks, int(lo), int(hi), 82 + 4 * step))
        snapshot = np.array(live)
        truth[qid2] = int(((snapshot >= lo) & (snapshot <= hi)).sum())
    res = srv.run()
    for qid, want in truth.items():
        assert len(res[qid].row_ids) == want, (qid, want)
    # the threshold actually fired, and the folds went through the
    # merge network (compares attributed), never a rebuild pass
    assert len(srv.compaction_log) >= 1
    assert all(c.merge_rounds >= 1 for c in srv.compaction_log)
    assert not st.has_delta


# ---------------------------------------------------------------------------
# joins guard the write path
# ---------------------------------------------------------------------------

def test_join_refuses_pending_delta_but_allows_tombstones(bfv_engine_ks):
    ks = bfv_engine_ks
    left = Table.from_arrays(ks, "l", {"k": np.array([1, 2, 3], np.int64)},
                             jax.random.PRNGKey(24))
    right = Table.from_arrays(ks, "r", {"k": np.array([2, 3, 4], np.int64)},
                              jax.random.PRNGKey(25))
    join = P.Join(left=None, right=None, on=("k", "k"))
    left.insert(ks, {"k": np.array([5], np.int64)}, jax.random.PRNGKey(26))
    with pytest.raises(ValueError, match="compact"):
        db.execute_join(ks, left, right, join)
    db.compact(ks, left)
    right.delete([2])          # tombstones are fine: the row just drops
    res = db.execute_join(ks, left, right, join)
    assert res.pairs.tolist() == [[1, 0], [2, 1]]
