"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figures map 1:1 to the paper:
  fig1  BFV micro-benchmarks (KeyGen/Enc{Basic,FAE}/Cmp{Basic,FAE})
  fig2  CKKS micro-benchmarks
  fig3  real-world datasets (Bitcoin / Covid19 / hg38)
  fig4  protocol comparison (HADES vs HOPE vs POPE)
  table1  feature matrix (+ mechanical interaction checks)
plus three framework benches: kernels (Pallas fused compare), roofline
(the dry-run derived table), and db_engine (the repro.db query engine:
index build amortization, indexed vs. linear scans, batched serving).
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import common


def main() -> None:
    common.header()
    from benchmarks import (db_engine, fig1_bfv, fig2_ckks, fig3_datasets,
                            fig4_baselines, kernels_bench, roofline_report,
                            table1_features)
    suites = [
        ("fig1", fig1_bfv.run),
        ("fig2", fig2_ckks.run),
        ("fig3", fig3_datasets.run),
        ("fig4", fig4_baselines.run),
        ("table1", table1_features.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline_report.run),
        ("db_engine", db_engine.run),
    ]
    failed = []
    for name, fn in suites:
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
            common.emit(f"{name}.FAILED", -1.0, "see stderr")
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
