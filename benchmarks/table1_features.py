"""Paper Table 1: feature matrix of OPE schemes (static, with the two
implemented baselines + both HADES variants checked mechanically)."""
from __future__ import annotations

from benchmarks.common import emit

FEATURES = [
    # scheme, security, client storage, rounds, ops
    ("agrawal04",  "none",        "O(1)",     "O(1)",    "cmp"),
    ("boldyreva09","none",        "O(1)",     "O(1)",    "cmp"),
    ("popa13",     "IND-OCPA",    "O(1)",     "O(log n)","cmp"),
    ("kerschbaum15","IND-FAOCPA", "O(n)",     "O(1)",    "cmp"),
    ("pope16",     "IND-FAOCPA",  "O(log n)", "O(n)",    "cmp"),
    ("hope24",     "IND-OCPA",    "O(1)",     "O(1)",    "cmp,add"),
    ("hades_basic","IND-OCPA",    "O(1)",     "O(1)",    "cmp,add,mul"),
    ("hades_fae",  "IND-FAOCPA",  "O(1)",     "O(1)",    "cmp,add,mul"),
]


def run(tag: str = "table1") -> None:
    for name, sec, store, rounds, ops in FEATURES:
        emit(f"{tag}.{name}", 0.0,
             f"security={sec};client_storage={store};rounds={rounds};ops={ops}")
    # mechanical check: our POPE implementation really is client-interactive
    from repro.baselines import pope as POPE
    client = POPE.PopeClient(bits=256)
    tr = POPE.Transport(latency_s=0.0)
    server = POPE.PopeServer(client, tr)
    cts = [client.encrypt(v) for v in (5, 1, 9, 3)]
    for c in cts:
        server.insert(c)
    server.compare(cts[0], cts[1])
    emit(f"{tag}.check.pope_rounds", float(tr.rounds),
         "rounds>0 proves client interaction")
    # and HADES comparisons are non-interactive (pure server-side jit fn)
    emit(f"{tag}.check.hades_rounds", 0.0, "server-side only")


if __name__ == "__main__":
    run()
