"""Serving-loop load generator: mixed multi-tenant traffic under SLOs.

Drives a deterministic stream of point / range / join / mutation
traffic from two tenants (each with its OWN KeySet and an ACLed table)
through `repro.db.serve_loop.ServeLoop`, and records what the paper's
"database serving untrusted-cloud traffic" story needs measured:

  * `db.serve.loop.point.isolated` — indexed point-lookup p50/p99 with
    nothing else on the loop (the baseline SLO);
  * `db.serve.loop.point.mixed`   — the same lookups while scans,
    joins and writes stream in.  ASSERTED: p99 ≤ 2x the isolated p99 —
    the whole reason the two-class scheduler exists;
  * `db.serve.loop.bulk.mixed`    — scan/join latency under the mix;
  * `db.serve.loop.steady`        — steady-state QPS, shed rate
    (ASSERTED 0 under this light load), and the jit retrace delta
    across the steady phase (ASSERTED 0: after the warmup wave has
    visited every pow2 bucket and delta-run shape, the jit cache must
    be hot — pow2 bucketing's contract);
  * `db.serve.loop.admission`     — overload demo: queue caps reject,
    past deadlines shed, both explicitly (ASSERTED).

Traffic is seeded and phase-structured (isolated → warmup → steady),
so runs are reproducible; every pass lands in the BENCH json via
`benchmarks/common.write_json` (use `--json BENCH_db.json --append` to
merge into the engine trajectory).  `--trace` additionally writes the
run's Chrome trace for the CI artifact.

  PYTHONPATH=src python -m benchmarks.serve_loop --rows 1024 --rounds 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit, write_json
from repro import db, obs
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.db import plan as P
from repro.db.serve_loop import OK, REJECTED, SHED, AdmissionPolicy, ServeLoop

INSERT_CHUNK = 8          # delta grows 8,16,24,32 -> compact (pow2 pads)
COMPACT_AT = 32


def _keys(profile: str, mode: str, seed: int):
    params = make_params(profile, mode=mode)
    kw = {"paper_ecek_weight": 0} if mode == "paper" else {}
    return keygen(params, jax.random.PRNGKey(seed), **kw)


def _pcts(lats):
    lats = np.asarray(sorted(lats))
    return (float(np.percentile(lats, 50)) * 1e6,
            float(np.percentile(lats, 99)) * 1e6)


def _mk_tenant(profile, mode, name, seed, n_rows, n_padded,
               with_right=False):
    """One tenant's world: own KeySet, own indexed table (+ optional
    small right-hand table for joins), own ciphertext pool."""
    ks = _keys(profile, mode, seed)
    rng = np.random.default_rng(seed)
    lim = ks.params.max_operand // 2
    vals = rng.integers(0, lim, n_rows).astype(np.int64)
    table = db.Table.from_arrays(ks, f"{name}_t", {"v": vals},
                                 jax.random.PRNGKey(seed + 1),
                                 n_padded=n_padded)
    indexes = {"v": db.SortedIndex.build(ks, table, "v")}
    right = None
    if with_right:
        right = db.Table.from_arrays(
            ks, f"{name}_r", {"v": vals[:64].copy()},
            jax.random.PRNGKey(seed + 2))
    # deterministic encrypted probe pool (reused across rounds so the
    # load generator's own encryption cost stays off the serving path)
    pool = [E.encrypt(ks, np.int64(int(v)), jax.random.PRNGKey(seed + 10 + i))
            for i, v in enumerate(rng.choice(vals, 16, replace=True))]
    bounds = []
    for i in range(8):
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        bounds.append((
            E.encrypt(ks, np.int64(int(lo)),
                      jax.random.PRNGKey(seed + 100 + i)),
            E.encrypt(ks, np.int64(int(hi)),
                      jax.random.PRNGKey(seed + 200 + i))))
    return dict(ks=ks, rng=rng, vals=vals, table=table, indexes=indexes,
                right=right, pool=pool, bounds=bounds, name=name)


def _point_wave(loop, tenant, n, deadline_s=None):
    """Submit n indexed point lookups; returns their tickets."""
    now = time.monotonic()
    dl = None if deadline_s is None else now + deadline_s
    return [loop.submit(tenant["name"], tenant["name"] + "_t",
                        db.Eq("v", tenant["pool"][i % len(tenant["pool"])]),
                        deadline=dl)
            for i in range(n)]


def _bulk_wave(loop, tenant, n):
    """Submit n full-scan range queries (forced bulk class)."""
    return [loop.submit(tenant["name"], tenant["name"] + "_t",
                        db.Range("v", *tenant["bounds"][i %
                                                        len(tenant["bounds"])]),
                        klass="bulk")
            for i in range(n)]


def run(profile: str = "test-bfv", mode: str = "paper", rows: int = 1024,
        rounds: int = 4, lane_budget=None,
        tag: str = "db.serve.loop") -> dict:
    """Drive the phased load and emit + assert the loop's BENCH passes."""
    # headroom below the pow2 pad so the warmup compaction never grows
    # the base block (stable scan-width shapes == stable jit cache)
    n_rows = rows - max(rows // 8, 4 * COMPACT_AT)
    assert n_rows > 0, f"--rows {rows} too small for mutation headroom"
    alice = _mk_tenant(profile, mode, "alice", 11, n_rows, rows)
    bob = _mk_tenant(profile, mode, "bob", 23, n_rows, rows,
                     with_right=True)

    loop = ServeLoop(batch=8)
    for t in (alice, bob):
        loop.register(t["name"] + "_t", db.QueryServer(
            t["ks"], t["table"], indexes=t["indexes"], batch=8,
            compact_threshold=COMPACT_AT, lane_budget=lane_budget),
            tenants=(t["name"],))

    # alice's hot WRITE table (same tenant keys, own registration):
    # mutation traffic and its union reads (base ∪ fresh delta run,
    # which pay an on-the-fly delta-index build per run) live here, so
    # the SLO tables' point work is identical in the isolated and
    # mixed phases and the p99 ratio measures SCHEDULING, not the
    # write path's build cost.  Registered LAST so its point batch
    # drafts after the SLO tables' in every pump.
    w_rows = 2 * COMPACT_AT
    wvals = alice["rng"].integers(
        0, alice["ks"].params.max_operand // 2, w_rows).astype(np.int64)
    # pad far above every compaction high-water mark (warmup cycle +
    # the per-phase flush folds) so base growth never re-pads mid-run
    wtable = db.Table.from_arrays(alice["ks"], "alice_w", {"v": wvals},
                                  jax.random.PRNGKey(31),
                                  n_padded=8 * COMPACT_AT)
    wserver = db.QueryServer(
        alice["ks"], wtable,
        indexes={"v": db.SortedIndex.build(alice["ks"], wtable, "v")},
        batch=8, compact_threshold=COMPACT_AT, lane_budget=lane_budget)
    loop.register("alice_w", wserver, tenants=("alice",))
    wprobe = E.encrypt(alice["ks"], np.int64(int(wvals[0])),
                       jax.random.PRNGKey(32))

    def insert_chunk(i):
        lim = alice["ks"].params.max_operand // 2
        data = {"v": alice["rng"].integers(0, lim, INSERT_CHUNK)
                .astype(np.int64)}
        loop.submit_insert("alice", "alice_w", data,
                           jax.random.PRNGKey(7000 + i))

    def union_probe():
        # indexed point read that also walks the pending delta run(s)
        return loop.submit("alice", "alice_w", db.Eq("v", wprobe))

    def join_one(t):
        loop.submit_join(t["name"], t["name"] + "_t",
                         db.Join(None, None, on="v"), t["right"],
                         strategy="nested")

    def drain():
        res = loop.run_until_idle()
        bad = [r for r in res.values()
               if not r.done or r.status not in (OK, REJECTED, SHED)]
        assert not bad, f"unexpected terminal states: {bad[:3]}"
        return res

    def lat(res, tickets):
        return [res[t].latency_s for t in tickets if res[t].status == OK]

    # ---- phase 1: warmup — visit every pow2 bucket + delta shape --------
    # point buckets 8/4/2/1, bulk buckets 4/2/1, the join grid, and one
    # full insert->probe->compact cycle on the write table (delta pads
    # 8/16/32 + the merge network), so the measured phases re-use only
    # already-compiled shapes
    for n in (8, 4, 2, 1):
        _point_wave(loop, alice, n)
        _point_wave(loop, bob, n)
        drain()
    for n in (4, 2, 1):
        _bulk_wave(loop, alice, n)
        _bulk_wave(loop, bob, n)
        drain()
    join_one(bob)
    drain()
    for i in range(COMPACT_AT // INSERT_CHUNK):     # one full delta cycle
        insert_chunk(i)
        union_probe()                               # probe base ∪ delta
        drain()
    # compaction stays a warmup-only event: measured rounds must not
    # cross a merge (its cost would land on that round's queue waits)
    wserver.compact_threshold = 1 << 30
    max_chunks = COMPACT_AT // INSERT_CHUNK         # delta pad stays warm

    def flush_writes(i):
        # fold the accumulated delta back into base OUTSIDE any timed
        # window, so each measured phase starts from the same state
        wserver.compact_threshold = 1
        insert_chunk(i)
        drain()
        wserver.compact_threshold = 1 << 30

    # ---- phase 2: isolated baseline — points + writes, NO bulk ----------
    # the write applies (admission-order barriers) are part of BOTH
    # phases by design, so the mixed/isolated ratio isolates exactly
    # what the two-class scheduler controls: scan/join interference
    iso_lat = []
    chunks = 0
    for r in range(rounds):
        if chunks < max_chunks:         # keep the delta pad in-warmup
            insert_chunk(50 + r)
            chunks += 1
        tks = _point_wave(loop, alice, 8) + _point_wave(loop, bob, 8)
        res = drain()
        iso_lat += lat(res, tks)
    iso_p50, iso_p99 = _pcts(iso_lat)
    emit(f"{tag}.point.isolated", iso_p50,
         f"p99_us={iso_p99:.0f};n={len(iso_lat)}")
    flush_writes(90)

    # ---- phase 3: steady-state mixed load -------------------------------
    fields0 = obs.bench_fields() if obs.is_enabled() else None
    sub0, served0, shed0 = (loop.stats.submitted, loop.stats.served,
                            loop.stats.shed)
    mixed_point, mixed_bulk, union_lat = [], [], []
    chunks = 0
    t0 = time.perf_counter()
    for r in range(rounds):
        ptks = _point_wave(loop, alice, 8, deadline_s=600.0)
        ptks += _point_wave(loop, bob, 8, deadline_s=600.0)
        btks = _bulk_wave(loop, alice, 4) + _bulk_wave(loop, bob, 4)
        join_one(bob)
        utks = []
        if chunks < max_chunks:         # keep the delta pad in-warmup
            insert_chunk(100 + r)
            utks.append(union_probe())
            chunks += 1
        res = drain()
        mixed_point += lat(res, ptks)
        mixed_bulk += lat(res, btks)
        union_lat += lat(res, utks)
    steady_wall = time.perf_counter() - t0
    if chunks < rounds:
        log_skipped = rounds - chunks
        print(f"# note: write traffic capped at {chunks} chunks "
              f"({log_skipped} rounds ran insert-free — delta pad "
              f"would leave the warmed pow2 set)")
    served = loop.stats.served - served0
    shed_rate = (loop.stats.shed - shed0) / max(
        1, loop.stats.submitted - sub0)
    retrace_delta = (obs.bench_fields()["jit_retraces"]
                     - fields0["jit_retraces"]) if fields0 else 0

    mix_p50, mix_p99 = _pcts(mixed_point)
    blk_p50, blk_p99 = _pcts(mixed_bulk)
    ratio = mix_p99 / iso_p99
    # the two-class scheduler's contract, asserted where it is produced:
    # point p99 under mixed load stays within 2x its isolated p99
    assert np.isfinite(mix_p99) and np.isfinite(blk_p99)
    assert ratio <= 2.0, (
        f"point p99 degraded {ratio:.2f}x under mixed load "
        f"(isolated {iso_p99:.0f}us, mixed {mix_p99:.0f}us)")
    assert shed_rate == 0.0, f"shed under light load: {shed_rate}"
    assert retrace_delta == 0, (
        f"jit retraced {retrace_delta}x in steady state — a launch "
        "shape escaped the pow2 buckets")
    emit(f"{tag}.point.mixed", mix_p50,
         f"p99_us={mix_p99:.0f};p99_vs_isolated={ratio:.2f}x;"
         f"n={len(mixed_point)}")
    emit(f"{tag}.bulk.mixed", blk_p50,
         f"p99_us={blk_p99:.0f};n={len(mixed_bulk)}")
    if union_lat:
        # union reads pay the fresh delta-run index build — reported
        # on their own so the SLO passes stay a pure scheduling signal
        u50, u99 = _pcts(union_lat)
        emit(f"{tag}.write.union_read", u50,
             f"p99_us={u99:.0f};n={len(union_lat)}")
    emit(f"{tag}.steady", 1e6 * steady_wall / max(1, served),
         f"qps={served / steady_wall:.1f};served={served};"
         f"shed_rate={shed_rate};jit_retraces_delta={retrace_delta};"
         f"deadline_miss={loop.stats.deadline_miss}")

    # ---- phase 4: overload — admission control does its job -------------
    tight = ServeLoop(policy=AdmissionPolicy(tenant_queue_cap=4),
                      batch=8)
    tight.register("alice_t", db.QueryServer(
        alice["ks"], alice["table"], indexes=alice["indexes"], batch=8),
        tenants=("alice",))
    t0 = time.perf_counter()
    # already-expired request first (admission doesn't look at
    # deadlines — the draft does), then a burst past the queue cap
    late = tight.submit("alice", "alice_t", db.Eq("v", alice["pool"][0]),
                        deadline=time.monotonic() - 1.0)
    burst = [tight.submit("alice", "alice_t",
                          db.Eq("v", alice["pool"][i % len(alice["pool"])]))
             for i in range(8)]
    res = tight.run_until_idle()
    wall = time.perf_counter() - t0
    rejected = sum(res[t].status == REJECTED for t in burst)
    assert rejected == 5, f"cap 4 minus late's slot admits 3: {rejected}"
    assert res[late].status == SHED, res[late].status
    assert all(res[t].status == OK for t in burst
               if res[t].status != REJECTED)
    emit(f"{tag}.admission", wall * 1e6,
         f"burst=9;cap=4;rejected={rejected};shed=1")

    return {
        "rows": int(n_rows), "rounds": rounds,
        "point_p50_us": round(iso_p50, 1),
        "point_p99_us": round(iso_p99, 1),
        "mixed_point_p99_us": round(mix_p99, 1),
        "p99_vs_isolated": round(ratio, 3),
        "bulk_p99_us": round(blk_p99, 1),
        "steady_qps": round(served / steady_wall, 2),
        "shed_rate": shed_rate,
        "jit_retraces_delta": int(retrace_delta),
        "write_chunks": chunks,
        "union_read_p99_us": round(_pcts(union_lat)[1], 1)
        if union_lat else None,
        "admission_rejected": int(rejected),
    }


def main() -> None:
    """CLI: run the phased load generator and write the BENCH json."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="test-bfv")
    ap.add_argument("--mode", default="paper")
    ap.add_argument("--rows", type=int, default=1024)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--lane-budget", type=int, default=0,
                    help="per-launch eval-lane cap (0 = policy default)")
    ap.add_argument("--json", default="BENCH_serve_loop.json",
                    help="machine-readable output path ('' = skip)")
    ap.add_argument("--append", action="store_true",
                    help="merge passes into an existing json trajectory")
    ap.add_argument("--trace", default="",
                    help="also write the run's Chrome trace here")
    args = ap.parse_args()
    obs.enable()               # launch accounting + serve.* counters on
    if args.lane_budget:
        from repro.kernels import ops as _KO
        _KO.set_lane_budget(args.lane_budget)
    summary = run(profile=args.profile, mode=args.mode, rows=args.rows,
                  rounds=args.rounds,
                  lane_budget=args.lane_budget or None)
    print(f"serve_loop: {summary}")
    if args.trace:
        obs.write_chrome_trace(args.trace)
        print(f"chrome trace -> {args.trace}")
    if args.json:
        from repro.kernels import ops as _KO
        write_json(args.json,
                   meta={"benchmark": "serve_loop",
                         "profile": args.profile, "mode": args.mode,
                         "rows_arg": args.rows,
                         "lane_budget": _KO.resolve_lane_budget(
                             args.lane_budget or None),
                         "backend": jax.default_backend(),
                         "devices": jax.device_count(),
                         **obs.bench_fields()},
                   extra={"serve_loop": summary},
                   append=args.append)


if __name__ == "__main__":
    main()
