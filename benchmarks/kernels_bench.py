"""Kernel-level benchmarks: Pallas fused compare vs reference pipeline.

On CPU both run interpreted/XLA so wall-clock is not the TPU story — the
`derived` column carries the structural win instead: HBM bytes moved per
comparison (the fused kernel emits K residues instead of a full [2,K,n]
eval polynomial), which is the §Perf memory-term claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.kernels import ops

N = 32


def run(tag: str = "kernels", profile: str = "test-bfv") -> None:
    for mode in ("paper", "gadget"):
        params = make_params(profile, mode=mode)
        ks = keygen(params, jax.random.PRNGKey(1),
                    paper_ecek_weight=0 if mode == "paper" else None)
        m = jnp.arange(N, dtype=jnp.int64)
        ct_a = E.encrypt(ks, m, jax.random.PRNGKey(2))
        ct_b = E.encrypt(ks, jnp.roll(m, 1), jax.random.PRNGKey(3))
        ref = jax.jit(lambda a, b: C.compare(ks, a, b))
        emit(f"{tag}.{mode}.ref_compare", timeit(ref, ct_a, ct_b, per=N), "")
        emit(f"{tag}.{mode}.pallas_compare",
             timeit(lambda a, b: ops.compare(ks, a, b), ct_a, ct_b, per=N),
             "interpret-mode (CPU)")
        n, K = params.n, params.num_towers
        naive_out = 2 * K * n * 8
        fused_out = K * 8
        emit(f"{tag}.{mode}.out_bytes_ratio", naive_out / fused_out,
             f"naive={naive_out}B fused={fused_out}B per compare")


if __name__ == "__main__":
    run()
