"""Paper Figure 4: ciphertext comparison time across protocols.

HADES Basic / HADES FAE vs HOPE [31] (Paillier, stateless) vs POPE [27]
(client-interactive; its cost IS the round trips — paper reports 385 ms
vs HOPE 1.7 ms vs HADES 6.5 ms on a LAN-ish link).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.baselines import hope as HOPE
from repro.baselines import pope as POPE
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params

N = 64


def run(tag: str = "fig4", profile: str = "bench-bfv",
        pope_latency_s: float = 0.004) -> None:
    # --- HADES ---
    params = make_params(profile, mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(1))
    vals = np.random.default_rng(3).integers(0, 10**6, N) % params.t
    m = jnp.asarray(vals, jnp.int64)
    enc = jax.jit(lambda mm, kk: E.encrypt(ks, mm, kk))
    ct_a = enc(m, jax.random.PRNGKey(2))
    ct_b = enc(jnp.roll(m, 1), jax.random.PRNGKey(3))
    cmp_b = jax.jit(lambda a, b: C.compare(ks, a, b))
    cmp_f = jax.jit(lambda a, b: C.compare_fae(ks, a, b))
    emit(f"{tag}.hades_basic", timeit(cmp_b, ct_a, ct_b, per=N),
         "paper: 6.5ms/op on CPU OpenFHE")
    emit(f"{tag}.hades_fae", timeit(cmp_f, ct_a, ct_b, per=N),
         "paper: 6.1ms/op")

    # --- HOPE ---
    ctx = HOPE.keygen(bits=1024)
    cts = [HOPE.encrypt(ctx, int(v)) for v in vals[:16]]
    t0 = time.perf_counter()
    for i in range(len(cts) - 1):
        HOPE.compare(ctx, cts[i], cts[i + 1])
    hope_us = (time.perf_counter() - t0) / (len(cts) - 1) * 1e6
    emit(f"{tag}.hope", hope_us, "paper: 1.7ms/op (Paillier-1024)")

    # --- POPE ---
    client = POPE.PopeClient(bits=512)
    transport = POPE.Transport(latency_s=pope_latency_s)
    server = POPE.PopeServer(client, transport)
    pcts = [client.encrypt(int(v)) for v in vals[:16]]
    for ct in pcts:
        server.insert(ct)
    t0 = time.perf_counter()
    n_cmp = 8
    for i in range(n_cmp):
        server.compare(pcts[i], pcts[i + 1])
    pope_us = (time.perf_counter() - t0) / n_cmp * 1e6
    emit(f"{tag}.pope", pope_us,
         f"paper: 385ms/op; rounds={transport.rounds};"
         f"latency={pope_latency_s*1e3:.0f}ms/rt")


if __name__ == "__main__":
    run()
