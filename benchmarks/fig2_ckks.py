"""Paper Figure 2: HADES micro-benchmarks on the CKKS (float) profile.

Paper claim validated: CKKS ops cost ~2-3x their BFV counterparts (bigger
ring / float encode), while supporting floating-point operands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params

N_VALUES = 100


def run(profile: str = "test-ckks", mode: str = "gadget",
        tag: str = "fig2.ckks") -> None:
    params = make_params(profile, mode=mode)
    key = jax.random.PRNGKey(0)
    vals = np.random.default_rng(8).uniform(0, 1e6, N_VALUES)
    m = jnp.asarray(vals, jnp.float64)

    ks = keygen(params, jax.random.PRNGKey(1))
    emit(f"{tag}.keygen",
         timeit(lambda: keygen(params, jax.random.PRNGKey(1)).pk0, iters=2),
         f"profile={profile};n={params.n}")
    enc_b = jax.jit(lambda mm, kk: E.encrypt(ks, mm, kk))
    enc_f = jax.jit(lambda mm, kk: E.encrypt_fae(ks, mm, kk))
    emit(f"{tag}.enc_basic", timeit(enc_b, m, key, per=N_VALUES), "float64")
    emit(f"{tag}.enc_fae", timeit(enc_f, m, key, per=N_VALUES), "")

    ct_a = enc_b(m, jax.random.PRNGKey(2))
    ct_b = enc_b(jnp.roll(m, 1), jax.random.PRNGKey(3))
    cmp_b = jax.jit(lambda a, b: C.compare(ks, a, b))
    cmp_f = jax.jit(lambda a, b: C.compare_fae(ks, a, b))
    emit(f"{tag}.cmp_basic", timeit(cmp_b, ct_a, ct_b, per=N_VALUES), "")
    emit(f"{tag}.cmp_fae", timeit(cmp_f, ct_a, ct_b, per=N_VALUES), "")


if __name__ == "__main__":
    run()
