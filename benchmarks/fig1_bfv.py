"""Paper Figure 1: HADES Basic vs FA-Extension micro-benchmarks, BFV.

KeyGen / EncBasic / EncFAE / CmpBasic / CmpFAE over 100 uniform values in
[0, 1e6) (preprocessed mod t=65537, §6.2.1).  The paper's qualitative
claims validated here (EXPERIMENTS.md §Paper-claims):
  * FAE encryption costs ~2-3x Basic (perturbation + extra noise path)
  * comparison is cheaper than encryption
  * FAE comparison ~= Basic comparison
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params

N_VALUES = 100


def run(profile: str = "bench-bfv", mode: str = "gadget",
        tag: str = "fig1.bfv") -> None:
    params = make_params(profile, mode=mode)
    key = jax.random.PRNGKey(0)
    vals = np.random.default_rng(7).integers(0, 10**6, N_VALUES) % params.t
    m = jnp.asarray(vals, jnp.int64)

    kg = lambda: keygen(params, jax.random.PRNGKey(1))
    emit(f"{tag}.keygen", timeit(lambda: kg().pk0, iters=3),
         f"profile={profile};mode={mode}")

    ks = keygen(params, jax.random.PRNGKey(1))
    enc_b = jax.jit(lambda mm, kk: E.encrypt(ks, mm, kk))
    enc_f = jax.jit(lambda mm, kk: E.encrypt_fae(ks, mm, kk))
    emit(f"{tag}.enc_basic", timeit(enc_b, m, key, per=N_VALUES),
         f"n={params.n};towers={params.num_towers}")
    emit(f"{tag}.enc_fae", timeit(enc_f, m, key, per=N_VALUES), "")

    ct_a = enc_b(m, jax.random.PRNGKey(2))
    ct_b = enc_b(jnp.roll(m, 1), jax.random.PRNGKey(3))
    ctf_a = enc_f(m, jax.random.PRNGKey(4))
    ctf_b = enc_f(jnp.roll(m, 1), jax.random.PRNGKey(5))
    cmp_b = jax.jit(lambda a, b: C.compare(ks, a, b))
    cmp_f = jax.jit(lambda a, b: C.compare_fae(ks, a, b))
    emit(f"{tag}.cmp_basic", timeit(cmp_b, ct_a, ct_b, per=N_VALUES), "")
    emit(f"{tag}.cmp_fae", timeit(cmp_f, ctf_a, ctf_b, per=N_VALUES), "")

    # paper-faithful CEK mode (single-poly cek, 1 NTT-mul per compare) —
    # this is the variant the paper's "comparison cheaper than encryption"
    # claim is about; the gadget mode above pays K*D muls for the F1 fix.
    if mode != "paper":
        pparams = make_params(profile, mode="paper")
        pks = keygen(pparams, jax.random.PRNGKey(1), paper_ecek_weight=0)
        pct_a = E.encrypt(pks, m, jax.random.PRNGKey(2))
        pct_b = E.encrypt(pks, jnp.roll(m, 1), jax.random.PRNGKey(3))
        cmp_p = jax.jit(lambda a, b: C.compare(pks, a, b))
        emit(f"{tag}.cmp_paper_mode",
             timeit(cmp_p, pct_a, pct_b, per=N_VALUES),
             "paper-faithful single-poly CEK")


if __name__ == "__main__":
    run()
