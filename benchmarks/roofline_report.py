"""Assemble the §Roofline table from artifacts/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def run(tag: str = "roofline", art_dir: str = "artifacts/dryrun") -> None:
    files = sorted(glob.glob(os.path.join(art_dir, "*.json")))
    if not files:
        emit(f"{tag}.missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        cell = f"{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec["status"] != "ok":
            emit(f"{tag}.{cell}", 0.0, f"status={rec['status']}")
            continue
        r = rec["roofline"]
        emit(f"{tag}.{cell}", r["step_time_s"] * 1e6,
             f"dom={r['dominant']};frac={r['roofline_fraction']};"
             f"useful={r['useful_ratio']};mem_gib="
             f"{rec['memory']['peak_per_device_gib']}")


if __name__ == "__main__":
    run()
