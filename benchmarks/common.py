"""Shared benchmark harness: timing, CSV emission, profile selection."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3,
           per: int = 1) -> float:
    """Median wall time per logical operation, in microseconds.

    `per` = number of logical ops one call performs (batched compares).
    Blocks on device results so jit dispatch isn't under-counted.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args)) if _is_jax(fn(*args)) else fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if _is_jax(out):
            jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    med = sorted(times)[len(times) // 2]
    return med / per * 1e6


def _is_jax(x) -> bool:
    try:
        jax.tree.leaves(x)
        return any(hasattr(l, "block_until_ready")
                   for l in jax.tree.leaves(x))
    except Exception:
        return False


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def header() -> None:
    print("name,us_per_call,derived")
