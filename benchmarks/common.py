"""Shared benchmark harness: timing, CSV + JSON emission, profiles."""
from __future__ import annotations

import datetime
import json
import subprocess
import time
from typing import Callable, Dict, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []

# Bump when the JSON document layout changes shape (pass fields,
# meta stamps) so cross-PR diff tooling can gate on it.
BENCH_SCHEMA_VERSION = 2


def _git_sha() -> str:
    """The repo's current commit sha ("unknown" outside a checkout);
    host-side subprocess, never on any jit path."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def run_stamp() -> Dict[str, object]:
    """The provenance stamp every BENCH document's meta carries: git
    sha, schema version, ISO-8601 UTC timestamp (`datetime`, host-side
    only — never inside a jit trace)."""
    now = datetime.datetime.now(datetime.timezone.utc)
    return {"git_sha": _git_sha(),
            "schema_version": BENCH_SCHEMA_VERSION,
            "timestamp": now.isoformat(timespec="seconds")}


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3,
           per: int = 1) -> float:
    """Median wall time per logical operation, in microseconds.

    `per` = number of logical ops one call performs (batched compares).
    Blocks on device results so jit dispatch isn't under-counted.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args)) if _is_jax(fn(*args)) else fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        if _is_jax(out):
            jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    med = sorted(times)[len(times) // 2]
    return med / per * 1e6


def _is_jax(x) -> bool:
    try:
        jax.tree.leaves(x)
        return any(hasattr(l, "block_until_ready")
                   for l in jax.tree.leaves(x))
    except Exception:
        return False


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def header() -> None:
    print("name,us_per_call,derived")


def _parse_derived(derived: str) -> Dict[str, object]:
    """'k=v;k2=v2' -> typed dict (ints/floats/bools where they parse)."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        for cast in (int, float):
            try:
                out[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            out[k] = {"True": True, "False": False}.get(v, v)
    return out


def write_json(path: str, *, meta: Dict[str, object] | None = None,
               extra: Dict[str, object] | None = None,
               append: bool = False) -> dict:
    """Dump every emitted row (plus free-form `extra` sections) as one
    machine-readable JSON document — the cross-PR perf trajectory file
    (BENCH_db.json etc.).  Re-parses each row's derived string into a
    typed dict so downstream tooling never scrapes the CSV.

    `append=True` merges into an existing document instead of replacing
    it: passes with the same name are overwritten in place, new passes
    append at the end, and `meta` / `extra` keys update over what is
    already there — so a partial re-run (e.g. just the write-path
    passes) keeps the rest of the trajectory machine-comparable."""
    passes = [{"name": n, "us_per_call": round(us, 2),
               **_parse_derived(d)} for n, us, d in ROWS]
    doc = {"meta": {**run_stamp(), **(meta or {})}, "passes": passes}
    if extra:
        doc.update(extra)
    if append:
        try:
            with open(path) as f:
                old = json.load(f)
        except (OSError, ValueError):
            old = None
        if old is not None:
            merged = {p["name"]: p for p in old.get("passes", [])}
            merged.update({p["name"]: p for p in passes})
            old["passes"] = list(merged.values())
            old["meta"] = {**old.get("meta", {}), **doc["meta"]}
            for k, v in (extra or {}).items():
                old[k] = v
            doc = old
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    print(f"wrote {path} ({len(doc['passes'])} passes)")
    return doc
