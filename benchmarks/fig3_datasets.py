"""Paper Figure 3: per-operation times across the three real-world
datasets (Bitcoin 1,085 / Covid19 340 / hg38 34,423 values).

Paper claims validated: KeyGen constant across datasets; Enc times vary
only mildly; comparisons are the dominant aggregate cost (pairwise scaling)
but cheap per operation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import load_dataset, DATASETS


def run(profile: str = "bench-bfv", mode: str = "gadget",
        tag: str = "fig3", max_rows: int = 2048) -> None:
    params = make_params(profile, mode=mode)
    ks = keygen(params, jax.random.PRNGKey(1))
    enc_b = jax.jit(lambda mm, kk: E.encrypt(ks, mm, kk))
    enc_f = jax.jit(lambda mm, kk: E.encrypt_fae(ks, mm, kk))
    cmp_b = jax.jit(lambda a, b: C.compare(ks, a, b))
    cmp_f = jax.jit(lambda a, b: C.compare_fae(ks, a, b))
    emit(f"{tag}.keygen",
         timeit(lambda: keygen(params, jax.random.PRNGKey(1)).pk0, iters=2),
         "dataset-independent")
    for name in DATASETS:
        full = load_dataset(name, scheme="bfv", t=params.t)
        data = jnp.asarray(full[:max_rows], jnp.int64)
        n = data.shape[0]
        emit(f"{tag}.{name}.enc_basic",
             timeit(enc_b, data, jax.random.PRNGKey(2), per=n),
             f"rows={len(full)};timed_rows={n}")
        emit(f"{tag}.{name}.enc_fae",
             timeit(enc_f, data, jax.random.PRNGKey(3), per=n), "")
        ct = enc_b(data, jax.random.PRNGKey(4))
        ct_r = enc_b(jnp.roll(data, 1), jax.random.PRNGKey(5))
        emit(f"{tag}.{name}.cmp_basic", timeit(cmp_b, ct, ct_r, per=n), "")
        emit(f"{tag}.{name}.cmp_fae", timeit(cmp_f, ct, ct_r, per=n), "")


if __name__ == "__main__":
    run()
