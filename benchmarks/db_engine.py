"""repro.db engine benchmark: index build amortization + fused plans.

Demonstrates the database-perspective payoff on the paper's hg38 dataset
(34,423 genomic coordinates, the largest of §6.2.1):

  * index_build     — one-time encrypted bitonic sort (O(n log^2 n)
                      trapdoor compares, every stage one batched Eval)
  * point lookup    — linear fused scan (n compares) vs. index binary
                      search (~2 log2 n compares)
  * range query     — repeated queries with fresh bounds, linear vs.
                      indexed; derived column reports speedup and the
                      break-even query count for the index build
  * batched serving — K range queries executed one-by-one vs. one
                      QueryServer batch (single fused Eval)
  * e2e             — And(Range, Eq) + TopK matches the plaintext answer
                      exactly on all three paper datasets (full rows)

Default profile is test-bfv in paper mode with the Thm 4.1 zero-weight
CEK precondition (exact compares, ~6x faster than gadget mode — the op
*count* comparison is mode-independent).  Pass mode="gadget" for the
full-noise path.

  PYTHONPATH=src python -m benchmarks.db_engine [--rows N] [--mode gadget]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import db
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import DATASETS, load_dataset


def _keys(profile: str, mode: str):
    params = make_params(profile, mode=mode)
    kw = {"paper_ecek_weight": 0} if mode == "paper" else {}
    return keygen(params, jax.random.PRNGKey(1), **kw)


def _enc(ks, v, seed):
    return E.encrypt(ks, jnp.asarray(int(v)), jax.random.PRNGKey(seed))


def _timed(fn, reps: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps, out


def run(profile: str = "test-bfv", mode: str = "paper",
        rows: int | None = None, queries: int = 8, tag: str = "db") -> None:
    ks = _keys(profile, mode)
    params = ks.params
    vals = load_dataset("hg38", scheme="bfv", t=params.t)
    if rows:
        vals = vals[:rows]
    vals = vals.astype(np.int64)
    n = len(vals)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    table = db.Table.from_arrays(ks, "hg38", {"v": vals},
                                 jax.random.PRNGKey(2))
    emit(f"{tag}.encrypt_table", (time.perf_counter() - t0) * 1e6,
         f"rows={n};padded={table.n_padded};mode={mode}")

    # ---- one-time index build (amortized over every later lookup) -------
    t0 = time.perf_counter()
    idx = db.SortedIndex.build(ks, table, "v")
    build_s = time.perf_counter() - t0
    ok = bool((vals[idx.perm] == np.sort(vals)).all())
    emit(f"{tag}.index_build", build_s * 1e6,
         f"compares={idx.build_compares};sorted_ok={ok}")

    # ---- point lookup: linear fused scan vs. index binary search --------
    target = int(vals[n // 3])
    q_eq = db.Eq("v", _enc(ks, target, 3))
    lin = db.execute(ks, table, q_eq)                       # warm the scan
    lin_s, lin_res = _timed(lambda: db.execute(ks, table, q_eq), reps=2)
    ind = db.execute(ks, table, q_eq, indexes={"v": idx})   # warm the search
    ind_s, ind_res = _timed(
        lambda: db.execute(ks, table, q_eq, indexes={"v": idx}), reps=2)
    same = set(lin_res.row_ids.tolist()) == set(ind_res.row_ids.tolist())
    emit(f"{tag}.point.linear", lin_s * 1e6,
         f"compares={lin_res.stats.filter_compares}")
    emit(f"{tag}.point.indexed", ind_s * 1e6,
         f"compares={ind_res.stats.filter_compares};"
         f"speedup={lin_s / ind_s:.1f}x;match={same}")

    # ---- repeated range queries with fresh bounds -----------------------
    bounds = []
    for i in range(queries):
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        bounds.append((int(lo), int(hi),
                       _enc(ks, lo, 100 + i), _enc(ks, hi, 200 + i)))

    def run_ranges(indexes):
        masks = []
        for _, _, ct_lo, ct_hi in bounds:
            masks.append(db.execute(ks, table,
                                    db.Range("v", ct_lo, ct_hi),
                                    indexes=indexes).mask)
        return masks

    lin_total, lin_masks = _timed(lambda: run_ranges(None))
    ind_total, ind_masks = _timed(lambda: run_ranges({"v": idx}))
    exact = all(
        np.array_equal(m, (vals >= lo) & (vals <= hi)) and np.array_equal(m, mi)
        for (lo, hi, _, _), m, mi in zip(bounds, lin_masks, ind_masks))
    per_lin, per_ind = lin_total / queries, ind_total / queries
    saved = per_lin - per_ind
    break_even = build_s / saved if saved > 0 else float("inf")
    emit(f"{tag}.range.linear", per_lin * 1e6, f"queries={queries}")
    emit(f"{tag}.range.indexed", per_ind * 1e6,
         f"speedup={per_lin / per_ind:.1f}x;exact={exact};"
         f"index_break_even_queries={break_even:.0f}")

    # ---- batched serving: K queries, one fused pass ---------------------
    # steady-state comparison: warm both paths (the sequential path was
    # already warmed above; run one throwaway batch so the batched shape's
    # one-time XLA compile isn't billed to the serving loop)
    seq_s, _ = _timed(lambda: run_ranges(None))
    server = db.QueryServer(ks, table, batch=queries)
    for _, _, ct_lo, ct_hi in bounds:
        server.submit(db.Range("v", ct_lo, ct_hi))
    server.run()                                            # warm
    for _, _, ct_lo, ct_hi in bounds:
        server.submit(db.Range("v", ct_lo, ct_hi))
    bat_s, _ = _timed(server.run)
    emit(f"{tag}.serve.sequential", seq_s / queries * 1e6, "")
    emit(f"{tag}.serve.batched", bat_s / queries * 1e6,
         f"fused_eval_calls={server.batch_log[-1].eval_calls};"
         f"speedup={seq_s / bat_s:.1f}x")

    # indexed serving: K queries' binary searches ride the same probe lanes
    seq_i, _ = _timed(lambda: run_ranges({"v": idx}))
    iserver = db.QueryServer(ks, table, indexes={"v": idx}, batch=queries)
    for _, _, ct_lo, ct_hi in bounds:
        iserver.submit(db.Range("v", ct_lo, ct_hi))
    iserver.run()                                           # warm
    for _, _, ct_lo, ct_hi in bounds:
        iserver.submit(db.Range("v", ct_lo, ct_hi))
    bat_i, _ = _timed(iserver.run)
    emit(f"{tag}.serve.sequential_indexed", seq_i / queries * 1e6, "")
    emit(f"{tag}.serve.batched_indexed", bat_i / queries * 1e6,
         f"index_compares={iserver.batch_log[-1].index_compares};"
         f"speedup={seq_i / bat_i:.1f}x")

    # ---- e2e And(Range, Eq) + TopK on all three datasets (full rows) ----
    for name in DATASETS:
        dvals = load_dataset(name, scheme="bfv", t=params.t).astype(np.int64)
        aux = np.random.default_rng(1).integers(0, params.t - 1, len(dvals))
        dt = db.Table.from_arrays(ks, name, {"v": dvals, "aux": aux},
                                  jax.random.PRNGKey(4))
        lo, hi = (int(np.percentile(dvals, 30)),
                  int(np.percentile(dvals, 70)))
        eq_v = int(aux[len(aux) // 2])
        query = db.Query(
            where=db.And(db.Range("v", _enc(ks, lo, 5), _enc(ks, hi, 6)),
                         db.Eq("aux", _enc(ks, eq_v, 7))),
            top_k=db.TopK("v", 5))
        e2e_s, res = _timed(lambda: db.execute(ks, dt, query))
        want_mask = (dvals >= lo) & (dvals <= hi) & (aux == eq_v)
        want_top = sorted(dvals[want_mask].tolist(), reverse=True)[:5]
        exact = (np.array_equal(res.mask, want_mask)
                 and dvals[res.row_ids].tolist() == want_top)
        emit(f"{tag}.e2e.{name}", e2e_s * 1e6,
             f"rows={len(dvals)};matched={int(want_mask.sum())};"
             f"exact={exact}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="test-bfv")
    ap.add_argument("--mode", default="paper", choices=["paper", "gadget"])
    ap.add_argument("--rows", type=int, default=0, help="0 = full hg38")
    ap.add_argument("--queries", type=int, default=8)
    args = ap.parse_args()
    run(profile=args.profile, mode=args.mode, rows=args.rows,
        queries=args.queries)
