"""repro.db engine benchmark: index build amortization + fused plans.

Demonstrates the database-perspective payoff on the paper's hg38 dataset
(34,423 genomic coordinates, the largest of §6.2.1):

  * index_build     — one-time encrypted bitonic sort (O(n log^2 n)
                      trapdoor compares, every stage one batched Eval)
  * point lookup    — linear fused scan (n compares) vs. index binary
                      search (~2 log2 n compares)
  * range query     — repeated queries with fresh bounds, linear vs.
                      indexed; derived column reports speedup and the
                      break-even query count for the index build
  * batched serving — K range queries executed one-by-one vs. one
                      QueryServer batch (single fused Eval)
  * e2e             — And(Range, Eq) + TopK matches the plaintext answer
                      exactly on all three paper datasets (full rows)
  * ckks float pass — the same engine over a CKKS float column (bitcoin
                      volumes on a 0.25 grid): indexed vs linear range
                      query, ε-band Eq lookups, And(Range, Eq) + TopK
                      vs the plaintext reference — BENCH json tracks the
                      float path next to the integer one

  * sharded pass   — the same filter + top-k plan on a ShardedTable at
                      1 vs 4 shards: per-shard scan compares must drop
                      to 1/S of the single-device count while the
                      cross-shard top-k merge stays O(k·S) — the
                      distributed-execution contract, asserted here and
                      recorded in the JSON trajectory

  * join pass      — equi-join on hg38-derived keys, nested-loop
                      (tiled N_l x N_r pair grid, ONE launch layout) vs
                      sort-merge (two SortedIndex runs + log-depth
                      merge + adjacency): wall time AND compare lanes,
                      with the measured nested/sort-merge compare ratio
                      asserted > 1 and recorded in BENCH_db.json —
                      plus the same join on 4-shard tables (the [S, S]
                      pair grid), byte-identical pairs required

  * write pass     — the delta-run write path on the same hg38 table:
                      sustained inserts/sec while a QueryServer keeps
                      answering (FIFO mutation queue), the base ∪ delta
                      index probe within its 2·log2(n_base) +
                      2·log2(n_delta) per-lane budget, and delta
                      compaction through the log-depth merge network
                      (merge compares asserted strictly below the
                      from-scratch rebuild cost)

Every pass lands in BENCH_db.json (machine-readable: wall-clock,
rows/s, compare counts per pass) so the perf trajectory is tracked
across PRs — benchmarks/common.write_json (append mode supports
partial re-runs, e.g. just the db.write.* passes).

Default profile is test-bfv in paper mode with the Thm 4.1 zero-weight
CEK precondition (exact compares, ~6x faster than gadget mode — the op
*count* comparison is mode-independent).  Pass mode="gadget" for the
full-noise path.

  PYTHONPATH=src python -m benchmarks.db_engine [--rows N] [--mode gadget]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_json
from repro import db, obs
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import DATASETS, load_dataset


def _keys(profile: str, mode: str):
    params = make_params(profile, mode=mode)
    kw = {"paper_ecek_weight": 0} if mode == "paper" else {}
    return keygen(params, jax.random.PRNGKey(1), **kw)


def _enc(ks, v, seed):
    return E.encrypt(ks, jnp.asarray(int(v)), jax.random.PRNGKey(seed))


def _timed(fn, reps: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps, out


def _obs_mark():
    """Launch-accounting snapshot before a pass (None when obs is off)."""
    return obs.bench_fields() if obs.is_enabled() else None


def _obs_since(mark) -> str:
    """Delta of the launch-accounting counters since `mark`, rendered as
    derived-string fields — every BENCH pass carries its own launches,
    compare lanes, and retrace count in the JSON trajectory."""
    if mark is None:
        return ""
    now = obs.bench_fields()
    return ("".join(f";{k}={now[k] - mark[k]}" for k in
                    ("eval_launches", "compare_lanes", "jit_retraces")))


def run(profile: str = "test-bfv", mode: str = "paper",
        rows: int | None = None, queries: int = 8, tag: str = "db") -> tuple:
    ks = _keys(profile, mode)
    params = ks.params
    vals = load_dataset("hg38", scheme="bfv", t=params.t)
    if rows:
        vals = vals[:rows]
    vals = vals.astype(np.int64)
    n = len(vals)
    rng = np.random.default_rng(0)

    t0 = time.perf_counter()
    table = db.Table.from_arrays(ks, "hg38", {"v": vals},
                                 jax.random.PRNGKey(2))
    emit(f"{tag}.encrypt_table", (time.perf_counter() - t0) * 1e6,
         f"rows={n};padded={table.n_padded};mode={mode}")

    # ---- one-time index build (amortized over every later lookup) -------
    t0 = time.perf_counter()
    idx = db.SortedIndex.build(ks, table, "v")
    build_s = time.perf_counter() - t0
    ok = bool((vals[idx.perm] == np.sort(vals)).all())
    emit(f"{tag}.index_build", build_s * 1e6,
         f"compares={idx.build_compares};sorted_ok={ok}")

    # ---- point lookup: linear fused scan vs. index binary search --------
    target = int(vals[n // 3])
    q_eq = db.Eq("v", _enc(ks, target, 3))
    lin = db.execute(ks, table, q_eq)                       # warm the scan
    m_lin = _obs_mark()
    lin_s, lin_res = _timed(lambda: db.execute(ks, table, q_eq), reps=2)
    d_lin = _obs_since(m_lin)
    ind = db.execute(ks, table, q_eq, indexes={"v": idx})   # warm the search
    m_ind = _obs_mark()
    ind_s, ind_res = _timed(
        lambda: db.execute(ks, table, q_eq, indexes={"v": idx}), reps=2)
    d_ind = _obs_since(m_ind)
    same = set(lin_res.row_ids.tolist()) == set(ind_res.row_ids.tolist())
    emit(f"{tag}.point.linear", lin_s * 1e6,
         f"compares={lin_res.stats.filter_compares}{d_lin}")
    emit(f"{tag}.point.indexed", ind_s * 1e6,
         f"compares={ind_res.stats.filter_compares};"
         f"speedup={lin_s / ind_s:.1f}x;match={same}{d_ind}")

    # ---- tracing overhead on the indexed point path ---------------------
    # acceptance: < 5% with obs enabled, unmeasurable disabled.  The two
    # states are interleaved rep by rep and compared by median, so slow
    # scheduler ticks land on both sides instead of biasing one.
    was_on = obs.is_enabled()
    offs, ons = [], []
    for _ in range(8):
        obs.disable()
        t0 = time.perf_counter()
        db.execute(ks, table, q_eq, indexes={"v": idx})
        offs.append(time.perf_counter() - t0)
        obs.enable()
        t0 = time.perf_counter()
        db.execute(ks, table, q_eq, indexes={"v": idx})
        ons.append(time.perf_counter() - t0)
    if not was_on:
        obs.disable()
    off_s = sorted(offs)[len(offs) // 2]
    on_s = sorted(ons)[len(ons) // 2]
    emit(f"{tag}.obs.overhead_indexed", (on_s - off_s) * 1e6,
         f"traced_us={on_s * 1e6:.0f};untraced_us={off_s * 1e6:.0f};"
         f"overhead_pct={(on_s / off_s - 1) * 100:.1f}")

    # ---- repeated range queries with fresh bounds -----------------------
    bounds = []
    for i in range(queries):
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        bounds.append((int(lo), int(hi),
                       _enc(ks, lo, 100 + i), _enc(ks, hi, 200 + i)))

    def run_ranges(indexes):
        masks = []
        for _, _, ct_lo, ct_hi in bounds:
            masks.append(db.execute(ks, table,
                                    db.Range("v", ct_lo, ct_hi),
                                    indexes=indexes).mask)
        return masks

    m_rl = _obs_mark()
    lin_total, lin_masks = _timed(lambda: run_ranges(None))
    d_rl = _obs_since(m_rl)
    m_ri = _obs_mark()
    ind_total, ind_masks = _timed(lambda: run_ranges({"v": idx}))
    d_ri = _obs_since(m_ri)
    exact = all(
        np.array_equal(m, (vals >= lo) & (vals <= hi)) and np.array_equal(m, mi)
        for (lo, hi, _, _), m, mi in zip(bounds, lin_masks, ind_masks))
    per_lin, per_ind = lin_total / queries, ind_total / queries
    saved = per_lin - per_ind
    break_even = build_s / saved if saved > 0 else float("inf")
    emit(f"{tag}.range.linear", per_lin * 1e6, f"queries={queries}{d_rl}")
    emit(f"{tag}.range.indexed", per_ind * 1e6,
         f"speedup={per_lin / per_ind:.1f}x;exact={exact};"
         f"index_break_even_queries={break_even:.0f}{d_ri}")

    # ---- batched serving: K queries, one fused pass ---------------------
    # steady-state comparison: warm both paths (the sequential path was
    # already warmed above; run one throwaway batch so the batched shape's
    # one-time XLA compile isn't billed to the serving loop)
    seq_s, _ = _timed(lambda: run_ranges(None))
    server = db.QueryServer(ks, table, batch=queries)
    for _, _, ct_lo, ct_hi in bounds:
        server.submit(db.Range("v", ct_lo, ct_hi))
    server.run()                                            # warm
    for _, _, ct_lo, ct_hi in bounds:
        server.submit(db.Range("v", ct_lo, ct_hi))
    m_bat = _obs_mark()
    bat_s, _ = _timed(server.run)
    d_bat = _obs_since(m_bat)
    emit(f"{tag}.serve.sequential", seq_s / queries * 1e6, "")
    emit(f"{tag}.serve.batched", bat_s / queries * 1e6,
         f"fused_eval_calls={server.batch_log[-1].eval_calls};"
         f"speedup={seq_s / bat_s:.1f}x{d_bat}")

    # indexed serving: K queries' binary searches ride the same probe lanes
    seq_i, _ = _timed(lambda: run_ranges({"v": idx}))
    iserver = db.QueryServer(ks, table, indexes={"v": idx}, batch=queries)
    for _, _, ct_lo, ct_hi in bounds:
        iserver.submit(db.Range("v", ct_lo, ct_hi))
    iserver.run()                                           # warm
    for _, _, ct_lo, ct_hi in bounds:
        iserver.submit(db.Range("v", ct_lo, ct_hi))
    m_bi = _obs_mark()
    bat_i, _ = _timed(iserver.run)
    d_bi = _obs_since(m_bi)
    emit(f"{tag}.serve.sequential_indexed", seq_i / queries * 1e6, "")
    emit(f"{tag}.serve.batched_indexed", bat_i / queries * 1e6,
         f"index_compares={iserver.batch_log[-1].index_compares};"
         f"speedup={seq_i / bat_i:.1f}x{d_bi}")

    # ---- e2e And(Range, Eq) + TopK on all three datasets (full rows) ----
    for name in DATASETS:
        dvals = load_dataset(name, scheme="bfv", t=params.t).astype(np.int64)
        aux = np.random.default_rng(1).integers(0, params.t - 1, len(dvals))
        dt = db.Table.from_arrays(ks, name, {"v": dvals, "aux": aux},
                                  jax.random.PRNGKey(4))
        lo, hi = (int(np.percentile(dvals, 30)),
                  int(np.percentile(dvals, 70)))
        eq_v = int(aux[len(aux) // 2])
        query = db.Query(
            where=db.And(db.Range("v", _enc(ks, lo, 5), _enc(ks, hi, 6)),
                         db.Eq("aux", _enc(ks, eq_v, 7))),
            top_k=db.TopK("v", 5))
        e2e_s, res = _timed(lambda: db.execute(ks, dt, query))
        want_mask = (dvals >= lo) & (dvals <= hi) & (aux == eq_v)
        want_top = sorted(dvals[want_mask].tolist(), reverse=True)[:5]
        exact = (np.array_equal(res.mask, want_mask)
                 and dvals[res.row_ids].tolist() == want_top)
        emit(f"{tag}.e2e.{name}", e2e_s * 1e6,
             f"rows={len(dvals)};matched={int(want_mask.sum())};"
             f"exact={exact}")
    return ks, table, idx, vals


def _median_timed(fn, reps: int = 3):
    """Median-of-reps wall clock (the serving passes compare two timed
    paths, so one slow scheduler tick must not decide the ratio)."""
    ts, out = [], None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2], out


def run_serve_scale(profile: str = "test-bfv", mode: str = "paper",
                    sizes: tuple = (65536, 8192), queries: int = 8,
                    reps: int = 3, lane_budget: int | None = None,
                    tag: str = "db.serve") -> dict:
    """Batched vs sequential serving across table sizes — the
    bandwidth-cliff pass.

    A batch of K same-column range queries is 2K eval lanes per row.
    Stacked eagerly that is a 2K·N working set, which falls off the
    cache/bandwidth cliff at large N (the measured 0.5x regression at
    N=65536 that motivated the lane-budget tiling).  With column dedup
    (the K queries share ONE ciphertext column) and lane-budgeted tiles,
    the batch's working set is bounded by `lane_budget` regardless of N,
    so batching must beat issuing the K queries one by one — asserted
    here (`ratio >= 1.0`) at every size, and recorded per size in
    BENCH_db.json with the budget that produced it."""
    ks = _keys(profile, mode)
    hg = load_dataset("hg38", scheme="bfv", t=ks.params.t).astype(np.int64)
    rng = np.random.default_rng(3)
    from repro.kernels import ops as KO
    budget = KO.resolve_lane_budget(lane_budget)
    summary: dict = {"queries": queries, "atoms": 2 * queries,
                     "lane_budget": budget, "mode": mode, "sizes": {}}
    for size in sizes:
        vals = np.resize(hg, size)          # tile hg38 up to the target N
        table = db.Table.from_arrays(ks, f"hg38_{size}", {"v": vals},
                                     jax.random.PRNGKey(2))
        bounds = []
        for i in range(queries):
            lo, hi = np.sort(rng.choice(vals, 2, replace=False))
            bounds.append((int(lo), int(hi),
                           _enc(ks, lo, 100 + i), _enc(ks, hi, 200 + i)))

        def run_seq():
            return [db.execute(ks, table, db.Range("v", c_lo, c_hi)).mask
                    for _, _, c_lo, c_hi in bounds]

        server = db.QueryServer(ks, table, batch=queries,
                                lane_budget=lane_budget)

        def run_batch():
            qids = [server.submit(db.Range("v", c_lo, c_hi))
                    for _, _, c_lo, c_hi in bounds]
            res = server.run()
            return [res[q].mask for q in qids]

        run_seq(), run_batch()              # warm both paths' programs
        seq_s, seq_masks = _median_timed(run_seq, reps)
        m_b = _obs_mark()
        bat_s, bat_masks = _median_timed(run_batch, reps)
        d_b = _obs_since(m_b)
        exact = all(
            np.array_equal(sm, (vals >= lo) & (vals <= hi))
            and np.array_equal(sm, bm)
            for (lo, hi, _, _), sm, bm in zip(bounds, seq_masks, bat_masks))
        ratio = seq_s / bat_s
        # one pass row per size (append-mode JSON merges rows by name)
        emit(f"{tag}.batched_vs_sequential.n{size}", bat_s / queries * 1e6,
             f"rows={size};atoms={2 * queries};ratio={ratio:.2f}x;"
             f"seq_us_per_q={seq_s / queries * 1e6:.0f};"
             f"lane_budget={budget};reps={reps};exact={exact}{d_b}")
        assert exact, f"served masks diverged from plaintext at N={size}"
        assert ratio >= 1.0, (
            f"batched serving lost to sequential at N={size}: "
            f"{ratio:.2f}x (lane_budget={budget}) — the working-set "
            f"tiling contract is broken")
        summary["sizes"][str(size)] = {
            "sequential_s_per_q": round(seq_s / queries, 4),
            "batched_s_per_q": round(bat_s / queries, 4),
            "ratio": round(ratio, 3),
            "exact": bool(exact),
        }
    return summary


GRID = 0.25       # float lattice step (>> test-ckks tolerance ~0.016)


def _float_dataset(rows: int) -> np.ndarray:
    """Bitcoin trade volumes as CKKS floats, normalized onto the GRID
    lattice (so the plaintext reference stays exact) and into the small
    profile's compare headroom."""
    raw = load_dataset("bitcoin", scheme="ckks")
    if rows:
        raw = raw[:rows]
    return np.round(raw / raw.max() * 4000.0) * GRID        # [0, 1000]


def run_ckks(profile: str = "test-ckks", mode: str = "gadget",
             rows: int = 1024, queries: int = 4,
             tag: str = "db.ckks") -> None:
    """Float-column pass: the engine's ckks path, indexed vs linear."""
    ks = _keys(profile, mode)
    vals = _float_dataset(rows)
    n = len(vals)
    rng = np.random.default_rng(0)

    def fenc(v, seed):
        return E.encrypt(ks, jnp.asarray(float(v)), jax.random.PRNGKey(seed))

    t0 = time.perf_counter()
    table = db.Table.from_arrays(ks, "bitcoin_f", {"v": vals},
                                 jax.random.PRNGKey(2))
    emit(f"{tag}.encrypt_table", (time.perf_counter() - t0) * 1e6,
         f"rows={n};padded={table.n_padded};mode={mode}")

    t0 = time.perf_counter()
    idx = db.SortedIndex.build(ks, table, "v")
    build_s = time.perf_counter() - t0
    ok = bool(np.array_equal(vals[idx.perm], np.sort(vals)))
    emit(f"{tag}.index_build", build_s * 1e6,
         f"compares={idx.build_compares};sorted_ok={ok}")

    # ε-band point lookup: |v - target| <= ε, linear vs indexed
    target, eps = float(vals[n // 3]), 2 * GRID + GRID / 2   # off-lattice ε
    q_eq = db.Eq("v", fenc(target, 3), eps=eps)
    db.execute(ks, table, q_eq)                              # warm
    lin_s, lin_res = _timed(lambda: db.execute(ks, table, q_eq), reps=2)
    db.execute(ks, table, q_eq, indexes={"v": idx})          # warm
    ind_s, ind_res = _timed(
        lambda: db.execute(ks, table, q_eq, indexes={"v": idx}), reps=2)
    want = np.abs(vals - target) <= eps
    exact = (np.array_equal(lin_res.mask, want)
             and np.array_equal(ind_res.mask, want))
    emit(f"{tag}.eps_eq.linear", lin_s * 1e6,
         f"compares={lin_res.stats.filter_compares};matched={int(want.sum())}")
    emit(f"{tag}.eps_eq.indexed", ind_s * 1e6,
         f"compares={ind_res.stats.filter_compares};"
         f"speedup={lin_s / ind_s:.1f}x;exact={exact}")

    # repeated float range queries, off-lattice bounds: linear vs indexed
    bounds = []
    for i in range(queries):
        a, b = np.sort(rng.choice(vals, 2, replace=False))
        bounds.append((float(a) - GRID / 2, float(b) + GRID / 2))
    cts = [(lo, hi, fenc(lo, 100 + i), fenc(hi, 200 + i))
           for i, (lo, hi) in enumerate(bounds)]

    def run_ranges(indexes):
        return [db.execute(ks, table, db.Range("v", c_lo, c_hi),
                           indexes=indexes).mask
                for _, _, c_lo, c_hi in cts]

    run_ranges(None), run_ranges({"v": idx})                 # warm both
    lin_total, lin_masks = _timed(lambda: run_ranges(None))
    ind_total, ind_masks = _timed(lambda: run_ranges({"v": idx}))
    exact = all(
        np.array_equal(m, (vals >= lo) & (vals <= hi)) and np.array_equal(m, mi)
        for (lo, hi, _, _), m, mi in zip(cts, lin_masks, ind_masks))
    per_lin, per_ind = lin_total / queries, ind_total / queries
    emit(f"{tag}.range.linear", per_lin * 1e6, f"queries={queries}")
    emit(f"{tag}.range.indexed", per_ind * 1e6,
         f"speedup={per_lin / per_ind:.1f}x;exact={exact}")

    # e2e: And(Range, Eq-band) + TopK vs the plaintext reference
    aux = np.round(rng.uniform(0, 50, n) / GRID) * GRID
    dt = db.Table.from_arrays(ks, "bitcoin_f2", {"v": vals, "aux": aux},
                              jax.random.PRNGKey(4))
    lo = float(np.percentile(vals, 30)) - GRID / 2
    hi = float(np.percentile(vals, 70)) + GRID / 2
    eq_v, band = float(aux[n // 2]), GRID + GRID / 2
    query = db.Query(
        where=db.And(db.Range("v", fenc(lo, 5), fenc(hi, 6)),
                     db.Eq("aux", fenc(eq_v, 7), eps=band)),
        top_k=db.TopK("v", 5))
    e2e_s, res = _timed(lambda: db.execute(ks, dt, query))
    want_mask = ((vals >= lo) & (vals <= hi)
                 & (np.abs(aux - eq_v) <= band))
    want_top = sorted(vals[want_mask].tolist(), reverse=True)[:5]
    exact = (np.array_equal(res.mask, want_mask)
             and vals[res.row_ids].tolist() == want_top)
    emit(f"{tag}.e2e.float_topk", e2e_s * 1e6,
         f"rows={n};matched={int(want_mask.sum())};exact={exact}")


def run_sharded(profile: str = "test-bfv", mode: str = "paper",
                rows: int | None = None, k: int = 8,
                shards: tuple = (1, 4), tag: str = "db.shard") -> dict:
    """Sharded vs single-device filter + top-k on hg38.

    The distributed-execution contract in numbers: at S shards each
    shard scans A·N_sp ≈ A·n/S rows (1/S of the single-device fused
    scan) and the per-shard top-k networks shrink the same way, while
    the cross-shard merge adds only O(kp·S·log kp) compares — recorded
    per pass and summarized (with the ratio checks) for BENCH_db.json.
    """
    ks = _keys(profile, mode)
    params = ks.params
    vals = load_dataset("hg38", scheme="bfv", t=params.t)
    if rows:
        vals = vals[:rows]
    vals = vals.astype(np.int64)
    n = len(vals)
    lo, hi = (int(np.percentile(vals, 30)), int(np.percentile(vals, 70)))
    query = db.Query(
        where=db.Range("v", _enc(ks, lo, 5), _enc(ks, hi, 6)),
        top_k=db.TopK("v", k))
    want_mask = (vals >= lo) & (vals <= hi)
    want_top = sorted(vals[want_mask].tolist(), reverse=True)[:k]

    summary: dict = {"dataset": "hg38", "rows": n, "k": k, "mode": mode}
    for S in shards:
        spec = db.ShardSpec.create(S)
        t0 = time.perf_counter()
        st = db.ShardedTable.from_arrays(ks, "hg38", {"v": vals},
                                         jax.random.PRNGKey(2), spec=spec)
        emit(f"{tag}.s{S}.encrypt", (time.perf_counter() - t0) * 1e6,
             f"shards={S};devices={spec.mesh_devices};"
             f"block={st.n_padded_per_shard}")
        db.execute(ks, st, query)                        # warm the launches
        wall, res = _timed(lambda: db.execute(ks, st, query), reps=2)
        exact = (np.array_equal(res.mask, want_mask)
                 and vals[res.row_ids].tolist() == want_top)
        stats = res.stats
        emit(f"{tag}.s{S}.filter_topk", wall * 1e6,
             f"rows_per_s={n / wall:.0f};scan_compares={stats.scan_compares};"
             f"per_shard_scan={stats.per_shard_scan_compares};"
             f"per_shard_order={stats.per_shard_order_compares};"
             f"merge_compares={stats.merge_compares};exact={exact}")
        summary[f"s{S}"] = {
            "devices": spec.mesh_devices,
            "wall_s": round(wall, 3),
            "rows_per_s": round(n / wall),
            "scan_compares": stats.scan_compares,
            "per_shard_scan_compares": stats.per_shard_scan_compares,
            "per_shard_order_compares": stats.per_shard_order_compares,
            "merge_compares": stats.merge_compares,
            "exact": bool(exact),
        }
    # the acceptance ratios, checked where they are produced.  The
    # expected numbers follow the engine's documented pow2 geometry:
    # per-shard scans cover next_pow2(ceil(n/S)) rows and the merge
    # tournament runs over next_pow2(S) kp-blocks (non-pow2 shard
    # counts pad with sentinel blocks), so non-pow2 --shards don't
    # report spurious failures.
    from repro.core.compare import next_pow2
    s_lo, s_hi = min(shards), max(shards)
    base = summary[f"s{s_lo}"]
    top = summary[f"s{s_hi}"]
    kp = next_pow2(k)
    sp = next_pow2(s_hi)
    merge_bound = (sp - 1) * (kp + (kp // 2) * max(1, kp.bit_length() - 1))
    summary["per_shard_scan_ratio"] = round(
        top["per_shard_scan_compares"] / base["per_shard_scan_compares"], 4)
    want_ratio = (next_pow2(-(-n // s_hi)) / next_pow2(-(-n // s_lo)))
    summary["per_shard_scan_ratio_ok"] = bool(
        abs(summary["per_shard_scan_ratio"] - want_ratio) < 1e-9)
    summary["merge_bound_k_s"] = merge_bound
    summary["merge_within_bound"] = bool(top["merge_compares"] <= merge_bound)
    emit(f"{tag}.summary", 0.0,
         f"scan_ratio={summary['per_shard_scan_ratio']};"
         f"ratio_ok={summary['per_shard_scan_ratio_ok']};"
         f"merge={top['merge_compares']};bound={merge_bound};"
         f"merge_ok={summary['merge_within_bound']}")
    return summary


def run_join(profile: str = "test-bfv", mode: str = "paper",
             rows: int = 256, shards: int = 4, tag: str = "db.join") -> dict:
    """Nested-loop vs sort-merge equi-join on hg38-derived key columns.

    Keys are hg38 coordinates folded onto a small bucket domain so the
    join selects a realistic many-to-many match set (~rows/8 distinct
    keys).  The acceptance numbers: both strategies return identical
    canonical pairs, sort-merge spends measurably fewer compare lanes
    (`compare_ratio` = nested/sort-merge > 1, recorded in the JSON
    trajectory), and the 4-shard [S, S] pair grid reproduces the
    unsharded pairs byte for byte.
    """
    ks = _keys(profile, mode)
    vals = load_dataset("hg38", scheme="bfv", t=ks.params.t).astype(np.int64)
    n_l, n_r = rows, max(8, rows // 2)
    buckets = max(8, rows // 8)
    lk = vals[:n_l] % buckets
    rk = vals[n_l:n_l + n_r] % buckets
    lt = db.Table.from_arrays(ks, "hg38_l", {"k": lk},
                              jax.random.PRNGKey(30))
    rt = db.Table.from_arrays(ks, "hg38_r", {"k": rk},
                              jax.random.PRNGKey(31))
    want = np.argwhere(lk[:, None] == rk[None, :])
    join = db.Join(None, None, on="k")

    db.execute_join(ks, lt, rt, join, strategy="nested")   # warm the tiles
    m_n = _obs_mark()
    t_nest, res_n = _timed(
        lambda: db.execute_join(ks, lt, rt, join, strategy="nested"), reps=2)
    d_n = _obs_since(m_n)
    nested_ok = bool(np.array_equal(res_n.pairs, want))
    emit(f"{tag}.nested", t_nest * 1e6,
         f"rows={n_l}x{n_r};pairs={len(res_n)};"
         f"compares={res_n.stats.join_compares};"
         f"evals={res_n.stats.eval_calls};exact={nested_ok}{d_n}")

    t0 = time.perf_counter()
    li = {"k": db.SortedIndex.build(ks, lt, "k")}
    ri = {"k": db.SortedIndex.build(ks, rt, "k")}
    build_s = time.perf_counter() - t0
    db.execute_join(ks, lt, rt, join, left_indexes=li, right_indexes=ri)
    m_sm = _obs_mark()
    t_sm, res_s = _timed(
        lambda: db.execute_join(ks, lt, rt, join, left_indexes=li,
                                right_indexes=ri), reps=2)
    d_sm = _obs_since(m_sm)
    sm_ok = bool(np.array_equal(res_s.pairs, want))
    ratio = res_n.stats.join_compares / max(1, res_s.stats.join_compares)
    emit(f"{tag}.sort_merge", t_sm * 1e6,
         f"compares={res_s.stats.join_compares};"
         f"merge={res_s.stats.merge_compares};"
         f"adjacency={res_s.stats.adjacency_compares};"
         f"index_build_s={build_s:.3f};exact={sm_ok};"
         f"compare_ratio={ratio:.1f};speedup={t_nest / t_sm:.1f}x{d_sm}")

    # the acceptance criteria, enforced where they are produced: CI runs
    # this pass, so a strategy regression fails loudly instead of just
    # writing exact=false into the trajectory file
    assert nested_ok, "nested-loop join pairs diverged from plaintext"
    assert sm_ok, "sort-merge join pairs diverged from plaintext"
    assert ratio > 1, (
        f"sort-merge must spend fewer compare lanes than nested-loop "
        f"(got ratio {ratio:.2f})")

    summary = {
        "rows_left": n_l, "rows_right": n_r, "pairs": len(res_n),
        "nested": {"wall_s": round(t_nest, 3),
                   "compares": res_n.stats.join_compares,
                   "eval_calls": res_n.stats.eval_calls,
                   "exact": nested_ok},
        "sort_merge": {"wall_s": round(t_sm, 3),
                       "compares": res_s.stats.join_compares,
                       "merge_compares": res_s.stats.merge_compares,
                       "adjacency_compares": res_s.stats.adjacency_compares,
                       "index_build_s": round(build_s, 3),
                       "exact": sm_ok},
        "compare_ratio": round(ratio, 2),
        "sort_merge_fewer_compares": bool(ratio > 1),
    }
    if shards:
        sl = db.ShardedTable.from_table(ks, lt,
                                        spec=db.ShardSpec.create(shards))
        sr = db.ShardedTable.from_table(ks, rt,
                                        spec=db.ShardSpec.create(shards))
        db.execute_join(ks, sl, sr, join, strategy="nested")       # warm
        t_sh, res_sh = _timed(
            lambda: db.execute_join(ks, sl, sr, join, strategy="nested"),
            reps=2)
        sh_ok = bool(np.array_equal(res_sh.pairs, res_n.pairs))
        assert sh_ok, (
            f"sharded join pairs not byte-identical at S={shards}")
        emit(f"{tag}.sharded_s{shards}", t_sh * 1e6,
             f"grid={shards}x{shards};"
             f"compares={res_sh.stats.join_compares};identical={sh_ok}")
        summary["sharded"] = {"shards": shards, "wall_s": round(t_sh, 3),
                              "compares": res_sh.stats.join_compares,
                              "identical_pairs": sh_ok}
    return summary


def run_write(profile: str = "test-bfv", mode: str = "paper",
              rows: int | None = None, n_insert: int = 0, steps: int = 4,
              tag: str = "db.write", base: tuple | None = None) -> dict:
    """The encrypted write path: delta-run ingest while serving, the
    union (base ∪ delta) index probe, and delta compaction.

    Three passes, each with its acceptance check asserted inline:

      * insert_serve — a QueryServer interleaves insert chunks with
        range queries (FIFO: every query sees exactly the writes
        submitted before it); records sustained inserts/sec while
        serving, every answer checked against the running plaintext.
      * union_probe  — after the ingest (~5% new rows by default), a
        point lookup over base ∪ delta must return the from-scratch
        plaintext answer exactly, in
        <= 2·ceil(log2 n_base) + 2·ceil(log2 n_delta) compares per
        probe lane (base fan-out + one per-run binary search).
      * compact      — folding the delta through the log-depth merge
        network must cost O((n_delta + block)·log) merge compares,
        strictly below the O(n log^2 n) from-scratch rebuild; the
        post-compaction probe stays exact and the merged index sorted.
    """
    from repro.core.compare import next_pow2

    if base is not None:
        ks, table, idx, vals = base
    else:
        ks = _keys(profile, mode)
        vals = load_dataset("hg38", scheme="bfv", t=ks.params.t)
        if rows:
            vals = vals[:rows]
        vals = vals.astype(np.int64)
        table = db.Table.from_arrays(ks, "hg38_w", {"v": vals},
                                     jax.random.PRNGKey(2))
        idx = db.SortedIndex.build(ks, table, "v")
    indexes = {"v": idx}
    n = len(vals)
    rng = np.random.default_rng(7)
    m = n_insert if n_insert > 0 else max(8, round(0.05 * n))

    # ---- sustained ingest while serving (FIFO mutation queue) -----------
    server = db.QueryServer(ks, table, indexes=indexes, batch=4)
    all_vals = vals.copy()
    alive = np.ones(n, bool)
    chunks = np.array_split(rng.choice(vals, m), steps)
    qok, gid_ok = True, True
    t0 = time.perf_counter()
    for i, chunk in enumerate(chunks):
        ins = server.submit_insert({"v": chunk},
                                   jax.random.PRNGKey(1000 + i))
        lo, hi = np.sort(rng.choice(vals, 2, replace=False))
        lo, hi = int(lo), int(hi)
        qid = server.submit(db.Range("v", _enc(ks, lo, 2000 + i),
                                     _enc(ks, hi, 3000 + i)))
        res = server.run()
        start = len(all_vals)
        all_vals = np.concatenate([all_vals, chunk])
        alive = np.concatenate([alive, np.ones(len(chunk), bool)])
        gid_ok &= np.array_equal(res[ins].row_ids,
                                 np.arange(start, start + len(chunk)))
        want = (all_vals >= lo) & (all_vals <= hi) & alive
        qok &= np.array_equal(res[qid].mask, want)
    serve_s = time.perf_counter() - t0
    # a tombstone mid-stream: the very next query must exclude it
    dead = [n // 2, n // 2 + 1]
    did = server.submit_delete(dead)
    lo, hi = int(all_vals.min()), int(all_vals.max())
    qid = server.submit(db.Range("v", _enc(ks, lo, 2500),
                                 _enc(ks, hi, 3500)))
    res = server.run()
    alive[dead] = False
    qok &= (res[did].deleted == len(dead)
            and np.array_equal(res[qid].mask, alive))
    dbuild = sum(b.delta_build_compares for b in server.batch_log)
    emit(f"{tag}.insert_serve", serve_s * 1e6,
         f"inserts={m};inserts_per_s={m / serve_s:.1f};steps={steps};"
         f"exact={qok and gid_ok};delta_build_compares={dbuild}")
    assert qok and gid_ok, "served answers diverged from plaintext"

    # ---- union probe: base fan-out + one per-run binary search ----------
    target = int(all_vals[n + m // 2])            # lives in the delta run
    q_eq = db.Eq("v", _enc(ks, target, 4000))
    db.execute(ks, table, q_eq, indexes=indexes)              # warm
    probe_s, res = _timed(
        lambda: db.execute(ks, table, q_eq, indexes=indexes), reps=2)
    want = (all_vals == target) & alive
    exact = np.array_equal(res.mask, want)
    n_b, n_d = next_pow2(table.n_rows), next_pow2(table.n_delta)
    per_lane = (max(1, (n_b - 1).bit_length())
                + max(1, (n_d - 1).bit_length()))
    bound = 2 * 2 * per_lane                      # 2 lanes (lo, hi), <=2x
    emit(f"{tag}.union_probe", probe_s * 1e6,
         f"compares={res.stats.index_compares};bound={bound};"
         f"n_base={table.n_rows};n_delta={table.n_delta};"
         f"matched={int(want.sum())};exact={exact}")
    assert exact, "union probe diverged from the from-scratch answer"
    assert res.stats.index_compares <= bound, (
        f"union probe blew the 2·log2(n_base)+2·log2(n_delta) budget: "
        f"{res.stats.index_compares} > {bound}")

    # ---- compaction: merge network, never a rebuild ---------------------
    nb, nd = table.n_rows, table.n_delta
    t0 = time.perf_counter()
    cstats = db.compact(ks, table, indexes)
    compact_s = time.perf_counter() - t0
    L = next_pow2(max(nb, nd))
    merge_bound = cstats.merge_rounds * L * (1 + max(1, L.bit_length() - 1))
    sorted_ok = bool(np.array_equal(all_vals[indexes["v"].perm],
                                    np.sort(all_vals)))
    db.execute(ks, table, q_eq, indexes=indexes)              # warm
    post_s, post = _timed(
        lambda: db.execute(ks, table, q_eq, indexes=indexes), reps=2)
    post_ok = np.array_equal(post.mask, want)
    emit(f"{tag}.compact", compact_s * 1e6,
         f"merge_compares={cstats.merge_compares};bound={merge_bound};"
         f"rebuild_compares={cstats.rebuild_compares};"
         f"rounds={cstats.merge_rounds};sorted_ok={sorted_ok};"
         f"post_probe_compares={post.stats.index_compares};"
         f"post_exact={post_ok}")
    assert not table.has_delta and sorted_ok and post_ok
    assert cstats.merge_compares <= merge_bound, (
        f"compaction exceeded the (n_delta + block)·log merge bound: "
        f"{cstats.merge_compares} > {merge_bound}")
    if nb >= 32:        # at toy sizes the pow2-padded merge can tie/lose
        assert cstats.merge_compares < cstats.rebuild_compares, (
            f"compaction cost a rebuild, not a merge: "
            f"{cstats.merge_compares} >= {cstats.rebuild_compares}")

    return {
        "rows_base": n, "rows_inserted": m, "steps": steps,
        "inserts_per_s": round(m / serve_s, 1),
        "serve_wall_s": round(serve_s, 3),
        "delta_build_compares": dbuild,
        "union_probe": {"wall_s": round(probe_s, 4),
                        "compares": res.stats.index_compares,
                        "bound": bound, "exact": bool(exact)},
        "compact": {"wall_s": round(compact_s, 3),
                    "merge_compares": cstats.merge_compares,
                    "merge_bound": merge_bound,
                    "rebuild_compares": cstats.rebuild_compares,
                    "merge_beats_rebuild": bool(
                        cstats.merge_compares < cstats.rebuild_compares),
                    "post_probe_compares": post.stats.index_compares},
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default="test-bfv")
    ap.add_argument("--mode", default="paper", choices=["paper", "gadget"])
    ap.add_argument("--rows", type=int, default=0, help="0 = full hg38")
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--ckks-rows", type=int, default=1024,
                    help="rows for the float-column pass (0 = skip)")
    ap.add_argument("--shards", type=int, nargs="*", default=[1, 4],
                    help="shard counts for the sharded pass (empty = skip)")
    ap.add_argument("--topk", type=int, default=8,
                    help="k for the sharded filter+topk pass")
    ap.add_argument("--join-rows", type=int, default=256,
                    help="left rows for the join pass (0 = skip)")
    ap.add_argument("--write-rows", type=int, default=0,
                    help="inserted rows for the write pass "
                         "(0 = 5%% of base, -1 = skip)")
    ap.add_argument("--serve-sizes", type=int, nargs="*",
                    default=[65536, 8192],
                    help="table sizes for the batched-vs-sequential "
                         "serving pass (empty = skip)")
    ap.add_argument("--serve-reps", type=int, default=3,
                    help="timing reps (median) for the serving pass")
    ap.add_argument("--lane-budget", type=int, default=0,
                    help="eval lanes per fused-scan launch "
                         "(0 = kernels.ops policy default)")
    ap.add_argument("--skip-core", action="store_true",
                    help="skip the core single-table passes (partial "
                         "--append re-runs of later passes; implies "
                         "skipping the write pass, which reuses the "
                         "core pass's table)")
    ap.add_argument("--json", default="BENCH_db.json",
                    help="machine-readable output path ('' = skip)")
    ap.add_argument("--append", action="store_true",
                    help="merge passes into an existing json trajectory "
                         "instead of replacing it (partial re-runs)")
    args = ap.parse_args()
    # launch accounting on for the whole run: every pass's derived fields
    # carry its eval_launches / compare_lanes / jit_retraces share, and
    # the document gets one obs section with the totals
    obs.enable()
    if args.lane_budget:
        # process-wide: one knob governs the fused scans AND the join
        # grids of every pass below (kernels.ops shared policy)
        from repro.kernels import ops as _KO
        _KO.set_lane_budget(args.lane_budget)
    base = None
    if not args.skip_core:
        base = run(profile=args.profile, mode=args.mode, rows=args.rows,
                   queries=args.queries)
    sharded_summary = None
    if args.shards:
        sharded_summary = run_sharded(profile=args.profile, mode=args.mode,
                                      rows=args.rows, k=args.topk,
                                      shards=tuple(args.shards))
    join_summary = None
    if args.join_rows:
        join_summary = run_join(profile=args.profile, mode=args.mode,
                                rows=args.join_rows)
    if args.ckks_rows:
        run_ckks(rows=args.ckks_rows, queries=max(2, args.queries // 2))
    write_summary = None
    if args.write_rows >= 0 and base is not None:
        write_summary = run_write(profile=args.profile, mode=args.mode,
                                  rows=args.rows, n_insert=args.write_rows,
                                  base=base)
    serve_summary = None
    if args.serve_sizes:
        serve_summary = run_serve_scale(
            profile=args.profile, mode=args.mode,
            sizes=tuple(args.serve_sizes), queries=args.queries,
            reps=args.serve_reps, lane_budget=args.lane_budget or None)
    if args.json:
        from repro.kernels import ops as _KO
        write_json(args.json,
                   meta={"benchmark": "db_engine", "profile": args.profile,
                         "mode": args.mode, "rows_arg": args.rows,
                         "lane_budget": _KO.resolve_lane_budget(
                             args.lane_budget or None),
                         "backend": jax.default_backend(),
                         "devices": jax.device_count(),
                         **obs.bench_fields()},
                   # skipped passes stay absent (not null) so --append
                   # re-runs never clobber sections they didn't produce
                   extra={k: v for k, v in
                          {"sharded": sharded_summary,
                           "join": join_summary,
                           "write": write_summary,
                           "serve_scale": serve_summary,
                           "obs": obs.metrics_dump()}.items()
                          if v is not None},
                   append=args.append)
