"""Quickstart: HADES keygen -> encrypt -> compare, both modes, 2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.core import noise


def main():
    # --- gadget mode (correct + secure; DESIGN.md §1.1) ----------------
    params = make_params("test-bfv", mode="gadget")
    print(f"ring n={params.n}, towers={params.qs}, scale={params.scale}, "
          f"max comparable |diff|={params.max_operand}")
    budget = noise.predict(params)
    print(f"noise headroom: {budget.headroom_bits:.1f} bits "
          f"(tau={budget.tau}, 6σ={6*budget.eval_sigma:.0f})")

    ks = keygen(params, jax.random.PRNGKey(0))
    a = jnp.asarray([42, 7, 100, -5])
    b = jnp.asarray([7, 42, 100, 5])
    ct_a = E.encrypt(ks, a, jax.random.PRNGKey(1))
    ct_b = E.encrypt(ks, b, jax.random.PRNGKey(2))
    print("decrypt roundtrip:", E.decrypt(ks, ct_a))
    print("compare(a, b)    :", C.compare(ks, ct_a, ct_b),
          " (expected [1, -1, 0, -1])")

    # --- FA-Extension: equality is obfuscated ---------------------------
    eq = jnp.full((8,), 99)
    ct1 = E.encrypt_fae(ks, eq, jax.random.PRNGKey(3))
    ct2 = E.encrypt_fae(ks, eq, jax.random.PRNGKey(4))
    flips = C.compare_fae(ks, ct1, ct2)
    print("FAE compare of equal values (coin flips):", flips)

    # --- paper-literal mode ---------------------------------------------
    p2 = make_params("test-bfv", mode="paper")
    ks2 = keygen(p2, jax.random.PRNGKey(0), paper_ecek_weight=0)
    ct_a2 = E.encrypt(ks2, a, jax.random.PRNGKey(1))
    ct_b2 = E.encrypt(ks2, b, jax.random.PRNGKey(2))
    print("paper-mode compare:", C.compare(ks2, ct_a2, ct_b2))


if __name__ == "__main__":
    main()
