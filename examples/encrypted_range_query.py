"""Database-perspective demo: encrypted column -> range query, sort, top-k.

The server never sees plaintext values — only HADES comparison outcomes.

    PYTHONPATH=src python examples/encrypted_range_query.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import load_dataset


def main():
    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(0))

    # a slice of the paper's bitcoin dataset, reduced mod t
    col_plain = load_dataset("bitcoin", scheme="bfv", t=params.t)[:64]
    # clamp into the comparable range of the small test profile
    col_plain = (col_plain % (params.max_operand // 2)).astype(np.int64)
    column = E.encrypt(ks, jnp.asarray(col_plain), jax.random.PRNGKey(1))
    print(f"encrypted column: {col_plain.shape[0]} rows, "
          f"ct bytes/row = {2 * params.num_towers * params.n * 8}")

    lo_v, hi_v = int(np.percentile(col_plain, 25)), int(np.percentile(col_plain, 75))
    ct_lo = E.encrypt(ks, jnp.asarray(lo_v), jax.random.PRNGKey(2))
    ct_hi = E.encrypt(ks, jnp.asarray(hi_v), jax.random.PRNGKey(3))

    t0 = time.time()
    mask = C.range_query(ks, column, ct_lo, ct_hi)
    print(f"range [{lo_v}, {hi_v}]: {int(mask.sum())} rows matched "
          f"({time.time()-t0:.2f}s); exact: "
          f"{int(((col_plain>=lo_v)&(col_plain<=hi_v)).sum())}")

    t0 = time.time()
    _, perm = C.encrypted_sort(ks, column)
    sorted_plain = col_plain[np.asarray(perm)]
    ok = bool((sorted_plain == np.sort(col_plain)).all())
    print(f"encrypted bitonic sort: correct={ok} ({time.time()-t0:.2f}s)")

    _, idx = C.encrypted_topk(ks, column, 5)
    print("top-5 (via encrypted compare):", sorted(col_plain[np.asarray(idx)]),
          " exact:", sorted(np.sort(col_plain)[-5:]))


if __name__ == "__main__":
    main()
