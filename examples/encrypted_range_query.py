"""Database-perspective demo: encrypted column -> range query, sort, top-k,
then the same workload through the `repro.db` query engine on the full
34,423-row hg38 dataset.

The server never sees plaintext values — only HADES comparison outcomes.

    PYTHONPATH=src python examples/encrypted_range_query.py
    PYTHONPATH=src python examples/encrypted_range_query.py \
        --rows 0 --index-rows 8192        # 0 = full dataset

Part 1 drives the raw core/compare.py primitives on a 64-row bitcoin
slice (unchanged seed demo).  Part 2 builds a `repro.db.Table` over hg38,
runs a fused And(Range, Eq) + TopK plan — every filter comparison in ONE
batched Eval — and contrasts a linear-scan range query with the same
query through a HADES sorted index (O(log n) encrypted binary search).
Part 3 switches to a CKKS profile and runs the same engine over FLOAT
columns: ε-band equality (`Eq(col, v, eps)` selects |col - v| <= ε), an
ε-aware indexed lookup, and a float top-k — the paper's "supports both
integer and floating-point operations" claim, end to end.  Skip it with
--no-ckks (the ckks keygen is the slow part).
Part 4 shards the table across the host's devices (`repro.db.shard`):
the same fused plan runs shard-parallel with a cross-shard top-k merge,
answers match the single-device table exactly, and each shard scans
only 1/S of the rows.  Run with
XLA_FLAGS=--xla_force_host_platform_device_count=4 to watch it place on
a real 4-device mesh; without it the demo still runs (logical shards on
one device — answers identical by construction).
Part 5 joins TWO encrypted tables (positions x per-chromosome
annotations) on an encrypted key column: the batched nested-loop pair
grid vs the index-reusing sort-merge, identical pairs, far fewer
compares — and only the projected result columns are ever decrypted.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import db
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import load_dataset


def part1_primitives(ks, params):
    """The raw comparator ops on a small bitcoin slice."""
    col_plain = load_dataset("bitcoin", scheme="bfv", t=params.t)[:64]
    # clamp into the comparable range of the small test profile
    col_plain = (col_plain % (params.max_operand // 2)).astype(np.int64)
    column = E.encrypt(ks, jnp.asarray(col_plain), jax.random.PRNGKey(1))
    print(f"encrypted column: {col_plain.shape[0]} rows, "
          f"ct bytes/row = {2 * params.num_towers * params.n * 8}")

    lo_v, hi_v = (int(np.percentile(col_plain, 25)),
                  int(np.percentile(col_plain, 75)))
    ct_lo = E.encrypt(ks, jnp.asarray(lo_v), jax.random.PRNGKey(2))
    ct_hi = E.encrypt(ks, jnp.asarray(hi_v), jax.random.PRNGKey(3))

    t0 = time.time()
    mask = C.range_query(ks, column, ct_lo, ct_hi)
    print(f"range [{lo_v}, {hi_v}]: {int(mask.sum())} rows matched "
          f"({time.time()-t0:.2f}s); exact: "
          f"{int(((col_plain>=lo_v)&(col_plain<=hi_v)).sum())}")

    t0 = time.time()
    _, perm = C.encrypted_sort(ks, column)
    sorted_plain = col_plain[np.asarray(perm)]
    ok = bool((sorted_plain == np.sort(col_plain)).all())
    print(f"encrypted bitonic sort: correct={ok} ({time.time()-t0:.2f}s)")

    _, idx = C.encrypted_topk(ks, column, 5)
    print("top-5 (via encrypted compare):",
          sorted(col_plain[np.asarray(idx)]),
          " exact:", sorted(np.sort(col_plain)[-5:]))


def part2_db_engine(ks, params, rows: int, index_rows: int):
    """The repro.db engine over the hg38 genomic-coordinate dataset."""
    vals = load_dataset("hg38", scheme="bfv", t=params.t).astype(np.int64)
    if rows:
        vals = vals[:rows]
    rng = np.random.default_rng(0)
    chrom = rng.integers(1, 23, len(vals))         # second encrypted column

    print(f"\n--- repro.db on hg38 ({len(vals)} rows) ---")
    t0 = time.time()
    table = db.Table.from_arrays(ks, "hg38", {"pos": vals, "chrom": chrom},
                                 jax.random.PRNGKey(10))
    print(f"table: {table} ({table.ciphertext_bytes() / 1e6:.0f} MB ct, "
          f"encrypted in {time.time()-t0:.1f}s)")

    def enc(v, s):
        return E.encrypt(ks, jnp.asarray(int(v)), jax.random.PRNGKey(s))

    # fused plan: And(Range(pos), Eq(chrom)) + TopK — one Eval for the
    # whole filter stage, regardless of how many predicates it holds
    lo, hi = int(np.percentile(vals, 40)), int(np.percentile(vals, 60))
    target_chrom = 7
    query = db.Query(
        where=db.And(db.Range("pos", enc(lo, 11), enc(hi, 12)),
                     db.Eq("chrom", enc(target_chrom, 13))),
        top_k=db.TopK("pos", 5))
    t0 = time.time()
    res = db.execute(ks, table, query)
    want = (vals >= lo) & (vals <= hi) & (chrom == target_chrom)
    want_top = sorted(vals[want].tolist(), reverse=True)[:5]
    print(f"And(Range, Eq) + TopK: {int(want.sum())} matched, "
          f"top-5 exact={vals[res.row_ids].tolist() == want_top} "
          f"({time.time()-t0:.1f}s, {res.stats.eval_calls} fused Eval, "
          f"{res.stats.filter_compares} compares)")

    # index: build once on a prefix, then point lookups & range scans in
    # O(log n) compares instead of a linear scan
    n_idx = min(index_rows or len(vals), len(vals))
    sub = db.Table.from_arrays(ks, "hg38_idx", {"pos": vals[:n_idx]},
                               jax.random.PRNGKey(14))
    t0 = time.time()
    index = db.SortedIndex.build(ks, sub, "pos")
    print(f"sorted index over {n_idx} rows: built in {time.time()-t0:.1f}s "
          f"({index.build_compares} build compares, "
          f"sorted_ok={bool((vals[:n_idx][index.perm] == np.sort(vals[:n_idx])).all())})")

    q = db.Range("pos", enc(lo, 15), enc(hi, 16))
    db.execute(ks, sub, q)                                  # warm jit
    db.execute(ks, sub, q, indexes={"pos": index})
    t0 = time.time()
    lin = db.execute(ks, sub, q)
    t_lin = time.time() - t0
    t0 = time.time()
    ind = db.execute(ks, sub, q, indexes={"pos": index})
    t_ind = time.time() - t0
    match = bool(np.array_equal(lin.mask, ind.mask))
    print(f"range query: linear {t_lin:.2f}s "
          f"({lin.stats.filter_compares} compares) vs indexed {t_ind:.2f}s "
          f"({ind.stats.filter_compares} compares) — "
          f"speedup {t_lin / t_ind:.1f}x, match={match}")


def part3_ckks_floats(rows: int):
    """Float columns through the ckks profile: ε-band Eq + float top-k."""
    from repro.core.ckks import equality_tolerance

    params = make_params("test-ckks", mode="gadget")
    print(f"\n--- ckks float columns ({rows} rows, native tolerance "
          f"{equality_tolerance(params):.4f}) ---")
    t0 = time.time()
    ks = keygen(params, jax.random.PRNGKey(3))
    print(f"ckks keygen: {time.time()-t0:.1f}s")

    raw = load_dataset("bitcoin", scheme="ckks")[:rows]
    vals = np.round(raw / raw.max() * 400) * 0.25       # [0, 100] grid floats
    rng = np.random.default_rng(1)
    score = np.round(rng.uniform(0, 10, rows) * 4) * 0.25
    table = db.Table.from_arrays(ks, "btc_float",
                                 {"vol": vals, "score": score},
                                 jax.random.PRNGKey(4))

    def enc(v, s):
        return E.encrypt(ks, jnp.asarray(float(v)), jax.random.PRNGKey(s))

    # ε-band equality: every day whose score is within 0.3 of today's
    target, eps = float(score[-1]), 0.3
    res = db.execute(ks, table, db.Eq("score", enc(target, 5), eps=eps))
    want = np.abs(score - target) <= eps
    print(f"Eq(score, {target}, eps={eps}): {len(res)} rows "
          f"(plaintext: {int(want.sum())}, "
          f"exact={bool(np.array_equal(res.mask, want))})")

    # float range + top-k, linear vs ε-aware indexed binary search
    lo, hi = (float(np.percentile(vals, 40)) - 0.125,
              float(np.percentile(vals, 60)) + 0.125)
    q = db.Query(where=db.Range("vol", enc(lo, 6), enc(hi, 7)),
                 top_k=db.TopK("vol", 5), select=("vol",))
    idx = db.SortedIndex.build(ks, table, "vol")
    lin = db.execute(ks, table, q)
    ind = db.execute(ks, table, q, indexes={"vol": idx})
    wmask = (vals >= lo) & (vals <= hi)
    wtop = sorted(vals[wmask].tolist(), reverse=True)[:5]
    print(f"Range[{lo:.2f}, {hi:.2f}] + TopK(5): "
          f"linear==indexed=={bool(np.array_equal(lin.mask, ind.mask))}, "
          f"top-5 exact={vals[ind.row_ids].tolist() == wtop} "
          f"({ind.stats.index_compares} probe compares vs "
          f"{lin.stats.scan_compares} scan)")
    dec = np.asarray(E.decrypt(ks, ind.columns["vol"]))
    print(f"projected ciphertexts decrypt within "
          f"{np.abs(dec - np.asarray(wtop)).max():.2e} of plaintext")


def part4_sharded(ks, params, rows: int, shards: int, topk: int):
    """The same workload on a mesh-sharded table (repro.db.shard)."""
    vals = load_dataset("hg38", scheme="bfv", t=params.t).astype(np.int64)
    if rows:
        vals = vals[:rows]
    spec = db.ShardSpec.create(shards)
    print(f"\n--- sharded table: {len(vals)} rows over {spec} "
          f"({jax.device_count()} host devices, "
          f"shard_map={'on' if spec.shard_map_ok else 'off — 1 device'}) ---")

    t0 = time.time()
    st = db.ShardedTable.from_arrays(ks, "hg38", {"pos": vals},
                                     jax.random.PRNGKey(20), spec=spec)
    print(f"sharded ingest: {st.num_shards} x {st.n_padded_per_shard}-row "
          f"blocks, uneven tails masked per shard ({time.time()-t0:.1f}s)")

    def enc(v, s):
        return E.encrypt(ks, jnp.asarray(int(v)), jax.random.PRNGKey(s))

    lo, hi = int(np.percentile(vals, 35)), int(np.percentile(vals, 65))
    query = db.Query(where=db.Range("pos", enc(lo, 21), enc(hi, 22)),
                     top_k=db.TopK("pos", topk))
    db.execute(ks, st, query)                               # warm jit
    t0 = time.time()
    res = db.execute(ks, st, query)                         # auto-dispatch
    wall = time.time() - t0
    want = (vals >= lo) & (vals <= hi)
    want_top = sorted(vals[want].tolist(), reverse=True)[:topk]
    s = res.stats
    print(f"Range + TopK({topk}): {int(want.sum())} matched, "
          f"exact={vals[res.row_ids].tolist() == want_top} ({wall:.1f}s)")
    print(f"  per-shard scan: {s.per_shard_scan_compares} compares "
          f"(total {s.scan_compares} = {st.num_shards} shards x 1/S slices)")
    print(f"  top-k: {s.per_shard_order_compares} per-shard network + "
          f"{s.merge_compares} cross-shard merge compares "
          f"(merge is O(k*S), independent of n)")

    # fan-out index: every shard's index probed in one lane-batched launch
    idx = db.ShardedIndex.build(ks, st, "pos")
    res_i = db.execute(ks, st, db.Range("pos", enc(lo, 23), enc(hi, 24)),
                       indexes={"pos": idx})
    print(f"fan-out indexed range: match={bool(np.array_equal(res_i.mask, want))}, "
          f"{res_i.stats.index_compares} probe compares across "
          f"{st.num_shards} shard indexes, 0 scans")


def part5_join(ks, params, rows: int):
    """Two encrypted tables, one decrypted result: an equi-join."""
    vals = load_dataset("hg38", scheme="bfv", t=params.t).astype(np.int64)
    vals = vals[:rows]
    rng = np.random.default_rng(2)
    chrom = rng.integers(1, 23, len(vals))          # join key, left side
    positions = db.Table.from_arrays(
        ks, "positions", {"chrom": chrom, "pos": vals},
        jax.random.PRNGKey(30))
    # right side: one annotation row per chromosome (plus a few extras)
    ann_chrom = np.arange(1, 23)
    ann_score = rng.integers(0, 100, len(ann_chrom))
    annotations = db.Table.from_arrays(
        ks, "annotations", {"chrom": ann_chrom, "score": ann_score},
        jax.random.PRNGKey(31))

    print(f"\n--- encrypted join: {positions.n_rows} positions x "
          f"{annotations.n_rows} annotations on 'chrom' ---")
    join = db.Join(db.Query(select=("pos",)), db.Query(select=("score",)),
                   on="chrom")
    t0 = time.time()
    nested = db.execute_join(ks, positions, annotations, join,
                             strategy="nested")
    t_nested = time.time() - t0
    want = np.argwhere(chrom[:, None] == ann_chrom[None, :])
    print(f"nested-loop: {len(nested)} pairs "
          f"(exact={bool(np.array_equal(nested.pairs, want))}, "
          f"{nested.stats.join_compares} pair compares in "
          f"{nested.stats.eval_calls} tiled launches, {t_nested:.1f}s)")

    li = {"chrom": db.SortedIndex.build(ks, positions, "chrom")}
    ri = {"chrom": db.SortedIndex.build(ks, annotations, "chrom")}
    t0 = time.time()
    merged = db.execute_join(ks, positions, annotations, join,
                             left_indexes=li, right_indexes=ri)
    t_sm = time.time() - t0
    print(f"sort-merge:  {len(merged)} pairs "
          f"(identical={bool(np.array_equal(merged.pairs, nested.pairs))}, "
          f"{merged.stats.join_compares} compares = "
          f"{nested.stats.join_compares // max(1, merged.stats.join_compares)}"
          f"x fewer, {t_sm:.1f}s)")

    # ONLY the projected result ever decrypts (client-side, needs sk)
    pos_dec = np.asarray(E.decrypt(ks, merged.columns["left.pos"]))
    score_dec = np.asarray(E.decrypt(ks, merged.columns["right.score"]))
    ok = (np.array_equal(pos_dec, vals[merged.pairs[:, 0]])
          and np.array_equal(score_dec, ann_score[merged.pairs[:, 1]]))
    print(f"decrypted join result: {len(pos_dec)} (pos, score) rows, "
          f"exact={ok}; first 3: "
          f"{list(zip(pos_dec[:3].tolist(), score_dec[:3].tolist()))}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=0,
                    help="hg38 rows for the db demo (0 = all 34,423)")
    ap.add_argument("--index-rows", type=int, default=4096,
                    help="rows to index (0 = all; build is O(n log^2 n))")
    ap.add_argument("--no-ckks", action="store_true",
                    help="skip the float-column (ckks) part")
    ap.add_argument("--ckks-rows", type=int, default=256,
                    help="rows for the float-column part")
    ap.add_argument("--no-shard", action="store_true",
                    help="skip the sharded-table part")
    ap.add_argument("--shards", type=int, default=4,
                    help="logical shard count for part 4")
    ap.add_argument("--shard-rows", type=int, default=8192,
                    help="hg38 rows for the sharded part (0 = all)")
    ap.add_argument("--join-rows", type=int, default=512,
                    help="hg38 rows for the join part (0 = skip)")
    args = ap.parse_args(argv)

    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(0))
    part1_primitives(ks, params)
    part2_db_engine(ks, params, args.rows, args.index_rows)
    if not args.no_ckks:
        part3_ckks_floats(args.ckks_rows)
    if not args.no_shard:
        part4_sharded(ks, params, args.shard_rows, args.shards, 5)
    if args.join_rows:
        part5_join(ks, params, args.join_rows)


if __name__ == "__main__":
    main()
