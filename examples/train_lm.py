"""End-to-end training driver: ~100M-param smollm variant, few hundred
steps, checkpoint + resume demonstrated mid-run.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(Defaults are sized for this CPU container: seq 256, batch 8; pass
--steps 300 --seq 512 for the fuller run.)
"""
import argparse
import sys
import tempfile

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        half = args.steps // 2
        # phase 1: train to the midpoint, checkpointing
        train_driver.main([
            "--arch", "smollm-360m", "--variant", "train_100m",
            "--steps", str(half), "--seq", str(args.seq),
            "--batch", str(args.batch),
            "--ckpt-dir", ckpt, "--ckpt-every", "25",
        ])
        # phase 2: resume from the checkpoint and finish — proves the
        # restart path end-to-end (same data order, loss continuous)
        result = train_driver.main([
            "--arch", "smollm-360m", "--variant", "train_100m",
            "--steps", str(args.steps), "--seq", str(args.seq),
            "--batch", str(args.batch),
            "--ckpt-dir", ckpt, "--resume", "auto",
        ])
    ok = result["last_loss"] < result["first_loss"]
    print(f"loss {result['first_loss']:.3f} -> {result['last_loss']:.3f} "
          f"({'improved' if ok else 'NO IMPROVEMENT'})")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
