"""HADES x LM serving: encrypted top-k over model scores (DESIGN.md §2.1).

An outsourced LM server produces candidate scores (here: last-token logits
of a smollm-family model over a candidate set).  The score owner encrypts
them; the DB layer picks the top-k WITHOUT learning the scores, via HADES
comparisons.  This is the paper's database perspective applied at the
serving boundary.

    PYTHONPATH=src python examples/secure_topk_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import compare as C
from repro.core import encrypt as E
from repro.core.ckks import equality_tolerance
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.models import serve as SV
from repro.models import transformer as T


def main():
    # --- 1. the LM produces scores --------------------------------------
    cfg = configs.get_reduced("smollm_360m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S),
                                          0, cfg.vocab_size)}
    logits, _ = SV.prefill(cfg, params, batch)

    n_cand = 16
    cand = jax.random.choice(jax.random.PRNGKey(2),
                             cfg.vocab_size, (n_cand,), replace=False)
    scores = logits[0, cand]                       # [n_cand] float scores
    print("candidate scores:", np.round(np.asarray(scores), 2))

    # --- 2. client encrypts scores (CKKS profile: floats) ---------------
    hp = make_params("test-ckks", mode="gadget")
    ks = keygen(hp, jax.random.PRNGKey(3))
    tol = equality_tolerance(hp)
    enc_scores = E.encrypt(ks, scores.astype(jnp.float64),
                           jax.random.PRNGKey(4))

    # --- 3. server-side encrypted top-k ---------------------------------
    k = 4
    _, top_idx = C.encrypted_topk(ks, enc_scores, k)
    picked = np.asarray(cand)[np.asarray(top_idx)]
    exact = np.asarray(cand)[np.argsort(np.asarray(scores))[-k:]]
    print(f"encrypted top-{k} tokens: {sorted(picked.tolist())}")
    print(f"plaintext top-{k} tokens: {sorted(exact.tolist())}")
    print(f"(CKKS equality tolerance: |Δscore| < {tol:.3g} "
          f"counts as a tie and may reorder)")


if __name__ == "__main__":
    main()
