"""Part 6: watching the engine work — `repro.obs` end to end.

One encrypted range query (linear scan, then through the HADES sorted
index) runs under a trace; the demo prints the nested span tree with
device-true timings, the counter table the run produced, the jit-cache
observer's launch signatures, and writes a Chrome-trace JSON you can
drop into ui.perfetto.dev.

    PYTHONPATH=src python examples/part6_observability.py
    PYTHONPATH=src python examples/part6_observability.py \
        --rows 2048 --trace-out /tmp/trace.json

The parts 1-5 tour (primitives, engine, floats, shards, joins) lives
in examples/encrypted_range_query.py; this file is the observability
chapter: where the launches go, what each one cost, and how to tell a
healthy batch from a broken one (span taxonomy and counter glossary:
docs/architecture.md §8).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import db, obs
from repro.core import encrypt as E
from repro.core.keys import keygen
from repro.core.params import make_params
from repro.data import load_dataset


def main(argv=None):
    """Trace one encrypted range query; print spans + counters."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1024,
                    help="hg38 rows to load (0 = all 34,423)")
    ap.add_argument("--trace-out", default="obs_trace.json",
                    help="Chrome-trace JSON output path ('' = skip)")
    args = ap.parse_args(argv)

    params = make_params("test-bfv", mode="gadget")
    ks = keygen(params, jax.random.PRNGKey(0))
    vals = load_dataset("hg38", scheme="bfv", t=params.t).astype(np.int64)
    if args.rows:
        vals = vals[:args.rows]

    print(f"--- setup: {len(vals)} hg38 rows, encrypt + index ---")
    t0 = time.time()
    table = db.Table.from_arrays(ks, "hg38", {"pos": vals},
                                 jax.random.PRNGKey(1))
    idx = db.SortedIndex.build(ks, table, "pos")
    print(f"table {table.n_rows} rows (padded {table.n_padded}), index "
          f"built with {idx.build_compares} compares ({time.time()-t0:.1f}s)")

    def enc(v, s):
        return E.encrypt(ks, jnp.asarray(int(v)), jax.random.PRNGKey(s))

    lo, hi = int(np.percentile(vals, 40)), int(np.percentile(vals, 60))
    q = db.Range("pos", enc(lo, 2), enc(hi, 3))
    db.execute(ks, table, q)                          # warm jit (untraced)
    db.execute(ks, table, q, indexes={"pos": idx})

    # ---- the traced run: linear scan, then the indexed path -------------
    print(f"\n--- traced: Range[{lo}, {hi}] linear + indexed ---")
    with obs.tracing() as tr:
        with obs.span("demo.linear"):
            lin = db.execute(ks, table, q)
        with obs.span("demo.indexed"):
            ind = db.execute(ks, table, q, indexes={"pos": idx})
    assert np.array_equal(lin.mask, ind.mask)

    print("\nspan tree (device-true ms):")
    for line in tr.tree_lines():
        print(f"  {line}")

    print("\ncounter table:")
    snap = obs.REGISTRY.snapshot()
    width = max(len(k) for k in snap)
    for name, v in snap.items():
        if isinstance(v, dict):                       # histogram summary
            v = (f"count={v['count']:.0f} p50={v['p50']:.3g} "
                 f"p99={v['p99']:.3g}")
        print(f"  {name:<{width}}  {v}")

    print("\njit-cache observer (signatures per launch site):")
    for site, sigs in obs.jit_signatures().items():
        flag = "" if len(sigs) == 1 else "  <-- RETRACES"
        print(f"  {site}: {len(sigs)} signature(s){flag}")

    f = obs.bench_fields()
    print(f"\nlaunch accounting: {f['eval_launches']} launches, "
          f"{f['compare_lanes']} compare lanes, "
          f"{f['jit_retraces']} retraces")
    print(f"  linear scan:  {lin.stats.scan_compares} compares in "
          f"{lin.stats.eval_calls} fused launch")
    print(f"  indexed path: {ind.stats.index_compares} probe compares "
          f"(binary search, ~2*log2 n)")

    if args.trace_out:
        tr.write_chrome_trace(args.trace_out)
        errs = obs.validate_chrome_trace(tr.chrome_trace())
        print(f"\nwrote {args.trace_out} "
              f"(valid Chrome trace: {not errs}) — open at ui.perfetto.dev")


if __name__ == "__main__":
    main()
